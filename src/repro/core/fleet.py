"""Fleet-scale compaction scheduler: thousands of tables, one budget.

The single-table OODA loop (``AutoCompPipeline``) stays the per-pool policy
object; this layer owns the cross-table decide/act that the paper's future
work calls for (multi-objective, workload-aware compaction across a fleet):

  tables --classify--> workload class --> class pipeline.propose()
                                             |  (observe memoized per
                                             |   snapshot, activity-fed)
         pooled ranked candidates <----------+
                |
         fleet decide: min-max normalize across the WHOLE pool,
           benefit weighted by query frequency (hot tables first),
           aging boost + hard promotion for starved tables,
           greedy fit into the shared GBHr budget
           (unpriced candidates conservatively skipped)
                |
         fleet act: selected candidates dispatched per class through
           that class's scheduler; deferred work reported, not dropped

Workload classes (the trigger/granularity/data-movement policy axes of the
LSM design-space literature, collapsed to profiles):

  append-storm  sustained high-rate small-file ingestion (Arc's ~17k
                files/day/measurement storm) — compact eagerly, partition
                scope, low trigger threshold;
  bursty        interactive bursts — compact on a moderate threshold;
  cold          near-idle long tail — compact only heavy fragmentation
                (budget is better spent on tables queries actually touch);
  steady        everything else — the default profile.

Per-class profiles are plain knob dicts, hillclimbable with
``core.autotune.tune_design`` (see :meth:`FleetScheduler.tune_profile`).

Starvation bound: a fragmented table skipped ``starvation_cycles`` times
gets promoted ahead of the un-starved pool (oldest first) until served, so
no table waits forever behind permanently-hotter neighbors as long as the
budget clears the starved set each cycle.

Determinism (NFR2): the pooled ranking sorts by candidate key before
normalization and breaks every ordering tie on the key, so permuting table
enumeration order never changes the selection.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple

from repro.core.act import ActReport, Scheduler
from repro.core.decide import (FLEET_NORM_TRAITS, MoopRanker,
                               minmax_normalize, pooled_benefit,
                               select_budget)
from repro.core.filters import MinSmallFilesFilter
from repro.core.model import Candidate, Scope
from repro.core.observe import StatsCollector
from repro.core.ooda import AutoCompPipeline
from repro.core.orient import (ComputeCostTrait, FileCountReductionTrait,
                               FileEntropyTrait, TraitContext)
from repro.core.retention import RetentionQueue
from repro.lst.catalog import Catalog

MB = 1 << 20

CLASSES = ("append-storm", "bursty", "cold", "steady")


@dataclasses.dataclass(frozen=True)
class ClassProfile:
    """Per-workload-class policy knobs (the axes ``tune_profile``
    hillclimbs). ``scope`` maps to candidate granularity, ``min_small_files``
    is the compaction trigger threshold, ``target_file_mb`` the rewrite
    target size."""
    name: str
    scope: str = "hybrid"               # "table" | "hybrid"
    target_file_mb: int = 512
    min_small_files: int = 4
    top_k: Optional[int] = None         # per-class cap on pooled candidates
    benefit_weight: float = 0.7         # MOOP w1 (w2 = 1 - w1)

    def axes(self) -> Dict[str, Sequence]:
        """Discrete design space for ``tune_design`` (declaration order
        fixes the hillclimb walk)."""
        return {
            "min_small_files": (2, 4, 8, 16, 32),
            "scope": ("hybrid", "table"),
            "target_file_mb": (128, 256, 512),
        }


DEFAULT_PROFILES: Dict[str, ClassProfile] = {
    "append-storm": ClassProfile("append-storm", scope="hybrid",
                                 min_small_files=4),
    "bursty": ClassProfile("bursty", scope="hybrid", min_small_files=8),
    "cold": ClassProfile("cold", scope="table", min_small_files=32),
    "steady": ClassProfile("steady", scope="table", min_small_files=8),
}


def classify_table(read_rate: float, write_file_rate: float,
                   burstiness: float,
                   storm_file_rate: float = 50.0,
                   bursty_ratio: float = 3.0,
                   cold_rate: float = 0.5) -> str:
    """Map an observed write/query pattern to a workload class. Cold is
    checked before bursty: a near-idle table's lone write always looks
    "bursty" by peak-to-mean, but rates that low belong to the cold tail."""
    if write_file_rate >= storm_file_rate:
        return "append-storm"
    if read_rate < cold_rate and write_file_rate < cold_rate:
        return "cold"
    if burstiness >= bursty_ratio and write_file_rate > 0:
        return "bursty"
    return "steady"


def build_class_pipeline(profile: ClassProfile, activity=None,
                         stats: Optional[StatsCollector] = None,
                         scheduler: Optional[Scheduler] = None,
                         executor_memory_gb: float = 8.0,
                         rewrite_bytes_per_hour: float = 256e9
                         ) -> AutoCompPipeline:
    """One per-class policy pipeline: its propose() half feeds the fleet
    pool; its scheduler is the class's act tail. Pass a shared ``stats``
    collector so tables that migrate between classes with the same target
    size keep their memoized observations."""
    target = profile.target_file_mb * MB
    w1 = profile.benefit_weight
    return AutoCompPipeline(
        stats=stats if stats is not None
        else StatsCollector(target, activity=activity),
        traits=(FileCountReductionTrait(partition_aware=True),
                FileEntropyTrait(), ComputeCostTrait()),
        trait_ctx=TraitContext(target_file_bytes=target,
                               executor_memory_gb=executor_memory_gb,
                               rewrite_bytes_per_hour=rewrite_bytes_per_hour),
        ranker=MoopRanker({"file_count_reduction": w1,
                           "compute_cost": 1.0 - w1}),
        scheduler=scheduler if scheduler is not None else Scheduler(target),
        scope=Scope.TABLE,
        hybrid=(profile.scope == "hybrid"),
        pre_filters=(MinSmallFilesFilter(profile.min_small_files),),
        top_k=profile.top_k,
    )


@dataclasses.dataclass
class FleetCycleReport:
    """CycleReport-shaped (duck-typed for AutoCompService) plus the
    fleet-level accounting the bench artifact and the gate read."""
    n_tables: int = 0
    n_candidates: int = 0
    n_delete_candidates: int = 0
    n_selected: int = 0
    n_unpriced: int = 0
    selected_keys: List = dataclasses.field(default_factory=list)
    deferred_keys: List = dataclasses.field(default_factory=list)
    class_counts: Dict[str, int] = dataclasses.field(default_factory=dict)
    budget_gbhr: float = 0.0
    spent_gbhr: float = 0.0              # Σ selected compute_cost
    starved_served: int = 0              # promoted tables served this cycle
    max_skip_cycles: int = 0             # worst aging among fragmented tables
    act: Optional[ActReport] = None
    wall_s: float = 0.0
    # retention accounting (delete candidates only; see core.retention)
    rows_dropped: int = 0
    files_dropped: int = 0               # tier-1 metadata drops (0 bytes)
    retention_bytes_rewritten: int = 0   # tier-2 rewrite-delete bytes
    bytes_reclaimed: int = 0

    @property
    def files_removed(self) -> int:
        return self.act.files_removed if self.act else 0

    @property
    def gbhr(self) -> float:
        return self.act.gbhr if self.act else 0.0


class FleetScheduler:
    """Cross-table decide/act over many per-class pipelines under one
    shared GBHr budget."""

    def __init__(self, catalog: Catalog, budget_gbhr: float,
                 activity=None,
                 profiles: Optional[Dict[str, ClassProfile]] = None,
                 starvation_cycles: int = 5,
                 aging_boost: float = 0.5,
                 query_weight: float = 0.5,
                 benefit_weight: float = 0.7,
                 max_k: Optional[int] = None,
                 classify_fn: Optional[Callable[..., str]] = None,
                 pipeline_factory: Callable = build_class_pipeline,
                 retention: Optional[RetentionQueue] = None) -> None:
        self.catalog = catalog
        self.budget_gbhr = budget_gbhr
        self.activity = activity
        self.retention = retention if retention is not None \
            else RetentionQueue()
        self.profiles = dict(profiles if profiles is not None
                             else DEFAULT_PROFILES)
        self.starvation_cycles = starvation_cycles
        self.aging_boost = aging_boost
        self.query_weight = query_weight
        self.benefit_weight = benefit_weight
        self.max_k = max_k
        self.classify_fn = classify_fn or classify_table
        self.pipeline_factory = pipeline_factory
        # one collector per distinct target size, shared across classes, so
        # a table migrating between same-target classes keeps its memoized
        # observation (class churn must not defeat sub-linear re-observe)
        self._collectors: Dict[int, StatsCollector] = {}
        self.pipelines: Dict[str, AutoCompPipeline] = {
            name: pipeline_factory(p, activity,
                                   stats=self._stats_for(p.target_file_mb))
            for name, p in sorted(self.profiles.items())}
        # aging state: table_id -> consecutive cycles it stayed fragmented
        # (had a surviving candidate) without being served
        self.skip_cycles: Dict[str, int] = {}
        self.max_skip_ever = 0
        self.reports: List[FleetCycleReport] = []

    # ------------------------------------------------------------- classify
    def classify(self, table) -> str:
        if self.activity is None:
            return "steady"
        tid = table.table_id
        return self.classify_fn(self.activity.read_rate(tid),
                                self.activity.write_file_rate(tid),
                                self.activity.burstiness(tid))

    def _stats_for(self, target_file_mb: int) -> StatsCollector:
        target = target_file_mb * MB
        if target not in self._collectors:
            self._collectors[target] = StatsCollector(
                target, activity=self.activity)
        return self._collectors[target]

    # ------------------------------------------------------------- retention
    def submit_retention(self, policy) -> None:
        """Queue a standing ``lst.retention.RetentionPolicy``; every cycle
        routes it and pools a candidate when files currently age out."""
        self.retention.submit(policy)

    def submit_delete(self, op) -> None:
        """Queue a one-shot ``lst.retention.PredicateDelete``; it stays
        pending — surviving deferral and conflicts — until its routed work
        commits on every target table."""
        self.retention.submit(op)

    def set_profile(self, profile: ClassProfile) -> None:
        """Swap a class's policy profile (rebuilds its pipeline around the
        shared collector for the profile's target size)."""
        self.profiles[profile.name] = profile
        self.pipelines[profile.name] = self.pipeline_factory(
            profile, self.activity,
            stats=self._stats_for(profile.target_file_mb))

    def tune_profile(self, name: str,
                     evaluate: Callable[[ClassProfile], float],
                     axes: Optional[Dict[str, Sequence]] = None,
                     max_rounds: int = 4):
        """Hillclimb one class's knobs with ``core.autotune.tune_design``,
        warm-started from the incumbent profile; installs and returns the
        winner."""
        from repro.core.autotune import tune_design
        base = self.profiles[name]
        axes = axes if axes is not None else base.axes()
        start = {a: getattr(base, a) for a in axes}

        def ev(point: Dict[str, object]) -> float:
            return evaluate(dataclasses.replace(base, **point))

        res = tune_design(ev, axes, start=start, max_rounds=max_rounds)
        best = dataclasses.replace(base, **res.best_point)
        self.set_profile(best)
        return best, res

    # --------------------------------------------------------------- decide
    def decide(self, pool: Sequence[Candidate]
               ) -> Tuple[List[Candidate], List[Candidate], List[Candidate]]:
        """Fleet-level ranking + budget selection over the pooled
        candidates. Returns (ranked, selected, unpriced). Pure given the
        pool and aging state; input order never matters (NFR2)."""
        pool = sorted(pool, key=lambda c: c.key)
        minmax_normalize(pool, list(FLEET_NORM_TRAITS))
        qf = [c.stats.custom.get("query_freq", 0.0) if c.stats else 0.0
              for c in pool]
        lo, hi = (min(qf), max(qf)) if qf else (0.0, 0.0)
        span = hi - lo
        n_starve = max(1, self.starvation_cycles)
        for c, q in zip(pool, qf):
            qn = 0.0 if span <= 0 else (q - lo) / span
            benefit = pooled_benefit(c) * (1.0 + self.query_weight * qn)
            skip = self.skip_cycles.get(c.table.table_id, 0)
            c.score = (self.benefit_weight * benefit
                       - (1.0 - self.benefit_weight)
                       * c.normalized.get("compute_cost", 0.0)
                       + self.aging_boost * min(skip, n_starve) / n_starve)

        def starved_rank(c: Candidate) -> int:
            skip = self.skip_cycles.get(c.table.table_id, 0)
            return skip if skip >= self.starvation_cycles else 0

        ranked = sorted(pool,
                        key=lambda c: (-starved_rank(c), -c.score) + c.key)
        unpriced: List[Candidate] = []
        selected = select_budget(ranked, self.budget_gbhr,
                                 max_k=self.max_k, unpriced=unpriced)
        return ranked, selected, unpriced

    # ------------------------------------------------------------ run_cycle
    def run_cycle(self, catalog: Optional[Catalog] = None,
                  tables: Optional[Sequence] = None) -> FleetCycleReport:
        t0 = time.perf_counter()
        catalog = catalog if catalog is not None else self.catalog
        explicit = tables is not None
        tables = list(tables if explicit else catalog.tables())
        if explicit and self.retention.has_pending():
            # an after_write cycle only sees dirty tables; retention work on
            # quiet tables must still enter the pool (a compliance delete
            # can't wait for someone to write to the table)
            have = {t.table_id for t in tables}
            tables += [t for t in self.retention.target_tables(catalog)
                       if t.table_id not in have]
        rep = FleetCycleReport(n_tables=len(tables),
                               budget_gbhr=self.budget_gbhr)

        # classify + propose per class
        groups: Dict[str, List] = {}
        for t in sorted(tables, key=lambda t: t.table_id):
            groups.setdefault(self.classify(t), []).append(t)
        pool: List[Candidate] = []
        for cls in sorted(groups):
            pipe = self.pipelines[cls]
            cands = pipe.propose(catalog, tables=groups[cls])
            cap = self.profiles[cls].top_k
            if cap is not None:
                cands = cands[:cap]
            for c in cands:
                c.fleet_class = cls        # type: ignore[attr-defined]
            pool.extend(cands)
            rep.class_counts[cls] = len(groups[cls])
        # pending delete ops enter the same pool (priced, see core.retention)
        cls_of = {t.table_id: cls
                  for cls, ts in groups.items() for t in ts}
        del_cands = self.retention.propose(tables, activity=self.activity)
        for c in del_cands:
            c.fleet_class = cls_of.get(  # type: ignore[attr-defined]
                c.table.table_id, "steady")
        pool.extend(del_cands)
        rep.n_delete_candidates = len(del_cands)
        rep.n_candidates = len(pool)

        # fleet decide
        _, selected, unpriced = self.decide(pool)
        rep.n_selected = len(selected)
        rep.n_unpriced = len(unpriced)
        rep.selected_keys = [c.key for c in selected]
        rep.spent_gbhr = sum(c.traits.get("compute_cost", 0.0)
                             for c in selected)

        # fleet act: dispatch per class through that class's scheduler
        act = ActReport()
        by_class: Dict[str, List[Candidate]] = {}
        for c in selected:
            by_class.setdefault(c.fleet_class, []).append(c)  # type: ignore
        for cls in sorted(by_class):
            sub = self.pipelines[cls].act.execute(by_class[cls])
            act.results.extend(sub.results)
            act.deferred.extend(sub.deferred)
        rep.act = act
        rep.deferred_keys = [c.key for c in act.deferred]

        # retention accounting + one-shot completion (deferred deletes stay
        # pending in the queue and re-enter next cycle's pool)
        deferred_ids = {id(c) for c in act.deferred}
        for c in selected:
            if c.delete_route is None or id(c) in deferred_ids:
                continue
            results = getattr(c, "delete_results", [])
            rep.rows_dropped += sum(r.rows_dropped for r in results)
            rep.files_dropped += sum(
                r.files_removed for r in results
                if r.files_added == 0 and r.bytes_rewritten == 0)
            rep.retention_bytes_rewritten += sum(
                r.bytes_rewritten for r in results)
            rep.bytes_reclaimed += sum(r.bytes_reclaimed for r in results)
            self.retention.note_executed(c)

        # aging: fragmented-but-unserved tables age; served tables reset.
        # Deferred candidates were selected but NOT executed — they still
        # count as unserved so the window closure can't mask starvation.
        deferred_tables = {c.table.table_id for c in act.deferred}
        served = {c.table.table_id for c in selected} - deferred_tables
        fragmented = {c.table.table_id for c in pool}
        rep.starved_served = sum(
            1 for tid in served
            if self.skip_cycles.get(tid, 0) >= self.starvation_cycles)
        for tid in fragmented:
            if tid in served:
                self.skip_cycles.pop(tid, None)
            else:
                self.skip_cycles[tid] = self.skip_cycles.get(tid, 0) + 1
        for tid in list(self.skip_cycles):
            if tid not in fragmented:      # healed without compaction
                del self.skip_cycles[tid]
        rep.max_skip_cycles = max(self.skip_cycles.values(), default=0)
        self.max_skip_ever = max(self.max_skip_ever, rep.max_skip_cycles)

        rep.wall_s = time.perf_counter() - t0
        self.reports.append(rep)
        return rep

    # ------------------------------------------------------------ telemetry
    def totals(self) -> Dict[str, float]:
        return {
            "cycles": len(self.reports),
            "files_removed": sum(r.files_removed for r in self.reports),
            "gbhr": sum(r.gbhr for r in self.reports),
            "spent_gbhr": sum(r.spent_gbhr for r in self.reports),
            "max_skip_cycles": self.max_skip_ever,
            "deferred": sum(len(r.deferred_keys) for r in self.reports),
            "unpriced": sum(r.n_unpriced for r in self.reports),
            "rows_dropped": sum(r.rows_dropped for r in self.reports),
            "files_dropped": sum(r.files_dropped for r in self.reports),
            "retention_bytes_rewritten": sum(
                r.retention_bytes_rewritten for r in self.reports),
            "bytes_reclaimed": sum(r.bytes_reclaimed for r in self.reports),
        }
