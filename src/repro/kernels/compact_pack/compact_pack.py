"""Token-run compaction kernels — the AutoComp rewrite inner loop on TPU.

Hardware adaptation (DESIGN.md §2): the Spark executor's file-rewrite loop
(read many small fragments, emit few target-size files) becomes a
scalar-prefetched DMA gather. Token shards are written 128x8-aligned
(CHUNK = 1024 tokens = an (8, 128) int32 VMEM tile), so compacting F
fragments into dense output blocks is a *permutation of aligned chunks*:
no compute, pure data movement — exactly what the TPU DMA engine does well.

Two kernels:

``compact_chunks_kernel`` — the plain gather. The chunk index map rides in
scalar-prefetch SMEM (PrefetchScalarGridSpec); the BlockSpec index_map
dereferences it, so the Pallas pipeline issues the HBM->VMEM->HBM copies
with double buffering. The kernel body is a single VMEM tile copy. The
DMA granularity is tunable: when the plan is runs of consecutive chunks
(fragments usually are), the wrapper coarsens ``block_chunks`` chunks into
one block — fewer, larger copies, the data-movement knob the LSM
compaction design-space work (arXiv:2202.04522) identifies as dominant.

``compact_filter_kernel`` — the fused filter+pack variant (rewrite-deletes
as compaction: a rewrite that drops rows IS a compaction with a filter).
Filtering happens at 128-token row granularity in ONE pass: the grid walks
the *touched* source chunks in plan order (fully-dropped chunks are never
DMA'd), each kept row is scattered into a 16-row staging window at a
host-precomputed destination slot (scalar-prefetched, derived from the
per-chunk keep counts' prefix sums), and a carry tile in VMEM scratch
holds the <8 rows that straddle an output-chunk boundary. Dropped rows
never round-trip through VMEM twice — the unfused path writes every row
then re-reads all of them to filter.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import CompilerParams as _CompilerParams

CHUNK_ROWS = 8
CHUNK_COLS = 128
CHUNK_TOKENS = CHUNK_ROWS * CHUNK_COLS  # 1024

# destination-slot sentinel for dropped rows: never matches the 16-slot
# staging window iota, so the scatter contributes exact zeros
DROP_SLOT = 127


def _copy_kernel(idx_ref, src_ref, out_ref):
    del idx_ref  # consumed by the BlockSpec index maps
    out_ref[...] = src_ref[...]


def compact_chunks_kernel(src: jnp.ndarray, chunk_map: jnp.ndarray,
                          interpret: bool = False) -> jnp.ndarray:
    """Gather blocks of ``src`` according to ``chunk_map``.

    src: (n_src_blocks, rows, CHUNK_COLS) any dtype — ``rows`` is
        CHUNK_ROWS for the plain per-chunk gather, or a multiple of it
        when the wrapper coarsened the plan (block_chunks > 1)
    chunk_map: (n_out_blocks,) int32 -- source block id per output block
    returns (n_out_blocks, rows, CHUNK_COLS)
    """
    n_out = chunk_map.shape[0]
    rows = src.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_out,),
        in_specs=[
            pl.BlockSpec((1, rows, CHUNK_COLS),
                         lambda i, idx_ref: (idx_ref[i], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, rows, CHUNK_COLS),
                               lambda i, idx_ref: (i, 0, 0)),
    )
    return pl.pallas_call(
        _copy_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(
            (n_out, rows, CHUNK_COLS), src.dtype),
        interpret=interpret,
    )(chunk_map, src)


def _filter_kernel(chunk_sel_ref, dest_ref, completed_ref, out_idx_ref,
                   src_ref, out_ref, carry_ref):
    """One touched source chunk per step, sequential grid.

    The staging window W is 16 rows: slots 0..7 are the output chunk
    currently being assembled, 8..15 spill into the carry. Row j of the
    loaded tile goes to slot dest[8*i + j] (host-precomputed from the
    keep-count prefix sums; DROP_SLOT for dropped rows, which therefore
    contribute exact zeros and never reach the output). When this step
    completes an output chunk (completed[i]), W[:8] is final for out block
    out_idx[i] and W[8:] shifts down into the carry; otherwise everything
    still lives in W[:8] and carries forward. o_ref is written every step
    — Pallas flushes the block when out_idx advances, so the last write
    at each index wins, and the final partial chunk flushes at grid end
    zero-padded (the carry invariant keeps slots >= the fill level zero).
    """
    del chunk_sel_ref, out_idx_ref      # consumed by the BlockSpec maps
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    tile = src_ref[0]                                   # (8, 128)
    window = jnp.concatenate(
        [carry_ref[...], jnp.zeros_like(carry_ref)], axis=0)   # (16, 128)
    slot_iota = jax.lax.broadcasted_iota(
        jnp.int32, (2 * CHUNK_ROWS, 1), 0)
    for j in range(CHUNK_ROWS):
        dest = dest_ref[i * CHUNK_ROWS + j]
        row = tile[j:j + 1, :]                          # (1, 128)
        window = window + jnp.where(slot_iota == dest,
                                    jnp.broadcast_to(row, window.shape),
                                    jnp.zeros_like(window))
    out_ref[0] = window[:CHUNK_ROWS].astype(out_ref.dtype)
    carry_ref[...] = jnp.where(completed_ref[i] > 0,
                               window[CHUNK_ROWS:], window[:CHUNK_ROWS])


def compact_filter_kernel(src: jnp.ndarray, chunk_sel: jnp.ndarray,
                          dest: jnp.ndarray, completed: jnp.ndarray,
                          out_idx: jnp.ndarray, n_out: int,
                          interpret: bool = False) -> jnp.ndarray:
    """Fused filter+pack over touched chunks (see ``_filter_kernel``).

    src: (n_src_chunks, CHUNK_ROWS, CHUNK_COLS)
    chunk_sel: (n_touched,) int32 -- source chunk per grid step, plan order
    dest: (n_touched * CHUNK_ROWS,) int32 -- staging slot per source row
        (0..15, or DROP_SLOT for dropped rows)
    completed: (n_touched,) int32 -- 1 iff this step completes an output
        chunk (the step's kept rows cross an 8-row boundary)
    out_idx: (n_touched,) int32 -- output chunk being assembled at step i
    returns (n_out, CHUNK_ROWS, CHUNK_COLS), final chunk zero-padded
    """
    n_steps = chunk_sel.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(n_steps,),
        in_specs=[
            pl.BlockSpec((1, CHUNK_ROWS, CHUNK_COLS),
                         lambda i, cs, d, cf, oi: (cs[i], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, CHUNK_ROWS, CHUNK_COLS),
                               lambda i, cs, d, cf, oi: (oi[i], 0, 0)),
        scratch_shapes=[pltpu.VMEM((CHUNK_ROWS, CHUNK_COLS), src.dtype)],
    )
    return pl.pallas_call(
        _filter_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(
            (n_out, CHUNK_ROWS, CHUNK_COLS), src.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",)),   # carry crosses steps
        interpret=interpret,
    )(chunk_sel, dest, completed, out_idx, src)
