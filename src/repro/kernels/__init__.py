"""Pallas TPU kernels for the perf-critical hot spots.

Each kernel subpackage ships three modules:
  <name>.py -- pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target)
  ops.py    -- jit'd public wrapper (auto interpret-mode on CPU)
  ref.py    -- pure-jnp oracle used by the allclose test sweeps

Kernels:
  compact_pack -- chunk-aligned token-run compaction (the AutoComp rewrite
                  inner loop adapted to TPU: scalar-prefetched DMA gather)
  flash_attn   -- causal GQA flash attention (training/prefill)
  decode_attn  -- flash-decode over a KV cache (single-token serving)
  rmsnorm      -- fused RMSNorm
"""

from jax.experimental.pallas import tpu as _pltpu

# renamed TPUCompilerParams -> CompilerParams across jax versions; kernels
# import this single shim instead of guarding per-module
CompilerParams = getattr(_pltpu, "CompilerParams", None) \
    or getattr(_pltpu, "TPUCompilerParams")
