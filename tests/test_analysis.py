"""Roofline instrumentation: jaxpr FLOP counting (exact on known programs,
scan-trip-count aware) and HLO collective parsing with loop multipliers."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import analysis


class TestJaxprCost:
    def test_matmul_flops_exact(self):
        def f(a, b):
            return a @ b

        a = jax.ShapeDtypeStruct((8, 16), jnp.float32)
        b = jax.ShapeDtypeStruct((16, 32), jnp.float32)
        c = analysis.jaxpr_cost(f, a, b)
        assert c["dot_flops"] == 2 * 8 * 16 * 32

    def test_scan_multiplies_trip_count(self):
        def f(x, w):
            def body(carry, _):
                return carry @ w, None
            out, _ = jax.lax.scan(body, x, None, length=7)
            return out

        x = jax.ShapeDtypeStruct((4, 4), jnp.float32)
        w = jax.ShapeDtypeStruct((4, 4), jnp.float32)
        c = analysis.jaxpr_cost(f, x, w)
        assert c["dot_flops"] == 7 * 2 * 4 * 4 * 4

    def test_nested_scan(self):
        def f(x, w):
            def inner(c, _):
                return c @ w, None

            def outer(c, _):
                c2, _ = jax.lax.scan(inner, c, None, length=3)
                return c2, None

            out, _ = jax.lax.scan(outer, x, None, length=5)
            return out

        x = jax.ShapeDtypeStruct((2, 2), jnp.float32)
        w = jax.ShapeDtypeStruct((2, 2), jnp.float32)
        c = analysis.jaxpr_cost(f, x, w)
        assert c["dot_flops"] == 15 * 2 * 2 * 2 * 2

    def test_grad_counts_backward_flops(self):
        def loss(w, x):
            return jnp.sum((x @ w) ** 2)

        g = jax.grad(loss)
        w = jax.ShapeDtypeStruct((8, 8), jnp.float32)
        x = jax.ShapeDtypeStruct((4, 8), jnp.float32)
        fwd = analysis.jaxpr_cost(loss, w, x)["dot_flops"]
        bwd = analysis.jaxpr_cost(g, w, x)["dot_flops"]
        assert bwd >= 2 * fwd   # grad ~= fwd + 2 transposed matmuls

    def test_hbm_bytes_counts_dot_operands(self):
        def f(a, b):
            return a @ b

        a = jax.ShapeDtypeStruct((8, 16), jnp.bfloat16)
        b = jax.ShapeDtypeStruct((16, 32), jnp.bfloat16)
        c = analysis.jaxpr_cost(f, a, b)
        assert c["hbm_bytes"] == (8 * 16 + 16 * 32 + 8 * 32) * 2


SYNTH_HLO = """\
HloModule test

%region_body (p: (s32[], f32[8])) -> (s32[], f32[8]) {
  %ar = f32[8]{0} all-reduce(%x), replica_groups=[2,4]<=[8], to_apply=%sum
  ROOT %t = (s32[], f32[8]) tuple(%i, %ar)
}

%region_cond (p: (s32[], f32[8])) -> pred[] {
  %c = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

ENTRY %main (a: f32[8]) -> f32[8] {
  %ag = f32[32]{0} all-gather(%a), replica_groups=[2,4]<=[8], dimensions={0}
  %w = (s32[], f32[8]) while(%init), condition=%region_cond, body=%region_body
  ROOT %out = f32[8] get-tuple-element(%w), index=1
}
"""


class TestHloCollectives:
    def test_loop_trip_count_multiplies(self):
        agg = analysis.hlo_collective_bytes(SYNTH_HLO)
        assert agg["all-gather"]["count"] == 1
        assert agg["all-gather"]["bytes"] == 32 * 4
        # the while body runs 5 times
        assert agg["all-reduce"]["count"] == 5
        assert agg["all-reduce"]["bytes"] == 5 * 8 * 4

    def test_bf16_equiv_halves_f32(self):
        agg = analysis.hlo_collective_bytes(SYNTH_HLO)
        assert agg["total_bytes_bf16eq"] == agg["total_bytes"] / 2

    def test_top_collectives_view(self):
        rows = analysis.top_collectives(SYNTH_HLO, 5)
        assert rows
        assert rows[0]["bytes"] >= rows[-1]["bytes"]


S8_HLO = """\
HloModule int8exchange

ENTRY %main (a: s8[1,256]) -> s8[4,256] {
  %a2a = s8[4,256]{1,0} all-to-all(%chunks), replica_groups={{0,2,4,6},{1,3,5,7}}, dimensions={0}
  %ar = bf16[1024]{0} all-reduce(%g), replica_groups={{0,2,4,6},{1,3,5,7}}, to_apply=%sum
  ROOT %ag = s8[4,256]{1,0} all-gather(%a), replica_groups={{0,2,4,6},{1,3,5,7}}, dimensions={0}
}
"""


class TestWireBytes:
    """Ring wire model: all-reduce moves 2(g-1)/g of the payload, gather /
    all-to-all move (g-1)/g — this is what roofline collective_s now uses,
    and what makes the int8-vs-bf16 transport comparison honest."""

    def test_all_reduce_wire_is_2x_ring_fraction(self):
        agg = analysis.hlo_collective_bytes(SYNTH_HLO)
        # replica_groups=[2,4]<=[8]: group size 4 -> 2 * 3/4 of 32 bytes
        assert agg["all-reduce"]["wire_bytes"] == 5 * int(2 * 0.75 * 32)
        assert agg["all-gather"]["wire_bytes"] == int(0.75 * 32 * 4)
        assert agg["total_wire_bytes"] == (
            agg["all-reduce"]["wire_bytes"] + agg["all-gather"]["wire_bytes"])

    def test_list_form_replica_groups_and_s8_payloads(self):
        agg = analysis.hlo_collective_bytes(S8_HLO)
        # group size 4 from {{0,2,4,6},...}; s8 counts 1 byte/element
        assert agg["all-to-all"]["bytes"] == 4 * 256
        assert agg["all-to-all"]["wire_bytes"] == int(0.75 * 4 * 256)
        assert agg["all-gather"]["bytes"] == 4 * 256
        # bf16 payloads are already network dtype: eq == raw
        assert agg["all-reduce"]["wire_bytes_bf16eq"] == \
            agg["all-reduce"]["wire_bytes"] == int(2 * 0.75 * 1024 * 2)

    def test_int8_exchange_beats_bf16_all_reduce_per_element(self):
        """The core trade the transport exploits: for the same element count
        (1024), a2a+gather of s8 moves less wire than a bf16 all-reduce."""
        agg = analysis.hlo_collective_bytes(S8_HLO)
        int8_wire = (agg["all-to-all"]["wire_bytes"]
                     + agg["all-gather"]["wire_bytes"])
        assert int8_wire < agg["all-reduce"]["wire_bytes"]


class TestModelFlops:
    def test_train_formula(self):
        from repro.configs import get_config
        from repro.configs.shapes import SHAPES
        cfg = get_config("granite-3-8b")
        mf = analysis.model_flops(cfg, SHAPES["train_4k"])
        assert mf == pytest.approx(6 * cfg.param_count() * 256 * 4096)

    def test_moe_uses_active_params(self):
        from repro.configs import get_config
        from repro.configs.shapes import SHAPES
        cfg = get_config("qwen3-moe-235b-a22b")
        mf = analysis.model_flops(cfg, SHAPES["train_4k"])
        assert mf < 6 * cfg.param_count() * 256 * 4096 / 5
