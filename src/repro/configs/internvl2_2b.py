"""InternVL2-2B [arXiv:2404.16821; vlm — InternViT + InternLM2 backbone].

24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553.
This entry specifies the transformer BACKBONE (InternLM2-1.8B); the InternViT
frontend is a STUB: input_specs() provides precomputed patch embeddings that
occupy the first n_vision_tokens positions of the sequence.
"""
from repro.configs import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab=92553,
    head_dim=128,
    frontend="vit_patches",
    n_vision_tokens=256,
    rope_theta=1e6,
)
