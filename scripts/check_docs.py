#!/usr/bin/env python
"""Docs lint, run in tier-1 CI (scripts/ci.sh).

Two checks keep the documentation spine from rotting:

  1. every package under ``src/repro/`` (a directory with ``__init__.py``)
     has a ``README.md``;
  2. every RELATIVE markdown link in ``README.md`` and any
     ``src/**/README.md`` resolves to an existing file or directory
     (external http(s)/mailto links and pure #anchors are not checked).

Exit 0 when clean; exit 1 with one line per problem.
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def repo_root() -> Path:
    return Path(__file__).resolve().parent.parent


def find_packages(root: Path) -> list[Path]:
    src = root / "src" / "repro"
    return sorted(p for p in src.iterdir()
                  if p.is_dir() and (p / "__init__.py").exists())


def missing_readmes(root: Path) -> list[str]:
    return [f"package {p.relative_to(root)} has no README.md"
            for p in find_packages(root) if not (p / "README.md").exists()]


def doc_files(root: Path) -> list[Path]:
    docs = []
    if (root / "README.md").exists():
        docs.append(root / "README.md")
    docs += sorted((root / "src").rglob("README.md"))
    return docs


def broken_links(root: Path) -> list[str]:
    problems = []
    for doc in doc_files(root):
        text = doc.read_text(encoding="utf-8")
        for m in LINK_RE.finditer(text):
            target = m.group(1)
            if target.startswith(SKIP_PREFIXES):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            resolved = (doc.parent / path).resolve()
            if not resolved.exists():
                problems.append(
                    f"{doc.relative_to(root)}: broken link -> {target}")
    return problems


def main() -> int:
    root = repo_root()
    problems = missing_readmes(root) + broken_links(root)
    for p in problems:
        print(f"[check-docs] {p}")
    if problems:
        print(f"[check-docs] FAIL: {len(problems)} problem(s)")
        return 1
    n_docs = len(doc_files(root))
    print(f"[check-docs] OK: {len(find_packages(root))} packages, "
          f"{n_docs} README(s), all links resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
