"""Manifest-sharded checkpoints on the LST object store.

Every pytree leaf is written as its own object under ``ckpt/step-N/`` and a
manifest records (path, shape, dtype, treedef). This is exactly the
many-small-objects pattern the paper targets: a 94-layer model has hundreds
of tiny norm/gate leaves per save. The checkpoint prefix is itself an LST
table, so AutoComp can bundle-compact old checkpoints (``bundle_merge_fn``).

Features needed at 1000+-node scale:
  * async save (host thread; the training loop never blocks on the store);
  * atomic publish: the manifest is written last — a crash mid-save leaves
    no visible checkpoint;
  * elastic restore: leaves are re-laid-out to whatever mesh/shardings the
    restoring job passes (device count may differ from the saving job);
  * GC of superseded checkpoints (keep_last).
"""

from __future__ import annotations

import io
import json
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.lst.files import DataFile
from repro.lst.storage import ObjectStore
from repro.lst.table import LogStructuredTable


def _np_dtype(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes  # jax dependency; covers bfloat16/fp8 leaves
        return np.dtype(getattr(ml_dtypes, name))


def _leaf_bytes(arr) -> bytes:
    # raw little-endian bytes; shape/dtype live in the manifest (np.save
    # cannot round-trip ml_dtypes like bfloat16)
    return np.ascontiguousarray(np.asarray(arr)).tobytes()


def _leaf_from_bytes(raw: bytes, shape, dtype_name: str) -> np.ndarray:
    return np.frombuffer(raw, dtype=_np_dtype(dtype_name)).reshape(shape)


class CheckpointManager:
    def __init__(self, store: ObjectStore, prefix: str = "ckpt",
                 keep_last: int = 3,
                 table: Optional[LogStructuredTable] = None) -> None:
        self.store = store
        self.prefix = prefix
        self.keep_last = keep_last
        self.table = table           # optional LST registration for AutoComp
        self._async_thread: Optional[threading.Thread] = None
        self.save_count = 0

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Any, blocking: bool = True) -> None:
        self.wait()                   # one in-flight async save at a time
        with_path, treedef = jax.tree_util.tree_flatten_with_path(tree)
        # keypaths make restore structure-aware: a restoring job whose state
        # tree gained leaves (e.g. the int8_ef transport residual) can match
        # by key instead of position
        keys = [jax.tree_util.keystr(kp) for kp, _ in with_path]
        host_leaves = [np.asarray(l) for _, l in with_path]  # device->host now

        def do_save():
            base = f"{self.prefix}/step-{step:08d}"
            entries = []
            datafiles = []
            for i, (key, arr) in enumerate(zip(keys, host_leaves)):
                path = f"{base}/leaf-{i:05d}.npy"
                raw = _leaf_bytes(arr)
                self.store.put(path, raw)
                entries.append({"path": path, "shape": list(arr.shape),
                                "dtype": str(arr.dtype), "key": key})
                datafiles.append(DataFile(path=path, size_bytes=len(raw),
                                          num_rows=int(arr.size),
                                          partition=f"step-{step:08d}"))
            manifest = {"step": step, "leaves": entries,
                        "treedef": str(treedef)}
            # manifest LAST -> atomic publish
            self.store.put(f"{base}/MANIFEST.json",
                           json.dumps(manifest).encode())
            if self.table is not None:
                self.table.append(datafiles)
            self.save_count += 1
            self._gc()

        if blocking:
            do_save()
        else:
            self._async_thread = threading.Thread(target=do_save, daemon=True)
            self._async_thread.start()

    def wait(self) -> None:
        if self._async_thread is not None:
            self._async_thread.join()
            self._async_thread = None

    # --------------------------------------------------------------- restore
    def available_steps(self) -> List[int]:
        steps = []
        for p in self.store.list(self.prefix + "/"):
            if p.endswith("MANIFEST.json"):
                steps.append(int(p.split("step-")[1].split("/")[0]))
        return sorted(steps)

    def restore(self, tree_like: Any, step: Optional[int] = None,
                shardings: Optional[Any] = None,
                partial_ok: bool = False) -> Tuple[Any, int]:
        """Restore into the structure of ``tree_like``; optionally lay out
        each leaf with ``shardings`` (elastic restore onto any mesh).

        When the manifest carries keypaths (all saves since they were added),
        leaves are matched by key, so ``tree_like`` may have a different leaf
        *order*. With ``partial_ok=True`` leaves of ``tree_like`` that are
        absent from the checkpoint keep their reference value — this is how a
        run that switches ``grad_transport`` to int8_ef restores a pre-switch
        checkpoint: the fresh zero residual in ``opt_state["ef"]`` survives.
        Old keyless manifests fall back to strict positional matching.
        """
        steps = self.available_steps()
        if not steps:
            raise FileNotFoundError("no checkpoints available")
        step = steps[-1] if step is None else step
        base = f"{self.prefix}/step-{step:08d}"
        manifest = json.loads(self.store.get(f"{base}/MANIFEST.json"))
        ents = manifest["leaves"]
        with_path, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
        keyed = all("key" in e for e in ents)
        if keyed:
            by_key = {e["key"]: e for e in ents}
            matched = [(jax.tree_util.keystr(kp), ref,
                        by_key.get(jax.tree_util.keystr(kp)))
                       for kp, ref in with_path]
            missing = [k for k, _, e in matched if e is None]
            tree_keys = {k for k, _, _ in matched}
            extra = [k for k in by_key if k not in tree_keys]
            if (missing or extra) and not partial_ok:
                raise KeyError(
                    f"checkpoint step-{step} / tree mismatch: tree leaves "
                    f"missing from checkpoint {missing[:5]}, checkpoint "
                    f"leaves absent from tree {extra[:5]} (pass "
                    f"partial_ok=True to restore the intersection)")
        else:
            assert len(with_path) == len(ents), \
                f"leaf count mismatch: {len(with_path)} vs {len(ents)}"
            matched = [(jax.tree_util.keystr(kp), ref, ent)
                       for (kp, ref), ent in zip(with_path, ents)]
        out = []
        shard_leaves = None
        if shardings is not None:
            shard_leaves = jax.tree.flatten(shardings)[0]
        for i, (key, ref, ent) in enumerate(matched):
            if ent is None:                    # partial_ok: keep current value
                arr = np.zeros(ref.shape, ref.dtype) \
                    if isinstance(ref, jax.ShapeDtypeStruct) else np.asarray(ref)
            else:
                arr = _leaf_from_bytes(self.store.get(ent["path"]),
                                       ent["shape"], ent["dtype"])
            ref_np = ref if hasattr(ref, "shape") else np.asarray(ref)
            assert tuple(arr.shape) == tuple(ref_np.shape), \
                f"shape mismatch at leaf {key}: {arr.shape} vs {ref_np.shape}"
            if shard_leaves is not None:
                out.append(jax.device_put(arr, shard_leaves[i]))
            else:
                out.append(jax.numpy.asarray(arr, dtype=ref_np.dtype))
        return jax.tree.unflatten(treedef, out), step

    # -------------------------------------------------------------------- gc
    def _gc(self) -> None:
        steps = self.available_steps()
        for s in steps[:-self.keep_last] if self.keep_last else []:
            base = f"{self.prefix}/step-{s:08d}"
            for p in self.store.list(base + "/"):
                self.store.delete(p)


def bundle_merge_fn(table: LogStructuredTable, task, out_path: str) -> DataFile:
    """Checkpoint-bundle compaction: pack many small leaf objects into one
    indexed blob (AutoComp merge_fn for checkpoint tables)."""
    index = {}
    blob = io.BytesIO()
    for f in task.inputs:
        raw = table.store.get(f.path)
        index[f.path] = [blob.tell(), len(raw)]
        blob.write(raw)
    payload = json.dumps(index).encode()
    head = len(payload).to_bytes(8, "little")
    table.store.put(out_path, head + payload + blob.getvalue())
    return DataFile(path=out_path,
                    size_bytes=8 + len(payload) + blob.tell(),
                    num_rows=sum(f.num_rows for f in task.inputs),
                    partition=task.scope, created_at=table.now_fn())
