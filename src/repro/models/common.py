"""Shared modeling primitives: parameter-spec machinery, norms, RoPE,
embeddings, blockwise (memory-efficient) attention, losses.

Parameters are plain pytrees of jnp arrays. Every parameter leaf is declared
through a ``Spec`` carrying its shape, dtype and *logical axis names*; the
dist layer maps logical axes onto mesh axes. Layer stacks are stored with a
leading ``layers`` axis and consumed with ``lax.scan`` (homogeneous stacks)
so HLO size is O(1) in depth.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist import collectives
from repro.dist.sharding import constrain

PyTree = Any

DEFAULT_PARAM_DTYPE = jnp.bfloat16
COMPUTE_DTYPE = jnp.bfloat16


@dataclasses.dataclass(frozen=True)
class Spec:
    """Declaration of one parameter leaf."""
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]   # logical axis names, len == len(shape)
    dtype: Any = None                 # None -> DEFAULT_PARAM_DTYPE
    init: str = "normal"              # "normal" | "zeros" | "ones" | "small"

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def materialize(spec: Spec, key) -> jnp.ndarray:
    dtype = spec.dtype or DEFAULT_PARAM_DTYPE
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    # fan-in scaled normal; last axis treated as fan-out
    fan_in = int(np.prod(spec.shape[:-1])) if len(spec.shape) > 1 else spec.shape[0]
    scale = 0.02 if spec.init == "small" else 1.0 / np.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, spec.shape, jnp.float32) * scale).astype(dtype)


def tree_init(specs: PyTree, key) -> PyTree:
    leaves, treedef = jax.tree.flatten(specs, is_leaf=lambda x: isinstance(x, Spec))
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(treedef, [materialize(s, k) for s, k in zip(leaves, keys)])


def tree_abstract(specs: PyTree) -> PyTree:
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype or DEFAULT_PARAM_DTYPE),
        specs, is_leaf=lambda x: isinstance(x, Spec))


def tree_axes(specs: PyTree) -> PyTree:
    return jax.tree.map(lambda s: s.axes, specs, is_leaf=lambda x: isinstance(x, Spec))


def stack_layer_specs(layer_specs: PyTree, n_layers: int) -> PyTree:
    """Add a leading ``layers`` axis to every leaf spec."""
    return jax.tree.map(
        lambda s: Spec((n_layers,) + s.shape, ("layers",) + s.axes, s.dtype, s.init),
        layer_specs, is_leaf=lambda x: isinstance(x, Spec))


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(dt) * scale.astype(dt)


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                      # (D/2,)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, D/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., : d // 2], x[..., d // 2:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def swiglu(x, w_gate, w_up, w_down):
    hid_axes = (None,) * (x.ndim - 1) + ("mlp",)
    hid_axes = ("batch",) + hid_axes[1:]
    g = constrain(jnp.einsum("...d,df->...f", x, w_gate), *hid_axes)
    u = constrain(jnp.einsum("...d,df->...f", x, w_up), *hid_axes)
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u, w_down)


# ---------------------------------------------------------------------------
# attention (XLA path): blockwise online-softmax, never materializes S x S
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def _attn_block(q, k, v, q_pos, k_pos, causal, window, scale):
    """One (q-block, kv-block) tile. q:(B,bq,H,D) k/v:(B,bk,Hkv,D)."""
    b, bq, h, d = q.shape
    hkv = k.shape[2]
    group = h // hkv
    qg = q.reshape(b, bq, hkv, group, d)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    mask = jnp.ones((bq, k.shape[1]), bool)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window:
        mask &= q_pos[:, None] - k_pos[None, :] < window
    mask &= (k_pos >= 0)[None, :]
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    m = jnp.max(s, axis=-1)                                   # (B,hkv,g,bq)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bhgqd", p, v.astype(jnp.float32))
    return m, l, o


def blockwise_attention(q, k, v, *, causal=True, window=0,
                        q_offset=0, k_positions=None,
                        block_q=1024, block_k=1024):
    """Memory-efficient attention.

    q: (B, Sq, H, D); k,v: (B, Sk, Hkv, D). Returns (B, Sq, H, D).
    ``q_offset``: absolute position of q[0] (for decode/prefill continuation).
    ``k_positions``: optional (Sk,) absolute positions of cache slots
      (ring buffers); -1 marks invalid slots. Defaults to arange(Sk).
    """
    b, sq, h, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    scale = 1.0 / np.sqrt(d)
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    nq, nk = sq // bq, sk // bk
    assert sq % bq == 0 and sk % bk == 0, (sq, bq, sk, bk)
    if k_positions is None:
        k_positions = jnp.arange(sk, dtype=jnp.int32)
    q_pos = q_offset + jnp.arange(sq, dtype=jnp.int32)

    dv = v.shape[-1]
    qb = constrain(q.reshape(b, nq, bq, h, d).transpose(1, 0, 2, 3, 4),
                   None, "batch", None, "heads", None)
    kb = constrain(k.reshape(b, nk, bk, hkv, d).transpose(1, 0, 2, 3, 4),
                   None, "batch", None, "kv_heads", None)
    vb = constrain(v.reshape(b, nk, bk, hkv, dv).transpose(1, 0, 2, 3, 4),
                   None, "batch", None, "kv_heads", None)
    qpb = q_pos.reshape(nq, bq)
    kpb = k_positions.reshape(nk, bk)
    run_axes = ("batch", "kv_heads", None, None)

    def q_step(_, qi):
        qblk, qp = qi
        group = h // hkv

        def kv_step(carry, ki):
            m_run, l_run, o_run = carry
            kblk, vblk, kp = ki
            m, l, o = _attn_block(qblk, kblk, vblk, qp, kp, causal, window, scale)
            m_new = jnp.maximum(m_run, m)
            a_old = jnp.exp(m_run - m_new)
            a_new = jnp.exp(m - m_new)
            l_new = l_run * a_old + l * a_new
            o_new = o_run * a_old[..., None] + o * a_new[..., None]
            return (constrain(m_new, *run_axes), constrain(l_new, *run_axes),
                    constrain(o_new, *run_axes, None)), None

        m0 = constrain(jnp.full((b, hkv, group, bq), NEG_INF, jnp.float32),
                       *run_axes)
        l0 = constrain(jnp.zeros((b, hkv, group, bq), jnp.float32), *run_axes)
        o0 = constrain(jnp.zeros((b, hkv, group, bq, dv), jnp.float32),
                       *run_axes, None)
        (m_f, l_f, o_f), _ = jax.lax.scan(kv_step, (m0, l0, o0), (kb, vb, kpb))
        out = o_f / jnp.maximum(l_f[..., None], 1e-30)
        out = out.transpose(0, 3, 1, 2, 4).reshape(b, bq, h, dv)
        return None, constrain(out.astype(q.dtype), "batch", None, "heads", None)

    if nq == 1:
        _, out = q_step(None, (qb[0], qpb[0]))
        return out
    _, outs = jax.lax.scan(q_step, None, (qb, qpb))
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, dv)


def decode_attention(q, k_cache, v_cache, k_positions, pos,
                     k_scale=None, v_scale=None):
    """Single-token attention against a cache. q:(B,1,H,D), caches (B,S,Hkv,D).

    ``k_positions``: (S,) or per-row (B,S) absolute slot positions (-1
    invalid); ``pos``: scalar or per-row (B,) current position. Per-row
    forms are the continuous-batching case — every request sits at its own
    position and padded/stale slots are masked row-wise.

    ``k_scale``/``v_scale`` (B,S,Hkv,nb) mark an int8-*resident* cache
    (``kv_storage="int8"``): the stored leaves are blockwise-s8 along the
    feature axis and are dequantized here, per block, at read time — HBM
    holds half the bytes and only the attention operands ever exist in
    float. An f8-resident cache (``kv_storage="f8"``, scale-free e4m3)
    arrives without scales and is upcast here the same way — per block on
    the Pallas kernel path, whole-operand under XLA.
    """
    if k_scale is not None:
        k_cache = collectives.dequantize_int8_lastdim(k_cache, k_scale)
        v_cache = collectives.dequantize_int8_lastdim(v_cache, v_scale)
    elif k_cache.dtype == collectives.F8_DTYPE:
        k_cache = collectives.uncast_f8(k_cache)
        v_cache = collectives.uncast_f8(v_cache)
    b, _, h, d = q.shape
    hkv = k_cache.shape[2]
    dv = v_cache.shape[-1]
    group = h // hkv
    scale = 1.0 / np.sqrt(d)
    qg = q.reshape(b, hkv, group, d).astype(jnp.float32)
    s = jnp.einsum("bhgd,bshd->bhgs", qg, k_cache.astype(jnp.float32)) * scale
    pos_b = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    kp = jnp.asarray(k_positions, jnp.int32)
    if kp.ndim == 1:
        kp = kp[None, :]
    valid = (kp >= 0) & (kp <= pos_b[:, None])          # (B or 1, S) -> (B,S)
    valid = jnp.broadcast_to(valid, (b, k_cache.shape[1]))
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    s = constrain(s, "batch", "kv_heads", None, "kv_seq")
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgs,bshd->bhgd", p, v_cache.astype(jnp.float32))
    return o.reshape(b, 1, h, dv).astype(q.dtype)


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray,
                 mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Mean cross-entropy over (optionally masked) positions. fp32 internals."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
