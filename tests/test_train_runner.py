"""Fault tolerance: checkpoint round-trips (incl. bf16), preemption
recovery with loss continuity, straggler detection, elastic restore,
checkpoint-bundle compaction."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ModelConfig
from repro.lst import Catalog, InMemoryStore
from repro.lst import compaction as comp
from repro.lst.workload import SimClock
from repro.models import transformer
from repro.train import optimizer as opt_lib
from repro.train import step as step_lib
from repro.train.checkpoints import CheckpointManager, bundle_merge_fn
from repro.train.runner import (RunnerConfig, SimulatedPreemption, Trainer)

TINY = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=32,
                   n_heads=4, n_kv_heads=2, d_ff=64, vocab=128, head_dim=8,
                   tie_embeddings=True)


def make_setup(steps=30, seed=0):
    cfg = TINY
    params = transformer.init_params(cfg, jax.random.PRNGKey(seed))
    opt = opt_lib.init_state(params)
    step_fn = jax.jit(step_lib.make_train_step(
        cfg, opt_lib.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=steps)))
    rng = np.random.RandomState(seed)
    data = rng.randint(0, cfg.vocab, size=(64, 4, 33)).astype(np.int32)

    def batches():
        for slab in data:
            yield {"tokens": slab[:, :-1], "labels": slab[:, 1:]}

    return cfg, params, opt, step_fn, batches


class TestCheckpoints:
    def test_roundtrip_bf16_and_scalars(self):
        store = InMemoryStore()
        mgr = CheckpointManager(store)
        tree = {"w": jnp.ones((4, 4), jnp.bfloat16) * 1.5,
                "mu": jnp.arange(8, dtype=jnp.float32),
                "step": 7}
        mgr.save(3, tree)
        restored, step = mgr.restore(tree)
        assert step == 3
        assert restored["w"].dtype == jnp.bfloat16
        assert jnp.allclose(restored["w"].astype(jnp.float32), 1.5)
        assert jnp.array_equal(restored["mu"], tree["mu"])
        assert int(restored["step"]) == 7

    def test_async_save_visible_after_wait(self):
        store = InMemoryStore()
        mgr = CheckpointManager(store)
        mgr.save(1, {"a": jnp.zeros(3)}, blocking=False)
        mgr.wait()
        assert mgr.available_steps() == [1]

    def test_gc_keeps_last(self):
        store = InMemoryStore()
        mgr = CheckpointManager(store, keep_last=2)
        for s in range(5):
            mgr.save(s, {"a": jnp.zeros(3)})
        assert mgr.available_steps() == [3, 4]

    def test_manifest_is_atomic_publish(self):
        """No MANIFEST -> checkpoint invisible (crash mid-save is safe)."""
        store = InMemoryStore()
        mgr = CheckpointManager(store)
        mgr.save(1, {"a": jnp.zeros(3)})
        store.delete("ckpt/step-00000001/MANIFEST.json")
        assert mgr.available_steps() == []
        with pytest.raises(FileNotFoundError):
            mgr.restore({"a": jnp.zeros(3)})

    def test_bundle_compaction_of_checkpoint_objects(self):
        """AutoComp can bundle many small checkpoint leaves (storage healing
        for the checkpoint table)."""
        clock = SimClock()
        store = InMemoryStore()
        cat = Catalog(store, now_fn=clock.now)
        table = cat.create_table("ckpt", "registry")
        table.now_fn = clock.now
        mgr = CheckpointManager(store, keep_last=10, table=table)
        mgr.save(1, {"a": jnp.zeros(64), "b": jnp.ones((8, 8))})
        n_before = table.file_count()
        tasks = comp.plan_table(table, target_bytes=1 << 20)
        assert tasks
        for t in tasks:
            r = comp.execute_task(table, t, merge_fn=bundle_merge_fn)
            assert r.success
        assert table.file_count() < n_before


class TestRecovery:
    def test_preemption_restart_continues_from_checkpoint(self):
        cfg, params, opt, step_fn, batches = make_setup()
        store = InMemoryStore()
        mgr = CheckpointManager(store, keep_last=3)
        fired = {"done": False}

        def fault(step):
            if step == 17 and not fired["done"]:
                fired["done"] = True
                raise SimulatedPreemption()

        tr = Trainer(RunnerConfig(total_steps=25, ckpt_every=5),
                     step_fn, params, opt, batches, ckpt=mgr,
                     fault_hook=fault)
        out = tr.run_with_recovery()
        assert tr.restarts == 1
        assert out["final_step"] == 25
        steps_seen = [h["step"] for h in out["history"]]
        assert 15 in steps_seen and steps_seen.count(16) >= 1
        # recovery resumed from step 15 (last ckpt), not from 0
        post = [s for s in steps_seen if steps_seen.count(s) > 1]
        assert 0 not in post

    def test_recovery_without_checkpoint_restarts_from_zero(self):
        cfg, params, opt, step_fn, batches = make_setup()
        fired = {"done": False}

        def fault(step):
            if step == 3 and not fired["done"]:
                fired["done"] = True
                raise SimulatedPreemption()

        tr = Trainer(RunnerConfig(total_steps=6, ckpt_every=100),
                     step_fn, params, opt, batches, ckpt=None,
                     fault_hook=fault)
        out = tr.run_with_recovery()
        assert out["final_step"] == 6

    def test_elastic_restore_into_new_batch_layout(self):
        """Save under one dp layout, restore and continue under another
        (different microbatching) — params/opt are layout-agnostic."""
        cfg, params, opt, step_fn, batches = make_setup()
        store = InMemoryStore()
        mgr = CheckpointManager(store)
        tr = Trainer(RunnerConfig(total_steps=10, ckpt_every=5),
                     step_fn, params, opt, batches, ckpt=mgr)
        tr.run()
        # "rescaled" job: microbatches=2 now
        step_fn2 = jax.jit(step_lib.make_train_step(
            cfg, opt_lib.AdamWConfig(), microbatches=2))
        (p2, o2, s2), step = mgr.restore((params, opt, 0))
        tr2 = Trainer(RunnerConfig(total_steps=12, ckpt_every=100),
                      step_fn2, p2, o2, batches)
        tr2.step = int(np.asarray(s2))
        out = tr2.run()
        assert out["final_step"] == 12


class TestStragglers:
    def test_straggler_detected_and_hook_fires(self):
        cfg, params, opt, step_fn, batches = make_setup()
        seen = []

        def inject(step, dt):
            return 0.5 if step == 20 else 0.0     # +500ms at step 20

        tr = Trainer(RunnerConfig(total_steps=24, straggler_window=8,
                                  straggler_factor=3.0),
                     step_fn, params, opt, batches,
                     straggler_hook=inject,
                     on_straggler=lambda s, dt, med: seen.append(s))
        tr.run()
        assert 20 in tr.stragglers_detected
        assert seen == tr.stragglers_detected
