"""Serving launcher: mesh-placed batched prefill + decode with a sharded
KV cache, quantized activation collectives, and optional prefill/decode
disaggregation.

``python -m repro.launch.serve --arch paper-lm-100m`` runs a batched
generation loop with the reduced smoke config (``--full`` lowers the real
published config instead) on a local mesh built over whatever devices exist
(1 CPU device degrades to a (1, 1) mesh; the CI multidevice job forces 8
host devices and gets a real (data, model) mesh). Params, KV cache, and
batch are explicitly placed: the ``serve_sp`` preset shards the cache over
data (batch dim) x model (sequence dim) and the residual stream over
sequence, and ``--act-transport int8`` runs the sequence-parallel
activation all-gathers as blockwise-int8 chunks + scales
(``repro.dist.collectives.act_gather``).

``--disagg`` splits the pipeline across two meshes — AutoComp's dedicated
compaction cluster, translated to serving: compute-bound prefill runs
sequence-parallel (``serve_sp``) on one half of the devices, decode runs
batch-heavy (``serve_decode``: cache resident, no per-step cache
collectives) on the other half, and the KV cache is handed off between
them once per request batch. ``--cache-transfer int8`` quantizes that
handoff blockwise along the sequence axis (s8 chunks + f32 scales on the
wire); ``--kv-storage int8`` additionally keeps the decode-resident cache
int8 (half the HBM), dequantized per block at attention read time. The two
knobs are orthogonal — 4 combinations, reported per decode dryrun cell
(``repro.launch.dryrun --shape decode``).

Continuous batching: requests at different positions share one decode step
(``prompt_lens`` gives per-row lengths; positions/masks are per-row, so
padded prompt slots are never attended — same semantics the decode_attn
Pallas kernel implements on TPU).
"""

from __future__ import annotations

import argparse
import contextlib
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.dist import collectives
from repro.dist import sharding as shd
from repro.launch.mesh import make_local_mesh
from repro.models import transformer
from repro.train import step as step_lib


def grow_cache(cache, target):
    """Grow every cache leaf to the decode-horizon shape (end-padding).

    ``target`` is the abstract decode cache, so windowed/SSM/xLSTM states
    are handled uniformly: leaves already at the target shape only cast,
    anything smaller pads with zeros at the end of each dimension (new
    slots read as empty and are masked by slot-position validity until
    written).
    """
    def grow(c, tgt):
        if c.shape == tgt.shape:
            return c.astype(tgt.dtype)
        pad = [(0, t - s) for s, t in zip(c.shape, tgt.shape)]
        return jnp.pad(c, pad).astype(tgt.dtype)

    return jax.tree.map(grow, cache, target)


def make_cache_transfer_step(cfg, batch: int, total: int, mode: str):
    """Single-mesh form of the prefill->decode cache handoff.

    Returns ``transfer(cache) -> cache`` that reshards every leaf to the
    layout the active ``axis_rules`` context resolves for its logical
    axes; ``mode="int8"`` routes leaves with a sequence axis through
    ``collectives.stream_int8`` (seq-blockwise s8 chunks + scales on the
    wire), everything else (recurrent state, ``mode="bf16"``) moves raw.
    jit it with in_shardings = the prefill layout and out_shardings = the
    decode layout under ``axis_rules(mesh, serve_decode)`` and the
    compiled HLO is the transfer's wire — what the dryrun and the disagg
    mesh tests measure.
    """
    if mode not in collectives.CACHE_TRANSFERS:
        raise ValueError(f"unknown cache_transfer {mode!r}; "
                         f"expected one of {collectives.CACHE_TRANSFERS}")
    axes = transformer.cache_axes(cfg, batch, total)

    def transfer(cache):
        def move(leaf, la):
            la = tuple(la)
            if mode == "int8" and "kv_seq" in la:
                return collectives.stream_int8(
                    leaf, *la, seq_axis=la.index("kv_seq"))
            return shd.constrain(leaf, *la)
        return jax.tree.map(move, cache, axes)
    return transfer


def _transfer_cache(cfg, cache, batch: int, total: int, dec_mesh, dec_rules,
                    mode: str, dst_shardings):
    """Two-mesh cache handoff: move the committed prefill cache onto the
    decode mesh placement. ``"bf16"`` is a plain ``device_put``;
    ``"int8"`` quantizes each sequence-carrying leaf blockwise along the
    sequence axis *on the prefill mesh*, moves the s8 chunks + f32 scales
    (the only cross-mesh traffic, ~1/4 the bf16 bytes), and dequantizes
    on arrival — AutoComp's compaction-output handoff, as a cache stream.
    """
    if mode == "bf16":
        return jax.device_put(cache, dst_shardings)
    axes = transformer.cache_axes(cfg, batch, total)
    leaves, treedef = jax.tree.flatten(cache)
    axes_l = [tuple(a) for a in treedef.flatten_up_to(axes)]
    dst_l = treedef.flatten_up_to(dst_shardings)
    seq_ix = [la.index("kv_seq") if "kv_seq" in la else None for la in axes_l]
    dtypes = [x.dtype for x in leaves]

    def quant(ls):
        return [x if si is None
                else collectives.quantize_int8_seqaxis(x, si)
                for x, si in zip(ls, seq_ix)]

    q_leaves = jax.jit(quant)(leaves)          # runs on the prefill mesh
    moved = []
    for x, si, la, dst in zip(q_leaves, seq_ix, axes_l, dst_l):
        if si is None:
            moved.append(jax.device_put(x, dst))
            continue
        q, s = x
        q_axes = la[:si] + la[si + 1:] + (la[si],)   # seq-last layout
        q_sh = jax.sharding.NamedSharding(
            dec_mesh, shd.resolve_spec(q.shape, q_axes, dec_mesh, dec_rules))
        s_sh = jax.sharding.NamedSharding(
            dec_mesh, shd.resolve_spec(s.shape, q_axes[:-1] + (None,),
                                       dec_mesh, dec_rules))
        moved.append((jax.device_put(q, q_sh), jax.device_put(s, s_sh)))

    def dequant(ls):
        return treedef.unflatten([
            x if si is None
            else collectives.dequantize_int8_seqaxis(x[0], x[1], si).astype(dt)
            for x, si, dt in zip(ls, seq_ix, dtypes)])

    return jax.jit(dequant, out_shardings=dst_shardings)(moved)


def generate(cfg, params, prompts: np.ndarray, max_new: int = 16,
             temperature: float = 0.0, seed: int = 0,
             prompt_lens: Optional[np.ndarray] = None,
             mesh=None, rules=None, act_transport: str = "bf16",
             decode_mesh=None, decode_rules=None,
             cache_transfer: str = "bf16", kv_storage: str = "bf16"):
    """prompts: (B, S0) int32, right-padded when ragged. Greedy (or
    sampled) decode of ``max_new`` tokens per row.

    ``prompt_lens`` (B,) enables ragged continuous batching: row i's real
    prompt is ``prompts[i, :prompt_lens[i]]``; every row decodes from its
    own position and pad slots are masked (each row's output matches a
    solo run of its unpadded prompt). ``mesh`` places params/cache/batch
    explicitly (``rules`` defaults to the ``serve_sp`` preset);
    ``act_transport`` picks the activation all-gather wire format.

    ``decode_mesh`` disaggregates: prefill compiles on ``mesh`` (its own
    devices, ``rules``), decode on ``decode_mesh`` (``decode_rules``,
    default the batch-heavy ``serve_decode`` preset), and the prefilled
    cache crosses between them once — raw under
    ``cache_transfer="bf16"``, as seq-blockwise s8 chunks + scales under
    ``"int8"``. ``kv_storage="int8"`` keeps the decode-resident cache
    int8 (works colocated too, and even without a mesh).
    """
    b, s0 = prompts.shape
    total = s0 + max_new
    ragged = prompt_lens is not None
    lens = np.asarray(prompt_lens, np.int32) if ragged else None
    if ragged:
        assert lens.shape == (b,) and (lens >= 1).all() and (lens <= s0).all()
        # Ragged masking is only sound for full (slot == position) caches:
        # ring buffers alias a padded position's junk slot to an in-window
        # position before the row overwrites it, and SSM/xLSTM recurrent
        # states scan pad tokens in during prefill — per-row masks cannot
        # undo either. Refuse loudly rather than drift from solo runs.
        if cfg.attn_window or cfg.family in ("hybrid", "ssm_xlstm"):
            raise NotImplementedError(
                f"ragged prompt_lens is unsupported for {cfg.name}: "
                "windowed (ring-buffer) and recurrent-state families need "
                "per-row prefill masking; pad to a uniform length instead")
    if cache_transfer not in collectives.CACHE_TRANSFERS:
        raise ValueError(f"unknown cache_transfer {cache_transfer!r}; "
                         f"expected one of {collectives.CACHE_TRANSFERS}")

    disagg = decode_mesh is not None
    if disagg and mesh is None:
        raise ValueError("disaggregated serving (decode_mesh=...) needs a "
                         "prefill mesh too")
    if mesh is not None and rules is None:
        rules = shd.PRESETS["serve_sp"]
    if disagg and decode_rules is None:
        decode_rules = shd.PRESETS["serve_decode"]
    dec_mesh = decode_mesh if disagg else mesh
    dec_rules = decode_rules if disagg else rules

    prefill_fn = step_lib.make_prefill_step(cfg, act_transport)
    # Under the serve_decode preset the cache is resident — decode has no
    # per-step gather to compress, so an int8 act transport there would
    # only round the whole resident cache through s8 every step (logit
    # drift, extra compute, zero wire saved). Drop to bf16 for the decode
    # half; custom decode_rules keep the caller's choice.
    dec_act = "bf16" if disagg and dec_rules is shd.PRESETS["serve_decode"] \
        else act_transport
    # validates kv_storage (and the family's eligibility for int8)
    decode_fn = step_lib.make_decode_step(cfg, total, dec_act, kv_storage)

    pre_ctx = shd.axis_rules(mesh, rules) if mesh is not None \
        else contextlib.nullcontext()
    dec_ctx = shd.axis_rules(dec_mesh, dec_rules) if dec_mesh is not None \
        else contextlib.nullcontext()

    c_abs_bf16 = transformer.abstract_cache(cfg, b, total)

    with pre_ctx:
        params_pre = params
        if mesh is not None:
            p_shard = shd.tree_shardings(transformer.abstract_params(cfg),
                                         transformer.param_axes(cfg),
                                         mesh, rules)
            params_pre = jax.device_put(params, p_shard)
        prefill = jax.jit(prefill_fn)
        pre_batch = {"tokens": jnp.asarray(prompts)}
        if ragged:
            pre_batch["last_pos"] = jnp.asarray(lens - 1)
        logits, cache = prefill(params_pre, pre_batch)
        cache = grow_cache(cache, c_abs_bf16)

    # ---- handoff: place the grown cache (and params) on the decode side
    with dec_ctx:
        c_shard = None
        params_dec = params_pre
        if dec_mesh is not None:
            c_axes = transformer.cache_axes(cfg, b, total)
            dst = shd.tree_shardings(c_abs_bf16, c_axes, dec_mesh, dec_rules)
            c_shard = dst
            if kv_storage == "int8":
                c_shard = shd.tree_shardings(
                    transformer.abstract_cache(cfg, b, total,
                                               kv_storage="int8"),
                    transformer.cache_axes(cfg, b, total,
                                           kv_storage="int8"),
                    dec_mesh, dec_rules)
            if disagg:
                # the decode cluster holds its own replica of the weights
                p_shard_dec = shd.tree_shardings(
                    transformer.abstract_params(cfg),
                    transformer.param_axes(cfg), dec_mesh, dec_rules)
                params_dec = jax.device_put(params, p_shard_dec)
                cache = _transfer_cache(cfg, cache, b, total, dec_mesh,
                                        dec_rules, cache_transfer, dst)
            else:
                # colocated: commit the grown cache to its serve placement
                cache = jax.device_put(cache, dst)
        if kv_storage == "int8":
            quant = jax.jit(transformer.quantize_cache_int8,
                            out_shardings=c_shard)
            cache = quant(cache)
        decode = jax.jit(decode_fn, out_shardings=(None, c_shard)) \
            if c_shard is not None else jax.jit(decode_fn)

        # first sampled token comes from prefill logits — the one batch
        # tensor that crosses from the prefill to the decode mesh
        key = jax.random.PRNGKey(seed)
        out_tokens = []
        tok = jnp.asarray(np.asarray(jnp.argmax(logits, -1),
                                     dtype=np.int32)[:, None])
        for i in range(max_new):
            out_tokens.append(np.asarray(tok))
            pos = jnp.asarray(lens + i) if ragged \
                else jnp.asarray(s0 + i, jnp.int32)
            logits, cache = decode(params_dec, cache,
                                   {"tokens": tok, "pos": pos})
            if temperature > 0:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(sub, logits / temperature
                                             ).astype(jnp.int32)[:, None]
            else:
                tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    return np.concatenate(out_tokens, axis=1)


def _pick_tp(n_devices: int, cfg) -> int:
    """Largest model-parallel degree (<= 2) the device count and head
    counts admit — the smoke default; override with --tp."""
    for tp in (2, 1):
        if n_devices % tp == 0 and cfg.n_heads % tp == 0:
            return tp
    return 1


def make_disagg_meshes(cfg, tp_prefill: int = 0, tp_decode: int = 0):
    """Split the local devices into a prefill mesh and a decode mesh.

    With >= 2 devices the halves are disjoint — two real clusters, the
    cache handoff is a genuine cross-mesh transfer. A single device serves
    both roles (degenerate (1, 1) meshes), so the smoke path runs
    anywhere. Each half keeps a (data, model) layout; ``tp_*=0``
    auto-picks the model degree per half.
    """
    devs = jax.devices()
    n = len(devs)
    pre, dec = (devs[:n // 2], devs[n // 2:]) if n >= 2 else (devs, devs)

    def mk(ds, tp):
        tp = tp or _pick_tp(len(ds), cfg)
        if len(ds) % tp != 0:
            raise ValueError(
                f"model-parallel degree {tp} does not divide the "
                f"{len(ds)}-device mesh half: disaggregated serving gives "
                f"each role {len(ds)} of the {n} devices, so --tp must "
                f"divide that")
        arr = np.array(ds).reshape(len(ds) // tp, tp)
        return jax.sharding.Mesh(arr, ("data", "model"))
    return mk(pre, tp_prefill), mk(dec, tp_decode)


def disagg_decode_report(cfg, batch: int, seq_len: int, mesh,
                         ici_bw: float = 50e9):
    """Compile the disaggregated-decode design space on one mesh and
    report every cache_transfer x kv_storage combination.

    Per combination ``"<transfer>x<storage>"``: ``transfer_s`` (the
    serve_sp -> serve_decode cache reshard's wire, HLO-parsed from the
    compiled transfer program), ``decode_step_s`` (the decode step's
    per-token wire under the storage arm), their sum ``collective_s``,
    and ``cache_resident_bytes_per_device`` (what the decode mesh's HBM
    actually holds — the storage arm's rent). Storage arms a family does
    not support (recurrent caches) are skipped and named in
    ``"unsupported"``. Used by ``repro.launch.dryrun`` for decode cells
    and exercised directly by the disagg mesh tests.
    """
    from repro.launch import analysis

    pre_rules = shd.PRESETS["serve_sp"]
    dec_rules = shd.PRESETS["serve_decode"]
    c_abs = transformer.abstract_cache(cfg, batch, seq_len)
    c_axes = transformer.cache_axes(cfg, batch, seq_len)
    pre_shard = shd.tree_shardings(c_abs, c_axes, mesh, pre_rules)
    dec_shard = shd.tree_shardings(c_abs, c_axes, mesh, dec_rules)
    p_abs = transformer.abstract_params(cfg)
    p_shard = shd.tree_shardings(p_abs, transformer.param_axes(cfg),
                                 mesh, dec_rules)

    transfers = {}
    for t in collectives.CACHE_TRANSFERS:
        fn = make_cache_transfer_step(cfg, batch, seq_len, t)
        with shd.axis_rules(mesh, dec_rules):
            hlo = jax.jit(fn, in_shardings=(pre_shard,),
                          out_shardings=dec_shard
                          ).lower(c_abs).compile().as_text()
        transfers[t] = analysis.hlo_collective_bytes(hlo)

    def device_bytes(abs_tree, axes_tree):
        tot = 0.0
        for leaf, la in zip(jax.tree.leaves(abs_tree),
                            jax.tree.structure(abs_tree
                                               ).flatten_up_to(axes_tree)):
            spec = shd.resolve_spec(leaf.shape, tuple(la), mesh, dec_rules)
            shards = shd.spec_shard_count(spec, mesh)
            tot += float(np.prod(leaf.shape)) * leaf.dtype.itemsize / shards
        return int(tot)

    decodes, cache_bytes, unsupported = {}, {}, []
    batch_abs = {"tokens": jax.ShapeDtypeStruct((batch, 1), jnp.int32),
                 "pos": jax.ShapeDtypeStruct((), jnp.int32)}
    for s in collectives.KV_STORAGES:
        try:
            fn = step_lib.make_decode_step(cfg, seq_len, "bf16", s)
        except NotImplementedError:
            unsupported.append(s)
            continue
        cs_abs = transformer.abstract_cache(cfg, batch, seq_len,
                                            kv_storage=s)
        cs_axes = transformer.cache_axes(cfg, batch, seq_len, kv_storage=s)
        cs_shard = shd.tree_shardings(cs_abs, cs_axes, mesh, dec_rules)
        with shd.axis_rules(mesh, dec_rules):
            hlo = jax.jit(fn, in_shardings=(p_shard, cs_shard, None),
                          out_shardings=(None, cs_shard)
                          ).lower(p_abs, cs_abs, batch_abs
                                  ).compile().as_text()
        decodes[s] = analysis.hlo_collective_bytes(hlo)
        cache_bytes[s] = device_bytes(cs_abs, cs_axes)

    cells = {}
    for t, tcoll in transfers.items():
        for s, dcoll in decodes.items():
            tw = float(tcoll["total_wire_bytes_bf16eq"])
            dw = float(dcoll["total_wire_bytes_bf16eq"])
            cells[f"{t}x{s}"] = {
                "transfer_s": tw / ici_bw,
                "decode_step_s": dw / ici_bw,
                "collective_s": (tw + dw) / ici_bw,
                "transfer_wire_bytes_bf16eq": int(tw),
                "transfer_wire_bytes_bf16eq_s8":
                    int(tcoll["total_wire_bytes_bf16eq_s8"]),
                "decode_wire_bytes_bf16eq": int(dw),
                "cache_resident_bytes_per_device": cache_bytes[s],
            }
    return {"cells": cells, "unsupported_storage": unsupported}


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b")
    ap.add_argument("--full", action="store_true",
                    help="serve the published config instead of the "
                         "reduced smoke config (the default)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--tp", type=int, default=0,
                    help="model-parallel degree (0 = auto)")
    ap.add_argument("--preset", default="serve_sp",
                    choices=sorted(shd.PRESETS))
    ap.add_argument("--act-transport", default="bf16",
                    choices=list(step_lib.ACT_TRANSPORTS))
    ap.add_argument("--ragged", action="store_true",
                    help="serve a mixed-length batch (continuous batching)")
    ap.add_argument("--disagg", action="store_true",
                    help="disaggregate: prefill and decode on separate "
                         "meshes (half the devices each), the cache handed "
                         "off between them")
    ap.add_argument("--cache-transfer", default="bf16",
                    choices=list(step_lib.CACHE_TRANSFERS),
                    help="wire format of the disagg prefill->decode cache "
                         "handoff")
    ap.add_argument("--kv-storage", default="bf16",
                    choices=list(step_lib.KV_STORAGES),
                    help="decode-resident cache dtype (int8 halves cache "
                         "HBM; attention dequantizes per block at read "
                         "time)")
    return ap


def resolve_config(args):
    """--full lowers the published config; the default is the smoke
    config (same family and code paths, CPU-runnable dims)."""
    return get_config(args.arch) if args.full else smoke_config(args.arch)


def main(argv=None) -> None:
    args = build_parser().parse_args(argv)
    cfg = resolve_config(args)
    if not cfg.supports_decode:
        raise SystemExit(f"{cfg.name} is encoder-only; no decode serving")

    decode_mesh = decode_rules = None
    if args.disagg:
        mesh, decode_mesh = make_disagg_meshes(cfg, args.tp, args.tp)
        rules = shd.PRESETS[args.preset]
        decode_rules = shd.PRESETS["serve_decode"]
    else:
        tp = args.tp or _pick_tp(jax.device_count(), cfg)
        mesh = make_local_mesh(model_parallel=tp)
        rules = shd.PRESETS[args.preset]

    key = jax.random.PRNGKey(0)
    params = transformer.init_params(cfg, key)
    rng = np.random.RandomState(0)
    prompts = rng.randint(0, cfg.vocab,
                          size=(args.batch, args.prompt_len)).astype(np.int32)
    lens = None
    if args.ragged:
        lens = rng.randint(max(1, args.prompt_len // 2), args.prompt_len + 1,
                           size=(args.batch,)).astype(np.int32)

    t0 = time.time()
    out = generate(cfg, params, prompts, max_new=args.max_new,
                   temperature=args.temperature, prompt_lens=lens,
                   mesh=mesh, rules=rules, act_transport=args.act_transport,
                   decode_mesh=decode_mesh, decode_rules=decode_rules,
                   cache_transfer=args.cache_transfer,
                   kv_storage=args.kv_storage)
    dt = time.time() - t0
    n_tok = out.size
    mesh_desc = dict(zip(mesh.axis_names, mesh.devices.shape))
    if decode_mesh is not None:
        mesh_desc = {"prefill": dict(zip(mesh.axis_names,
                                         mesh.devices.shape)),
                     "decode": dict(zip(decode_mesh.axis_names,
                                        decode_mesh.devices.shape))}
    print(f"[serve] arch={cfg.name} batch={args.batch} "
          f"prompt={args.prompt_len} new={args.max_new} "
          f"mesh={mesh_desc} "
          f"preset={args.preset} act_transport={args.act_transport} "
          f"disagg={args.disagg} cache_transfer={args.cache_transfer} "
          f"kv_storage={args.kv_storage}"
          + (f" lens={lens.tolist()}" if lens is not None else ""))
    print(f"[serve] generated {n_tok} tokens in {dt:.2f}s "
          f"({n_tok/dt:.1f} tok/s incl. compile)")
    print("[serve] sample:", out[0][:10])


if __name__ == "__main__":
    main()
