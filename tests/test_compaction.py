"""Compaction execution: binpack partition integrity, conflict retry,
atomic table-scope commits, failure injection, snapshot-expiry healing."""

import pytest

from repro.lst import Catalog, CommitConflict, InMemoryStore
from repro.lst import compaction as comp
from repro.lst.files import DataFile
from repro.lst.workload import SimClock

MB = 1 << 20


def make_table(granularity="table", partition_spec="p"):
    clock = SimClock()
    store = InMemoryStore()
    cat = Catalog(store, now_fn=clock.now)
    t = cat.create_table("ns", "t", partition_spec,
                         properties={"conflict_granularity": granularity})
    t.now_fn = clock.now
    return cat, t, store


def add_files(t, n, size=4 * MB, parts=("a", "b")):
    files = []
    for i in range(n):
        path = f"{t.table_id}/data/f{i}.bin"
        t.store.put(path, b"x" * 128)
        files.append(DataFile(path, size, 10, parts[i % len(parts)]))
    t.append(files)
    return files


class TestPlanning:
    def test_execution_never_crosses_partitions(self):
        _, t, _ = make_table()
        add_files(t, 10)
        tasks = comp.plan_table(t, target_bytes=64 * MB)
        for task in tasks:
            parts = {f.partition for f in task.inputs}
            assert len(parts) == 1

    def test_well_sized_files_not_replanned(self):
        _, t, _ = make_table()
        add_files(t, 6, size=600 * MB)
        assert comp.plan_table(t, target_bytes=512 * MB) == []


class TestExecution:
    def test_atomic_table_scope_single_commit(self):
        _, t, _ = make_table()
        add_files(t, 12)
        v0 = t.version
        tasks = comp.plan_table(t, target_bytes=64 * MB)
        res = comp.execute_tasks_atomic(t, tasks)
        assert res.success
        assert t.version == v0 + 1          # exactly one commit
        assert t.file_count() == len({f.partition
                                      for f in t.current_files()})

    def test_interleaved_write_conflicts_then_retries(self):
        _, t, _ = make_table("table")
        add_files(t, 12)
        injected = {"n": 0}

        def interleave(table, task):
            # two concurrent appends -> stale-metadata threshold crossed
            for j in range(2):
                path = f"{table.table_id}/data/x{injected['n']}-{j}.bin"
                table.store.put(path, b"y")
                table.append([DataFile(path, MB, 1, "a")])
            injected["n"] += 1

        tasks = comp.plan_table(t, target_bytes=64 * MB)
        res = comp.execute_tasks_atomic(t, tasks, interleave_fn=interleave)
        assert res.success
        assert res.conflict and res.retries >= 1   # conflicted, then recovered

    def test_failure_injection_reported_not_raised(self):
        _, t, _ = make_table()
        add_files(t, 8)
        tasks = comp.plan_table(t, target_bytes=64 * MB)
        res = comp.execute_task(t, tasks[0], fail_fn=lambda task: True)
        assert not res.success
        assert res.error == "injected_failure"
        # table unchanged
        assert t.file_count() == 8

    def test_partition_scope_commits_per_partition(self):
        _, t, _ = make_table("partition")
        add_files(t, 12)
        v0 = t.version
        tasks = comp.plan_table(t, target_bytes=64 * MB, scope="partition")
        for task in tasks:
            assert comp.execute_task(t, task).success
        assert t.version - v0 == len(tasks)   # one commit per task

    def test_compaction_then_expiry_frees_objects(self):
        _, t, store = make_table()
        add_files(t, 12)
        for task in comp.plan_table(t, target_bytes=64 * MB):
            assert comp.execute_task(t, task).success
        freed = t.expire_snapshots(keep_last=1)
        assert freed > 0


class TestAtomicAccounting:
    """execute_tasks_atomic must count (and physically delete) only the
    inputs ITS commit replaced — not credit concurrent writers' deletions
    to compaction, and not delete blobs of inputs that were already dead
    at commit time (execute_task's len(live_inputs) semantics)."""

    def test_concurrent_delete_not_credited_to_compaction(self):
        _, t, store = make_table("table")
        files = add_files(t, 12)
        dead = files[0]
        done = {"hit": False}

        def delete_one_input(table, _task):
            if not done["hit"]:
                done["hit"] = True
                table.delete_files([dead])

        tasks = comp.plan_table(t, target_bytes=64 * MB)
        n_inputs = sum(len(task.inputs) for task in tasks)
        assert any(f.path == dead.path
                   for task in tasks for f in task.inputs)
        res = comp.execute_tasks_atomic(t, tasks,
                                        interleave_fn=delete_one_input)
        assert res.success
        # the concurrently-deleted input is NOT compaction's removal...
        assert res.files_removed == n_inputs - 1
        # ...nor compaction's blob to clean: the deleting writer (or
        # snapshot expiry) owns that file's physical lifecycle
        assert store.exists(dead.path)
        # the inputs our commit replaced ARE cleaned up
        for task in tasks:
            for f in task.inputs:
                if f.path != dead.path:
                    assert not store.exists(f.path)

    def test_files_removed_equals_live_inputs(self):
        """No concurrency: every planned input is live, counted, deleted."""
        _, t, _ = make_table()
        add_files(t, 12)
        tasks = comp.plan_table(t, target_bytes=64 * MB)
        res = comp.execute_tasks_atomic(t, tasks)
        assert res.success
        assert res.files_removed == sum(len(task.inputs) for task in tasks)
        assert res.bytes_rewritten == sum(task.input_bytes for task in tasks)
