"""CAB-like synthetic workload generator (§6 "Design of Experimental
Workloads"): query streams modeled after cloud warehouse usage — constant
demand with sinusoidal variation (dashboards), short bursts (interactive),
large bursts (daily maintenance), and predictable hourly jobs — driving
writes into partitioned (LINEITEM-like) and unpartitioned (ORDERS-like)
tables. Deterministic under a seed (NFR2 makes the whole pipeline
reproducible end-to-end).
"""

from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.lst.catalog import Catalog
from repro.lst.files import DataFile
from repro.lst.table import CommitConflict, LogStructuredTable

MB = 1 << 20


class SimClock:
    """Logical time in hours (float)."""

    def __init__(self, start: float = 0.0) -> None:
        self.t = start

    def now(self) -> float:
        return self.t

    def advance(self, hours: float) -> None:
        self.t += hours


@dataclasses.dataclass
class StreamSpec:
    kind: str          # "dashboard" | "interactive" | "maintenance" | "hourly"
    table: str
    namespace: str
    reads_per_hour: float = 4.0
    writes_per_hour: float = 1.0
    files_per_write: Tuple[int, int] = (4, 40)       # min,max small files
    file_size_mb: Tuple[float, float] = (0.5, 32.0)  # lognormal-ish range


@dataclasses.dataclass
class WorkloadSpec:
    n_databases: int = 4
    tables_per_db: int = 4
    partitions_per_table: int = 12        # monthly SHIPDATE granularity
    partitioned_fraction: float = 0.5
    target_file_mb: int = 512
    initial_files_per_table: Tuple[int, int] = (50, 400)
    seed: int = 0


@dataclasses.dataclass
class FleetSpec:
    """High-rate fleet workload (Arc's small-file storm, scaled): thousands
    of tables with a class mix — a storm fraction ingesting tens of small
    files per write at a high write rate (Arc measured ~17k files/day per
    measurement; ``storm_writes_per_hour * mean(storm_files_per_write)``
    sets the scaled-down equivalent), a bursty interactive fraction, a cold
    long tail, and steady dashboard tables for the rest."""
    n_tables: int = 2000
    tables_per_db: int = 50
    storm_fraction: float = 0.15
    bursty_fraction: float = 0.2
    cold_fraction: float = 0.3
    partitioned_fraction: float = 0.5
    partitions_per_table: int = 12
    target_file_mb: int = 512
    initial_files_per_table: Tuple[int, int] = (4, 24)
    storm_files_per_write: Tuple[int, int] = (20, 60)
    storm_writes_per_hour: float = 6.0
    seed: int = 0
    # retention scenario knobs (only read when the bench enables retention):
    # a standing TTL dropping files older than this many sim-hours, and a
    # one-shot GDPR-style predicate delete over every Nth table dropping
    # ~selectivity of its rows
    retention_max_age_hours: float = 2.0
    gdpr_table_stride: int = 7
    gdpr_selectivity: float = 0.05


@dataclasses.dataclass
class QueryEvent:
    t: float
    kind: str            # "read" | "write"
    table_id: str
    latency: float = 0.0
    files_scanned: int = 0
    files_written: int = 0
    conflict: bool = False
    retries: int = 0


class ActivityTracker:
    """Aggregates :class:`QueryEvent` streams into per-table read/write
    rates over a sliding window of logical time — the bridge between the
    workload and the observe phase (``StatsCollector(activity=...)``).

    The fleet scheduler consumes these rates twice: query frequency weights
    compaction benefit (a hot table's small files hurt every read), and the
    write pattern (file rate + burstiness) drives workload classification
    (append-storm / bursty / cold / steady).
    """

    def __init__(self, now_fn, window_hours: float = 24.0) -> None:
        self.now_fn = now_fn
        self.window = window_hours
        # table_id -> list of (t, kind, n_files) pruned to the window
        self._events: Dict[str, List[Tuple[float, str, int]]] = {}

    def record(self, events: Sequence[QueryEvent]) -> None:
        for ev in events:
            self._events.setdefault(ev.table_id, []).append(
                (ev.t, ev.kind, ev.files_written if ev.kind == "write"
                 else ev.files_scanned))
        self._prune()

    def _prune(self) -> None:
        cutoff = self.now_fn() - self.window
        for tid, evs in self._events.items():
            if evs and evs[0][0] < cutoff:
                self._events[tid] = [e for e in evs if e[0] >= cutoff]

    def _span_hours(self, evs: List[Tuple[float, str, int]]) -> float:
        # rate denominator: observed span inside the window, >= 1h so a
        # single fresh event never reads as an infinite rate
        if not evs:
            return 1.0
        return max(1.0, self.now_fn() - min(e[0] for e in evs))

    def read_rate(self, table_id: str) -> float:
        """Reads per hour over the window (the query frequency weight)."""
        evs = self._events.get(table_id, [])
        return sum(1 for e in evs if e[1] == "read") / self._span_hours(evs)

    def write_rate(self, table_id: str) -> float:
        evs = self._events.get(table_id, [])
        return sum(1 for e in evs if e[1] == "write") / self._span_hours(evs)

    def write_file_rate(self, table_id: str) -> float:
        """Small files landed per hour — the append-storm signature."""
        evs = self._events.get(table_id, [])
        return sum(e[2] for e in evs if e[1] == "write") \
            / self._span_hours(evs)

    def burstiness(self, table_id: str) -> float:
        """Peak-to-mean ratio of per-hour write counts (1.0 = steady)."""
        evs = [e for e in self._events.get(table_id, []) if e[1] == "write"]
        if not evs:
            return 0.0
        per_hour: Dict[int, int] = {}
        for t, _, _ in evs:
            per_hour[int(t)] = per_hour.get(int(t), 0) + 1
        span = max(1, int(self._span_hours(evs)))
        mean = len(evs) / span
        return max(per_hour.values()) / mean if mean > 0 else 0.0


class CostModel:
    """Client-visible latency model: planning scales with file count (RPC
    pressure), execution with bytes and per-file open overhead — the
    mechanism behind Fig. 3/Fig. 8."""

    def __init__(self, open_ms: float = 4.0, plan_ms_per_file: float = 0.8,
                 read_gb_per_s: float = 1.0, base_ms: float = 50.0):
        self.open_ms = open_ms
        self.plan_ms_per_file = plan_ms_per_file
        self.read_gb_per_s = read_gb_per_s
        self.base_ms = base_ms

    def read_latency_s(self, files: Sequence[DataFile]) -> float:
        n = len(files)
        byts = sum(f.size_bytes for f in files)
        return (self.base_ms + n * (self.open_ms + self.plan_ms_per_file)
                ) / 1e3 + byts / (self.read_gb_per_s * 1e9)


class WorkloadGenerator:
    def __init__(self, catalog: Catalog, spec: WorkloadSpec,
                 clock: Optional[SimClock] = None,
                 cost: Optional[CostModel] = None) -> None:
        self.catalog = catalog
        self.spec = spec
        self.clock = clock or SimClock()
        self.cost = cost or CostModel()
        self.rng = np.random.RandomState(spec.seed)
        self.streams: List[StreamSpec] = []
        self.events: List[QueryEvent] = []
        self._file_ids = itertools.count(1)

    # -------------------------------------------------------------- setup
    def setup(self) -> None:
        kinds = ["dashboard", "interactive", "maintenance", "hourly"]
        for d in range(self.spec.n_databases):
            ns = f"db{d:02d}"
            self.catalog.create_namespace(ns, total_quota=200_000)
            for t in range(self.spec.tables_per_db):
                partitioned = self.rng.rand() < self.spec.partitioned_fraction
                name = f"table{t:02d}"
                table = self.catalog.create_table(
                    ns, name, "ship_month" if partitioned else None,
                    properties={"conflict_granularity": "table"})
                table.now_fn = self.clock.now
                n0 = self.rng.randint(*self.spec.initial_files_per_table)
                self._append_small_files(table, n0)
                self.streams.append(StreamSpec(
                    kind=kinds[t % len(kinds)], table=name, namespace=ns,
                    reads_per_hour=float(self.rng.randint(2, 12)),
                    writes_per_hour=float(self.rng.randint(1, 6))))

    def setup_fleet(self, fspec: FleetSpec) -> None:
        """Create a fleet of ``n_tables`` with a deterministic class mix.
        Stream kinds: ``append_storm`` (high-rate small-file ingestion),
        ``interactive`` (bursty), ``cold`` (near-idle long tail),
        ``dashboard`` (steady) — the observed write/query patterns the
        fleet scheduler classifies tables by."""
        self.spec = WorkloadSpec(
            n_databases=max(1, -(-fspec.n_tables // fspec.tables_per_db)),
            tables_per_db=fspec.tables_per_db,
            partitions_per_table=fspec.partitions_per_table,
            partitioned_fraction=fspec.partitioned_fraction,
            target_file_mb=fspec.target_file_mb,
            initial_files_per_table=fspec.initial_files_per_table,
            seed=fspec.seed)
        self.rng = np.random.RandomState(fspec.seed)
        n = fspec.n_tables
        n_storm = int(round(n * fspec.storm_fraction))
        n_bursty = int(round(n * fspec.bursty_fraction))
        n_cold = int(round(n * fspec.cold_fraction))
        kinds = (["append_storm"] * n_storm + ["interactive"] * n_bursty
                 + ["cold"] * n_cold)
        kinds += ["dashboard"] * (n - len(kinds))
        self.rng.shuffle(kinds)             # seeded: deterministic mixing
        made = 0
        for d in range(self.spec.n_databases):
            ns = f"db{d:03d}"
            self.catalog.create_namespace(ns, total_quota=500_000)
            for t in range(self.spec.tables_per_db):
                if made >= n:
                    break
                kind = kinds[made]
                partitioned = self.rng.rand() < fspec.partitioned_fraction
                name = f"table{t:03d}"
                table = self.catalog.create_table(
                    ns, name, "ship_month" if partitioned else None,
                    properties={"conflict_granularity": "table"})
                table.now_fn = self.clock.now
                n0 = self.rng.randint(*fspec.initial_files_per_table)
                self._append_small_files(table, n0)
                if kind == "append_storm":
                    st = StreamSpec(kind=kind, table=name, namespace=ns,
                                    reads_per_hour=2.0,
                                    writes_per_hour=fspec.storm_writes_per_hour,
                                    files_per_write=fspec.storm_files_per_write)
                elif kind == "interactive":
                    st = StreamSpec(kind=kind, table=name, namespace=ns,
                                    reads_per_hour=6.0, writes_per_hour=2.0)
                elif kind == "cold":
                    st = StreamSpec(kind=kind, table=name, namespace=ns,
                                    reads_per_hour=0.2, writes_per_hour=0.1,
                                    files_per_write=(1, 4))
                else:
                    st = StreamSpec(kind=kind, table=name, namespace=ns,
                                    reads_per_hour=6.0, writes_per_hour=1.0)
                self.streams.append(st)
                made += 1

    def _rand_partition(self, table: LogStructuredTable) -> Optional[str]:
        if not table.meta.partition_spec:
            return None
        return f"m{self.rng.randint(self.spec.partitions_per_table):02d}"

    def _small_file(self, table: LogStructuredTable,
                    partition: Optional[str]) -> DataFile:
        lo, hi = 0.5, 32.0
        size = float(np.exp(self.rng.uniform(np.log(lo), np.log(hi)))) * MB
        fid = next(self._file_ids)
        path = f"{table.table_id}/data/part-{fid:08d}.parquet"
        table.store.put(path, b"x" * min(int(size) // (1 << 14) + 1, 4096))
        return DataFile(path=path, size_bytes=int(size),
                        num_rows=int(size // 200), partition=partition,
                        created_at=self.clock.now())

    def _append_small_files(self, table: LogStructuredTable, n: int) -> int:
        files = [self._small_file(table, self._rand_partition(table))
                 for _ in range(n)]
        before = table.cas_retries
        table.append(files)
        self.catalog.notify_write(table)
        return table.cas_retries - before

    def _prepare_append(self, table: LogStructuredTable, n: int):
        """Open an append transaction (committed later — concurrent writers
        on the same table then collide on the version CAS, the paper's
        client-side conflicts)."""
        files = [self._small_file(table, self._rand_partition(table))
                 for _ in range(n)]
        return table.new_transaction().append_files(files)

    # -------------------------------------------------------------- phases
    def _intensity(self, stream: StreamSpec, hour: float) -> float:
        if stream.kind == "dashboard":     # sinusoidal constant demand
            return 1.0 + 0.5 * math.sin(2 * math.pi * hour / 24.0)
        if stream.kind == "interactive":   # short random bursts
            return 3.0 if self.rng.rand() < 0.2 else 0.3
        if stream.kind == "maintenance":   # large daily burst around hour 4
            return 6.0 if int(hour) % 24 == 4 else 0.1
        if stream.kind == "append_storm":  # sustained high-rate ingestion
            return 1.0
        if stream.kind == "cold":          # near-idle long tail
            return 1.0
        return 1.0 if abs(hour - round(hour)) < 0.26 else 0.0   # hourly job

    def run_hour(self, substeps: int = 4) -> List[QueryEvent]:
        """Advance one logical hour of mixed reads/writes. Writes within a
        substep run as CONCURRENT transactions (opened first, committed
        together), so same-table writers collide on the version CAS."""
        out: List[QueryEvent] = []
        for _ in range(substeps):
            self.clock.advance(1.0 / substeps)
            pending = []                      # (table, txn, event)
            for st in self.streams:
                table = self.catalog.get_table(st.namespace, st.table)
                inten = self._intensity(st, self.clock.now())
                n_reads = self.rng.poisson(st.reads_per_hour * inten / substeps)
                n_writes = self.rng.poisson(st.writes_per_hour * inten / substeps)
                for _ in range(n_reads):
                    part = self._rand_partition(table)
                    files = table.scan(partition=part)
                    # execute the read: one open() RPC per data file (the
                    # HDFS pressure that Fig. 11b measures)
                    for f in files:
                        if table.store.exists(f.path):
                            table.store.metrics.open_calls += 1
                    ev = QueryEvent(self.clock.now(), "read", table.table_id,
                                    latency=self.cost.read_latency_s(files),
                                    files_scanned=len(files))
                    out.append(ev)
                for _ in range(n_writes):
                    n_files = self.rng.randint(*st.files_per_write)
                    txn = self._prepare_append(table, n_files)
                    ev = QueryEvent(self.clock.now(), "write", table.table_id,
                                    files_written=n_files)
                    pending.append((table, txn, ev))
                    out.append(ev)
            for table, txn, ev in pending:    # concurrent commit wave
                before = table.cas_retries
                txn.commit()
                self.catalog.notify_write(table)
                ev.retries = table.cas_retries - before
                ev.conflict = ev.retries > 0
        self.events.extend(out)
        return out

    # -------------------------------------------------------------- metrics
    def total_file_count(self) -> int:
        return sum(t.file_count() for t in self.catalog.tables())

    def small_file_fraction(self, target_bytes: int) -> float:
        files = [f for t in self.catalog.tables() for f in t.current_files()]
        if not files:
            return 0.0
        return sum(1 for f in files if f.size_bytes < target_bytes) / len(files)
