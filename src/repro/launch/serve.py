"""Serving launcher: batched prefill + decode with a KV cache.

``python -m repro.launch.serve --arch granite-3-8b --smoke`` runs a batched
generation loop on CPU with the reduced config; the full configs lower on
the production mesh via the dry-run. Continuous batching: requests at
different positions share one decode step (ragged lengths are masked —
same semantics the decode_attn Pallas kernel implements on TPU).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.configs.shapes import ShapeSpec
from repro.models import transformer
from repro.train import step as step_lib


def generate(cfg, params, prompts: np.ndarray, max_new: int = 16,
             temperature: float = 0.0, seed: int = 0):
    """prompts: (B, S0) int32. Greedy (or sampled) decode of max_new tokens."""
    b, s0 = prompts.shape
    total = s0 + max_new
    prefill = jax.jit(step_lib.make_prefill_step(cfg))
    decode = jax.jit(step_lib.make_decode_step(cfg, total))

    logits, cache = prefill(params, {"tokens": jnp.asarray(prompts)})
    # grow every cache leaf to the decode-horizon shape (end-padding); the
    # target comes from the abstract decode cache, so windowed/SSM/xLSTM
    # states are handled uniformly
    target = transformer.abstract_cache(cfg, b, total)

    def grow(c, tgt):
        if c.shape == tgt.shape:
            return c.astype(tgt.dtype)
        pad = [(0, t - s) for s, t in zip(c.shape, tgt.shape)]
        return jnp.pad(c, pad).astype(tgt.dtype)

    cache = jax.tree.map(grow, cache, target)

    key = jax.random.PRNGKey(seed)
    out_tokens = []
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    for i in range(max_new):
        out_tokens.append(np.asarray(tok))
        logits, cache = decode(params, cache,
                               {"tokens": tok,
                                "pos": jnp.asarray(s0 + i, jnp.int32)})
        if temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits / temperature
                                         ).astype(jnp.int32)[:, None]
        else:
            tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    return np.concatenate(out_tokens, axis=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if not cfg.supports_decode:
        raise SystemExit(f"{cfg.name} is encoder-only; no decode serving")
    key = jax.random.PRNGKey(0)
    params = transformer.init_params(cfg, key)
    prompts = np.random.RandomState(0).randint(
        0, cfg.vocab, size=(args.batch, args.prompt_len)).astype(np.int32)

    t0 = time.time()
    out = generate(cfg, params, prompts, max_new=args.max_new)
    dt = time.time() - t0
    n_tok = out.size
    print(f"[serve] arch={cfg.name} batch={args.batch} "
          f"prompt={args.prompt_len} new={args.max_new}")
    print(f"[serve] generated {n_tok} tokens in {dt:.2f}s "
          f"({n_tok/dt:.1f} tok/s incl. compile)")
    print("[serve] sample:", out[0][:10])


if __name__ == "__main__":
    main()
