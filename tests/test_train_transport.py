"""Gradient-transport correctness on a single device: the int8_ef transport
(blockwise int8 + error feedback, ``grad_transport="int8_ef"``) converges to
within tolerance of the bf16 baseline on a scaled-down paper_lm_100m
(same family, tied embeddings, GQA 2:1 — only the dims shrink for CPU), and
the per-leaf residual in ``opt_state["ef"]`` round-trips through checkpoint
save/restore, including restore *from a pre-EF checkpoint* via keypath
matching + ``partial_ok``."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.lst import InMemoryStore
from repro.models import transformer
from repro.train import optimizer as opt_lib
from repro.train import step as step_lib
from repro.train.checkpoints import CheckpointManager

# paper-lm-100m with every dim divided down for CPU; aspect ratios intact
CFG = dataclasses.replace(
    get_config("paper-lm-100m"), name="paper-lm-scaled", n_layers=2,
    d_model=256, n_heads=4, n_kv_heads=2, head_dim=64, d_ff=512, vocab=512)

STEPS = 20


def _data(seed=0, n=4, batch=8, seq=32):
    rng = np.random.RandomState(seed)
    slabs = rng.randint(0, CFG.vocab, size=(n, batch, seq + 1)).astype(np.int32)
    return [{"tokens": s[:, :-1], "labels": s[:, 1:]} for s in slabs]


def _train(grad_transport, steps=STEPS, microbatches=2):
    params = transformer.init_params(CFG, jax.random.PRNGKey(0))
    opt = opt_lib.init_state(params,
                             error_feedback=grad_transport == "int8_ef")
    adamw = opt_lib.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=steps)
    step = jax.jit(step_lib.make_train_step(
        CFG, adamw, microbatches=microbatches, grad_transport=grad_transport))
    data = _data()
    losses = []
    for i in range(steps):
        params, opt, metrics = step(params, opt, data[i % len(data)])
        losses.append(float(metrics["loss"]))
    return params, opt, losses


class TestInt8EfConvergence:
    def test_tracks_bf16_baseline(self):
        _, _, l_bf16 = _train("bf16")
        _, opt, l_int8 = _train("int8_ef")
        # both learn ...
        assert l_bf16[-1] < l_bf16[0] - 0.05
        assert l_int8[-1] < l_int8[0] - 0.05
        # ... and the compressed run lands within tolerance of the baseline
        assert abs(l_int8[-1] - l_bf16[-1]) <= 0.05 * abs(l_bf16[-1]), \
            (l_int8[-1], l_bf16[-1])

    def test_residual_is_carried(self):
        _, opt, _ = _train("int8_ef", steps=2)
        ef_l1 = sum(float(jnp.sum(jnp.abs(e)))
                    for e in jax.tree.leaves(opt["ef"]))
        assert ef_l1 > 0.0                     # quantization error accumulated
        # residual leaves mirror the parameter tree
        assert (jax.tree.structure(opt["ef"]) ==
                jax.tree.structure(opt["mu"]))

    def test_missing_ef_state_raises(self):
        params = transformer.init_params(CFG, jax.random.PRNGKey(0))
        opt = opt_lib.init_state(params)       # no error_feedback
        step = step_lib.make_train_step(CFG, opt_lib.AdamWConfig(),
                                        grad_transport="int8_ef")
        with pytest.raises(KeyError):
            step(params, opt, _data()[0])

    def test_unknown_transport_rejected(self):
        with pytest.raises(ValueError):
            step_lib.make_train_step(CFG, opt_lib.AdamWConfig(),
                                     grad_transport="fp4")


class TestEfCheckpointRoundTrip:
    def test_residual_survives_save_restore(self):
        params, opt, _ = _train("int8_ef", steps=3)
        ckpt = CheckpointManager(InMemoryStore(), keep_last=2)
        ckpt.save(3, (params, opt), blocking=True)
        like = (jax.tree.map(jnp.zeros_like, params),
                jax.tree.map(jnp.zeros_like, opt))
        (rp, ro), step = ckpt.restore(like)
        assert step == 3
        for a, b in zip(jax.tree.leaves(opt["ef"]),
                        jax.tree.leaves(ro["ef"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert int(ro["step"]) == int(opt["step"])

    def test_manifest_records_keypaths(self):
        import json
        params, opt, _ = _train("int8_ef", steps=1)
        store = InMemoryStore()
        ckpt = CheckpointManager(store, keep_last=2)
        ckpt.save(1, (params, opt), blocking=True)
        manifest = json.loads(store.get("ckpt/step-00000001/MANIFEST.json"))
        keys = {e["key"] for e in manifest["leaves"]}
        assert any("'ef'" in k for k in keys)
        assert len(keys) == len(manifest["leaves"])   # keypaths are unique

    def test_pre_ef_checkpoint_restores_with_partial_ok(self):
        """Switching grad_transport mid-run: a checkpoint saved without the
        residual restores into EF-bearing state; the fresh residual keeps
        its (zero) value."""
        params, opt, _ = _train("bf16", steps=3)
        ckpt = CheckpointManager(InMemoryStore(), keep_last=2)
        ckpt.save(3, (params, opt), blocking=True)
        like_params = jax.tree.map(jnp.zeros_like, params)
        like_opt = opt_lib.init_state(like_params, error_feedback=True)
        with pytest.raises(KeyError):
            ckpt.restore((like_params, like_opt))
        (rp, ro), _ = ckpt.restore((like_params, like_opt), partial_ok=True)
        assert "ef" in ro
        for e in jax.tree.leaves(ro["ef"]):
            np.testing.assert_array_equal(np.asarray(e), 0.0)
        # restored moments match the saved ones
        for a, b in zip(jax.tree.leaves(opt["mu"]), jax.tree.leaves(ro["mu"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_ef_checkpoint_into_bf16_state_needs_partial_ok(self):
        """The symmetric direction: dropping checkpoint leaves (the saved
        residual) must be an explicit decision, not a silent discard."""
        params, opt, _ = _train("int8_ef", steps=2)
        ckpt = CheckpointManager(InMemoryStore(), keep_last=2)
        ckpt.save(2, (params, opt), blocking=True)
        like = (jax.tree.map(jnp.zeros_like, params),
                opt_lib.init_state(params))          # no "ef"
        with pytest.raises(KeyError):
            ckpt.restore(like)
        (_, ro), _ = ckpt.restore(like, partial_ok=True)
        assert "ef" not in ro
        assert int(ro["step"]) == int(opt["step"])
