"""End-to-end behaviour tests for AutoComp (the paper's system claims)."""

import numpy as np
import pytest

from benchmarks.workload_sim import make_pipeline, run_sim
from repro.core import AutoCompService
from repro.core.service import ServiceConfig
from repro.core.triggers import OptimizeAfterWriteHook
from repro.lst import Catalog, InMemoryStore
from repro.lst.workload import SimClock, WorkloadGenerator, WorkloadSpec

MB = 1 << 20


def small_world(seed=1, hours=1, n_databases=2, tables_per_db=3):
    clock = SimClock()
    store = InMemoryStore()
    catalog = Catalog(store, now_fn=clock.now)
    gen = WorkloadGenerator(catalog, WorkloadSpec(
        n_databases=n_databases, tables_per_db=tables_per_db, seed=seed), clock)
    gen.setup()
    for _ in range(hours):
        gen.run_hour()
    return clock, store, catalog, gen


class TestCompactionEffectiveness:
    def test_file_count_drops_after_cycle(self):
        _, _, catalog, gen = small_world()
        before = gen.total_file_count()
        rep = make_pipeline("table", k=10).run_cycle(catalog)
        assert rep.files_removed > 0
        assert gen.total_file_count() < before

    def test_diminishing_returns_second_cycle(self):
        """§7: once small files are merged, further compaction yields little
        — repeated cycles on an unchanged catalog converge."""
        _, _, catalog, _ = small_world()
        pipe = make_pipeline("table", k=50)
        r1 = pipe.run_cycle(catalog)
        r2 = pipe.run_cycle(catalog)
        assert r2.files_removed <= max(1, r1.files_removed // 10)

    def test_hybrid_scope_selects_partitions(self):
        _, _, catalog, _ = small_world()
        pipe = make_pipeline("hybrid", k=500)
        rep = pipe.run_cycle(catalog)
        scopes = {k[1] for k in rep.selected_keys}
        assert "partition" in scopes  # partitioned tables -> partition cands

    def test_compaction_strategies_reduce_vs_baseline(self):
        base = run_sim("none", hours=2, seed=4)
        comp = run_sim("table-10", hours=2, seed=4)
        assert comp["final_file_count"] < base["final_file_count"]


class TestDeterminism:
    def test_nfr2_same_input_same_decisions(self):
        """NFR2: identical catalog state -> identical selected candidates."""
        reps = []
        for _ in range(2):
            _, _, catalog, _ = small_world(seed=7)
            rep = make_pipeline("table", k=5).run_cycle(catalog)
            reps.append(rep.selected_keys)
        assert reps[0] == reps[1]

    def test_workload_deterministic_under_seed(self):
        a = run_sim("none", hours=1, seed=9)
        b = run_sim("none", hours=1, seed=9)
        assert a["final_file_count"] == b["final_file_count"]
        assert a["duration_s"] == pytest.approx(b["duration_s"])


class TestBudgetAndSelection:
    def test_budget_limits_selection(self):
        unlimited = make_pipeline("table", k=100)
        limited = make_pipeline("table", k=100, budget=1e-4)
        _, _, catalog2, _ = small_world()
        r_unlim = unlimited.run_cycle(catalog2)
        _, _, catalog3, _ = small_world()
        r_lim = limited.run_cycle(catalog3)
        assert r_lim.n_selected <= r_unlim.n_selected
        assert r_lim.gbhr <= 1e-4 + 1e-9


class TestServiceAndTriggers:
    def test_periodic_service_fires_on_interval(self):
        clock, _, catalog, gen = small_world()
        pipe = make_pipeline("table", k=5)
        svc = AutoCompService(catalog, pipe,
                              ServiceConfig(interval_hours=2.0), clock.now)
        fired = 0
        for _ in range(4):
            gen.run_hour()
            if svc.tick() is not None:
                fired += 1
        assert fired == 2     # every 2 of 4 hours
        assert svc.totals()["files_removed"] > 0

    def test_optimize_after_write_hook_marks_dirty(self):
        clock, _, catalog, gen = small_world()
        hook = OptimizeAfterWriteHook(catalog)
        gen.run_hour()
        dirty = hook.drain_dirty()
        assert dirty                      # writes marked tables dirty
        assert not hook.drain_dirty()     # drained

    def test_after_write_mode_only_processes_dirty(self):
        clock, _, catalog, gen = small_world()
        pipe = make_pipeline("table", k=50)
        svc = AutoCompService(catalog, pipe,
                              ServiceConfig(interval_hours=1.0,
                                            mode="after_write"), clock.now)
        gen.run_hour()
        rep = svc.tick()
        assert rep is not None
        dirty_tables = {k[0] for k in rep.selected_keys}
        assert all("/" in t for t in dirty_tables)


class TestStoreMetrics:
    def test_open_calls_drop_with_compaction(self):
        """Fig. 11b: compaction reduces filesystem open() pressure for the
        same logical reads."""
        base = run_sim("none", hours=2, seed=11, interleave=False)
        comp = run_sim("table-10", hours=2, seed=11, interleave=False)
        base_reads = sum(r["reads"] for r in base["hourly"]) or 1
        comp_reads = sum(r["reads"] for r in comp["hourly"]) or 1
        assert (comp["store_metrics"]["open_calls"] / comp_reads
                < base["store_metrics"]["open_calls"] / base_reads)
