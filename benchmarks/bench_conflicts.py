"""Table 1 — client-side and cluster-side write-write conflicts per hour,
for NoComp / Table-10 / Hybrid-500.

Reproduces the paper's qualitative findings: conflicts exist even without
compaction (concurrent writers), table-scope compaction adds cluster-side
conflicts early (stale metadata under Iceberg-v1.2 table-granularity
validation), and the hybrid strategy sees ~none (smaller candidates =>
lower disruption probability)."""

from __future__ import annotations

from typing import List

from benchmarks.workload_sim import run_sim

STRATEGIES = ("none", "table-10", "hybrid-500")


def main(hours: int = 5) -> List[str]:
    rows = []
    for strat in STRATEGIES:
        res = run_sim(strategy=strat, hours=hours, seed=2,
                      profile="write_heavy")
        client = "|".join(str(r["client_conflicts"]) for r in res["hourly"])
        cluster = "|".join(str(r.get("cluster_conflicts", 0))
                           for r in res["hourly"])
        rows.append(f"table1_client_conflicts[{strat}],"
                    f"{sum(r['client_conflicts'] for r in res['hourly'])},"
                    f"hourly={client}")
        rows.append(f"table1_cluster_conflicts[{strat}],"
                    f"{res['cluster_conflicts']},hourly={cluster}")
    return rows


if __name__ == "__main__":
    for r in main():
        print(r)
