#!/usr/bin/env python
"""Docs lint, run in tier-1 CI (scripts/ci.sh).

Four checks keep the documentation spine from rotting:

  1. every package under ``src/repro/`` (a directory with ``__init__.py``)
     has a ``README.md``;
  2. every RELATIVE markdown link in ``README.md``, any
     ``src/**/README.md``, and any ``docs/*.md`` resolves to an existing
     file or directory (external http(s)/mailto links and pure #anchors
     are not checked);
  3. every argparse flag of the serving launchers
     (``launch/serve.py``, ``launch/dryrun.py``) is documented in the
     serving operator's guide (``docs/serving.md``) — a new flag cannot
     land undocumented;
  4. every gated ``scripts/bench_diff.py`` metric key appears in a README
     or ``docs/*.md`` — either literally or via a ``<placeholder>``
     template (``kernel_<op>_tuned_s`` covers every concrete op key), so
     the "reading the nightly artifacts" docs can never silently fall
     behind the gate.

The flag check reads source text with a regex (never imports the
launchers — they pull in jax); the metric check imports ``bench_diff``
(stdlib-only) for its ``METRICS`` dict. Both checks are skipped in trees
that lack the corresponding sources, so the unit tests can build minimal
repos.

Exit 0 when clean; exit 1 with one line per problem.
"""

from __future__ import annotations

import importlib.util
import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")

# the first string literal of an add_argument call (flags only)
FLAG_RE = re.compile(r"add_argument\(\s*[\"'](--[A-Za-z0-9-]+)")
# launcher sources whose flags the operator's guide must cover
FLAG_SOURCES = ("src/repro/launch/serve.py", "src/repro/launch/dryrun.py")
SERVING_DOC = "docs/serving.md"

# a metric-key template in docs: text with <placeholder> segments, e.g.
# kernel_<op>_tuned_s or disagg_collective_s_<transfer>x<storage>
TEMPLATE_RE = re.compile(r"[a-z0-9_]*(?:<[a-z_]+>[a-z0-9_]*)+")


def repo_root() -> Path:
    return Path(__file__).resolve().parent.parent


def find_packages(root: Path) -> list[Path]:
    src = root / "src" / "repro"
    return sorted(p for p in src.iterdir()
                  if p.is_dir() and (p / "__init__.py").exists())


def missing_readmes(root: Path) -> list[str]:
    return [f"package {p.relative_to(root)} has no README.md"
            for p in find_packages(root) if not (p / "README.md").exists()]


def doc_files(root: Path) -> list[Path]:
    docs = []
    if (root / "README.md").exists():
        docs.append(root / "README.md")
    docs += sorted((root / "src").rglob("README.md"))
    if (root / "docs").is_dir():
        docs += sorted((root / "docs").glob("*.md"))
    return docs


def broken_links(root: Path) -> list[str]:
    problems = []
    for doc in doc_files(root):
        text = doc.read_text(encoding="utf-8")
        for m in LINK_RE.finditer(text):
            target = m.group(1)
            if target.startswith(SKIP_PREFIXES):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            resolved = (doc.parent / path).resolve()
            if not resolved.exists():
                problems.append(
                    f"{doc.relative_to(root)}: broken link -> {target}")
    return problems


def extract_flags(path: Path) -> list[str]:
    """Argparse flags of one launcher, from source text (no import —
    the launchers pull in jax)."""
    return sorted(set(FLAG_RE.findall(path.read_text(encoding="utf-8"))))


def missing_flag_docs(root: Path) -> list[str]:
    sources = [s for s in FLAG_SOURCES if (root / s).exists()]
    if not sources:
        return []
    doc = root / SERVING_DOC
    if not doc.exists():
        return [f"{SERVING_DOC} is missing (the serving operator's guide "
                f"must document every flag of {', '.join(sources)})"]
    text = doc.read_text(encoding="utf-8")
    problems = []
    for src in sources:
        for flag in extract_flags(root / src):
            if flag not in text:
                problems.append(f"{SERVING_DOC}: flag {flag} of {src} "
                                f"is undocumented")
    return problems


def gated_metrics(root: Path) -> dict:
    """The METRICS dict of scripts/bench_diff.py ({} when absent)."""
    path = root / "scripts" / "bench_diff.py"
    if not path.exists():
        return {}
    spec = importlib.util.spec_from_file_location("_bench_diff_docs", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return dict(mod.METRICS)


def _template_to_regex(template: str) -> re.Pattern:
    parts = re.split(r"<[a-z_]+>", template)
    return re.compile("[a-z0-9_]+".join(re.escape(p) for p in parts) + r"\Z")


def missing_metric_docs(root: Path) -> list[str]:
    metrics = gated_metrics(root)
    if not metrics:
        return []
    corpus = "\n".join(d.read_text(encoding="utf-8")
                       for d in doc_files(root))
    templates = [_template_to_regex(t)
                 for t in set(TEMPLATE_RE.findall(corpus)) if "<" in t]
    problems = []
    for key in sorted(metrics):
        if key in corpus or any(t.match(key) for t in templates):
            continue
        problems.append(
            f"gated bench_diff metric {key!r} is documented nowhere: add "
            f"it to a README or docs/*.md (templates like "
            f"kernel_<op>_tuned_s count)")
    return problems


def main() -> int:
    root = repo_root()
    problems = (missing_readmes(root) + broken_links(root)
                + missing_flag_docs(root) + missing_metric_docs(root))
    for p in problems:
        print(f"[check-docs] {p}")
    if problems:
        print(f"[check-docs] FAIL: {len(problems)} problem(s)")
        return 1
    n_docs = len(doc_files(root))
    n_flags = sum(len(extract_flags(root / s)) for s in FLAG_SOURCES
                  if (root / s).exists())
    print(f"[check-docs] OK: {len(find_packages(root))} packages, "
          f"{n_docs} doc file(s), all links resolve, {n_flags} launcher "
          f"flags and {len(gated_metrics(root))} gated metrics documented")
    return 0


if __name__ == "__main__":
    sys.exit(main())
