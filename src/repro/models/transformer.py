"""Model assembly: embeddings -> layer stack -> head, for all families.

Homogeneous stacks (dense / moe / mla / hybrid / encoder / vlm) store layer
parameters with a leading ``layers`` axis and run under ``lax.scan`` with
full rematerialization, so HLO size and activation memory are O(1) in depth.
xLSTM stacks are heterogeneous (alternating mLSTM/sLSTM) and use a Python
loop (12 layers).

``forward(cfg, params, batch, mode, cache, cache_len_total)``:
  mode="train"   -> (loss, metrics)
  mode="prefill" -> (last-position logits, cache)
  mode="decode"  -> (logits, new_cache)   [batch["pos"] = scalar position]
"""

from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig
from repro.dist import collectives
from repro.dist.collectives import act_gather
from repro.dist.sharding import constrain
from repro.models import attention, moe, ssm, xlstm
from repro.models.common import (
    Spec, rms_norm, swiglu, softmax_xent, stack_layer_specs,
    tree_abstract, tree_axes, tree_init,
)

VIT_HIDDEN = 1024    # stub InternViT output dim
AUDIO_HIDDEN = 512   # stub conv-frontend output dim

SCANNED_FAMILIES = ("dense", "moe", "mla", "hybrid", "encoder_audio", "vlm")


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------

def layer_specs(cfg: ModelConfig) -> Dict[str, Any]:
    s: Dict[str, Any] = {"ln1": Spec((cfg.d_model,), ("embed",), init="ones"),
                         "ln2": Spec((cfg.d_model,), ("embed",), init="ones")}
    if cfg.family == "mla":
        s["attn"] = attention.mla_specs(cfg)
    else:
        s["attn"] = attention.gqa_specs(cfg)
    if cfg.family == "hybrid":
        s["ssm"] = ssm.ssm_specs(cfg)
    if cfg.family == "moe":
        s["moe"] = moe.moe_specs(cfg)
    elif cfg.d_ff > 0:
        s["mlp"] = {
            "gate": Spec((cfg.d_model, cfg.d_ff), ("embed", "mlp")),
            "up": Spec((cfg.d_model, cfg.d_ff), ("embed", "mlp")),
            "down": Spec((cfg.d_ff, cfg.d_model), ("mlp", "embed")),
        }
    return s


def param_specs(cfg: ModelConfig) -> Dict[str, Any]:
    d, v = cfg.d_model, cfg.vocab
    specs: Dict[str, Any] = {
        "embed": Spec((v, d), ("vocab" if cfg.tie_embeddings else "vocab_in",
                               "embed")),
        "final_norm": Spec((d,), ("embed",), init="ones"),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = Spec((d, v), ("embed", "vocab"))
    if cfg.frontend == "vit_patches":
        specs["vision_adapter"] = Spec((VIT_HIDDEN, d), (None, "embed"))
    if cfg.frontend == "audio_frames":
        specs["audio_adapter"] = Spec((AUDIO_HIDDEN, d), (None, "embed"))
    if cfg.family == "ssm_xlstm":
        specs["blocks"] = [
            xlstm.mlstm_specs(cfg) if xlstm.is_mlstm_layer(cfg, i)
            else xlstm.slstm_specs(cfg)
            for i in range(cfg.n_layers)]
    else:
        specs["layers"] = stack_layer_specs(layer_specs(cfg), cfg.n_layers)
    return specs


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

# Cache leaves that carry attention KV state — the leaves a quantized
# resident cache stores compressed: kv_storage="int8" as s8 values + f32
# scales blocked along the trailing feature axis, kv_storage="f8" as
# scale-free e4m3 values (collectives.cast_f8). Recurrent-state leaves
# (ssm_*, xlstm blocks) are never storage-quantized.
QUANTIZABLE_CACHE_KEYS = ("k", "v", "latent", "k_rope")


def cache_struct(cfg: ModelConfig, batch: int, seq: int,
                 kv_storage: str = "bf16") -> Dict[str, Any]:
    """Shapes (python ints) for the decode cache; no allocation.

    ``kv_storage="int8"`` adds a ``<leaf>_scale`` entry per attention leaf
    (shape = leaf shape with the trailing feature dim replaced by its
    per-position block count); ``"f8"`` keeps the bf16 shapes — e4m3 is
    scale-free, only the leaf dtype changes."""
    if kv_storage not in collectives.KV_STORAGES:
        raise ValueError(f"unknown kv_storage {kv_storage!r}; "
                         f"expected one of {collectives.KV_STORAGES}")
    if cfg.family == "ssm_xlstm":
        return {"blocks": [
            (xlstm.mlstm_cache_shape(cfg, batch)
             if xlstm.is_mlstm_layer(cfg, i)
             else xlstm.slstm_cache_shape(cfg, batch))
            for i in range(cfg.n_layers)]}
    if cfg.family == "mla":
        per = attention.mla_cache_shape(cfg, batch, seq)
    else:
        per = attention.gqa_cache_shape(cfg, batch, seq)
    out = {k: (cfg.n_layers,) + v for k, v in per.items()}
    if cfg.family == "hybrid":
        for k, v in ssm.ssm_cache_shape(cfg, batch).items():
            out["ssm_" + k] = (cfg.n_layers,) + v
    if kv_storage == "int8":
        for k in [k for k in out if k in QUANTIZABLE_CACHE_KEYS]:
            shape = out[k]
            _, nb = collectives.lastdim_blocks(shape[-1])
            out[k + "_scale"] = shape[:-1] + (nb,)
    return out


def _flat_cache_axes(cfg: ModelConfig) -> Dict[str, Any]:
    """Assemble the flat-cache leaf axes from the family modules' StateStore
    contributions (each family declares its per-layer leaf layout; the
    stack prepends "layers" and derives each quantization-scale leaf as
    its value leaf's layout with the trailing block axis unsharded)."""
    if cfg.family == "mla":
        per = attention.mla_cache_axes()
    else:
        per = attention.gqa_cache_axes()
    out = {k: ("layers",) + v for k, v in per.items()}
    if cfg.family == "hybrid":
        for k, v in ssm.ssm_cache_axes().items():
            out["ssm_" + k] = ("layers",) + v
    for k in QUANTIZABLE_CACHE_KEYS:
        if k in out:
            out[k + "_scale"] = out[k][:-1] + (None,)
    return out


def cache_axes(cfg: ModelConfig, batch: int, seq: int,
               kv_storage: str = "bf16") -> Dict[str, Any]:
    struct = cache_struct(cfg, batch, seq, kv_storage)
    if cfg.family == "ssm_xlstm":
        return {"blocks": [
            {k: ("batch",) + (None,) * (len(v) - 1) for k, v in blk.items()}
            for blk in struct["blocks"]]}
    axes = _flat_cache_axes(cfg)
    return {k: axes[k] for k in struct}


def _cache_leaf_dtype(name: Optional[str], kv_storage: str, dtype):
    if kv_storage == "bf16" or name is None:
        return dtype
    if name.endswith("_scale"):
        return jnp.float32
    if name in QUANTIZABLE_CACHE_KEYS:
        return jnp.int8 if kv_storage == "int8" else collectives.F8_DTYPE
    return dtype


def abstract_cache(cfg: ModelConfig, batch: int, seq: int,
                   dtype=jnp.bfloat16, kv_storage: str = "bf16"
                   ) -> Dict[str, Any]:
    def mk(shape, name=None):
        return jax.ShapeDtypeStruct(
            shape, _cache_leaf_dtype(name, kv_storage, dtype))
    struct = cache_struct(cfg, batch, seq, kv_storage)
    if cfg.family == "ssm_xlstm":
        return {"blocks": [{k: mk(v) for k, v in blk.items()}
                           for blk in struct["blocks"]]}
    return {k: mk(v, k) for k, v in struct.items()}


def init_cache(cfg: ModelConfig, batch: int, seq: int, dtype=jnp.bfloat16,
               kv_storage: str = "bf16"):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        abstract_cache(cfg, batch, seq, dtype, kv_storage))


def quantize_cache_int8(cache: Dict[str, Any]) -> Dict[str, Any]:
    """Convert a bf16 decode cache into the int8-resident storage layout:
    every attention leaf becomes s8 values + a ``<leaf>_scale`` f32 leaf,
    quantized blockwise along the trailing feature axis (per position —
    matching what the decode step writes for each new token). Recurrent
    leaves pass through untouched. jit-compatible."""
    out: Dict[str, Any] = {}
    for name, leaf in cache.items():
        if name in QUANTIZABLE_CACHE_KEYS:
            q, s = collectives.quantize_int8_lastdim(leaf)
            out[name] = q
            out[name + "_scale"] = s
        else:
            out[name] = leaf
    return out


def quantize_cache(cache: Dict[str, Any], kv_storage: str) -> Dict[str, Any]:
    """Convert a bf16 decode cache (or cache slice) into the resident
    storage layout for ``kv_storage`` — identity for "bf16", s8 + scales
    for "int8", scale-free e4m3 for "f8". jit-compatible; the slot
    admission step and the whole-batch handoff both route through here."""
    if kv_storage == "bf16":
        return cache
    if kv_storage == "int8":
        return quantize_cache_int8(cache)
    if kv_storage == "f8":
        return {name: collectives.cast_f8(leaf)
                if name in QUANTIZABLE_CACHE_KEYS else leaf
                for name, leaf in cache.items()}
    raise ValueError(f"unknown kv_storage {kv_storage!r}; "
                     f"expected one of {collectives.KV_STORAGES}")


# ---------------------------------------------------------------------------
# layer body (scanned families)
# ---------------------------------------------------------------------------

def _layer_body(cfg: ModelConfig, mode: str, cache_len_total: int,
                x, lp, lcache, pos):
    aux = {}
    # residual stream anchor; under the "sp"/"serve_sp" presets seq_res ->
    # model shards the residual stream (Megatron sequence parallelism)
    x = constrain(x, "batch", "seq_res", "act_embed")
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    if mode != "decode":
        # the sp activation all-gather: attention needs the full sequence,
        # so the post-norm stream reshards seq-sharded -> gathered here
        # (int8 on the wire under act_transport="int8"). Decode's gather
        # is the KV-cache gather inside the attention layer instead.
        h = act_gather(h, "batch", None, "act_embed")
    attn_cache = None
    if lcache is not None and cfg.family != "hybrid":
        attn_cache = lcache
    elif lcache is not None:
        attn_cache = {"k": lcache["k"], "v": lcache["v"]}
    if cfg.family == "mla":
        attn_out, new_attn = attention.mla_apply(
            cfg, lp["attn"], h, mode, attn_cache, pos, cache_len_total)
    else:
        attn_out, new_attn = attention.gqa_apply(
            cfg, lp["attn"], h, mode, attn_cache, pos, cache_len_total)
    if cfg.family == "hybrid":
        ssm_cache = None
        if lcache is not None:
            ssm_cache = {"conv": lcache["ssm_conv"], "ssm": lcache["ssm_ssm"]}
        ssm_out, new_ssm = ssm.ssm_apply(cfg, lp["ssm"], h, mode, ssm_cache)
        x = x + 0.5 * (attn_out + ssm_out)
    else:
        x = x + attn_out
    h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
    if mode != "decode":
        h2 = act_gather(h2, "batch", None, "act_embed")   # sp gather, MLP side
    if cfg.family == "moe":
        y, aux = moe.moe_apply(cfg, lp["moe"], h2, mode=mode)
    elif cfg.d_ff > 0:
        y = swiglu(h2, lp["mlp"]["gate"], lp["mlp"]["up"], lp["mlp"]["down"])
    else:
        y = jnp.zeros_like(x)
    x = x + y

    new_cache = None
    if new_attn is not None:
        new_cache = dict(new_attn)
        if cfg.family == "hybrid":
            new_cache = {"k": new_attn["k"], "v": new_attn["v"],
                         "ssm_conv": new_ssm["conv"], "ssm_ssm": new_ssm["ssm"]}
    return x, new_cache, aux


def _run_stack(cfg, params, x, mode, cache, pos, cache_len_total):
    """Scan the homogeneous layer stack. Returns (x, new_cache, aux).

    ``cfg.remat_block`` layers form one rematerialization unit: only the
    unit's input is saved for backward, so saved-activation memory scales
    as L / remat_block (at the cost of re-running the whole unit forward in
    backward — flops unchanged under full remat, one extra unit-input copy).
    """
    has_cache = cache is not None and mode in ("decode",)
    emits_cache = mode in ("decode", "prefill")
    rb = max(1, cfg.remat_block)
    n_units = cfg.n_layers // rb
    assert cfg.n_layers % rb == 0, (cfg.n_layers, rb)

    def unit_body(xcur, lp_unit, lcache_unit, pos):
        caches = []
        aux_tot = {}
        for j in range(rb):
            lp = jax.tree.map(lambda t: t[j], lp_unit)
            lcache = jax.tree.map(lambda t: t[j], lcache_unit) \
                if lcache_unit is not None else None
            xcur, new_lcache, aux = _layer_body(
                cfg, mode, cache_len_total, xcur, lp, lcache, pos)
            caches.append(new_lcache)
            for k, v in (aux or {}).items():
                aux_tot[k] = aux_tot.get(k, 0.0) + v
        if caches[0] is not None:
            caches = jax.tree.map(lambda *ts: jnp.stack(ts), *caches)
        else:
            caches = None
        return xcur, caches, aux_tot

    body = jax.checkpoint(partial(unit_body, pos=pos))

    def scan_fn(carry, xs):
        xcur, aux_acc = carry
        lp_unit, lcache_unit = xs
        xnew, new_lcache, aux = body(xcur, lp_unit, lcache_unit)
        aux_acc = {k: aux_acc.get(k, 0.0) + v for k, v in aux.items()} \
            if aux else aux_acc
        return (xnew, aux_acc), new_lcache

    aux0 = {}
    if cfg.family == "moe":
        aux0 = {"moe_lb_loss": jnp.zeros((), jnp.float32),
                "moe_z_loss": jnp.zeros((), jnp.float32),
                "moe_drop_frac": jnp.zeros((), jnp.float32)}

    def to_units(t):
        return t.reshape(n_units, rb, *t.shape[1:])

    lp_units = jax.tree.map(to_units, params["layers"])
    xs_cache = jax.tree.map(to_units, cache) if has_cache else None
    (x, aux), new_cache = jax.lax.scan(scan_fn, (x, aux0),
                                       (lp_units, xs_cache))
    if not emits_cache:
        new_cache = None
    elif new_cache is not None:
        new_cache = jax.tree.map(
            lambda t: t.reshape(cfg.n_layers, *t.shape[2:]), new_cache)
    if cfg.family == "moe":
        aux = {k: v / cfg.n_layers for k, v in aux.items()}
    return x, new_cache, aux


def _run_xlstm(cfg, params, x, mode, cache):
    new_blocks = []
    blocks_cache = cache["blocks"] if cache is not None else [None] * cfg.n_layers
    for i, bp in enumerate(params["blocks"]):
        fn = xlstm.mlstm_apply if xlstm.is_mlstm_layer(cfg, i) else xlstm.slstm_apply
        x, bc = jax.checkpoint(partial(fn, cfg), static_argnums=(2,))(
            bp, x, mode, blocks_cache[i])
        new_blocks.append(bc)
    if mode in ("decode", "prefill"):
        return x, {"blocks": new_blocks}, {}
    return x, None, {}


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _embed_inputs(cfg, params, batch, mode):
    if cfg.frontend == "audio_frames":
        return constrain(jnp.einsum("bsf,fd->bsd", batch["frames"],
                                    params["audio_adapter"]),
                         "batch", None, "act_embed")
    tok = jnp.take(params["embed"], batch["tokens"], axis=0)
    tok = constrain(tok, "batch", None, "act_embed")
    if cfg.frontend == "vit_patches" and mode != "decode":
        vis = jnp.einsum("bpf,fd->bpd", batch["patches"],
                         params["vision_adapter"])
        return constrain(jnp.concatenate([vis, tok], axis=1),
                         "batch", None, "act_embed")
    return tok


def _logits(cfg, params, x):
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    out = jnp.einsum("...d,dv->...v", x, head)
    return constrain(out, *(("batch",) + (None,) * (out.ndim - 2) + ("vocab",)))


def forward(cfg: ModelConfig, params, batch: Dict[str, Any], mode: str,
            cache=None, cache_len_total: int = 0):
    x = _embed_inputs(cfg, params, batch, mode)
    pos = batch.get("pos", 0)

    if cfg.family == "ssm_xlstm":
        x, new_cache, aux = _run_xlstm(cfg, params, x, mode, cache)
    else:
        x, new_cache, aux = _run_stack(cfg, params, x, mode, cache, pos,
                                       cache_len_total)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)

    if mode == "train":
        if cfg.frontend == "vit_patches":
            x = x[:, cfg.n_vision_tokens:]       # loss on text positions only
        logits = _logits(cfg, params, x)
        loss = softmax_xent(logits, batch["labels"], batch.get("mask"))
        metrics = {"ce_loss": loss}
        if cfg.family == "moe":
            loss = loss + 0.01 * aux["moe_lb_loss"] \
                + cfg.router_aux_weight * aux["moe_z_loss"]
            metrics.update(aux)
        metrics["loss"] = loss
        return loss, metrics

    if mode == "encode":  # encoder-only serving: per-position unit logits
        return _logits(cfg, params, x), None

    if mode == "prefill":
        last = batch.get("last_pos")
        if last is None:
            xl = x[:, -1]
        else:   # ragged prompts: per-row index of the final prompt token
            idx = jnp.asarray(last, jnp.int32)[:, None, None]
            xl = jnp.take_along_axis(x, idx, axis=1)[:, 0]
        return _logits(cfg, params, xl), new_cache

    # decode
    logits = _logits(cfg, params, x[:, -1])
    return logits, new_cache


# ---------------------------------------------------------------------------
# public param API
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key):
    return tree_init(param_specs(cfg), key)


def abstract_params(cfg: ModelConfig):
    return tree_abstract(param_specs(cfg))


def param_axes(cfg: ModelConfig):
    return tree_axes(param_specs(cfg))
