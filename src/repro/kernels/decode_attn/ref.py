"""Pure-jnp oracle for flash-decode."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def decode_attention_ref(q, k, v, lengths):
    """q: (B,H,D); k,v: (B,S,Hkv,D); lengths: (B,)."""
    b, h, d = q.shape
    s, hkv = k.shape[1], k.shape[2]
    group = h // hkv
    k = jnp.repeat(k, group, axis=2)        # (B,S,H,D)
    v = jnp.repeat(v, group, axis=2)
    scores = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / np.sqrt(d)
    valid = jnp.arange(s)[None, None, :] < lengths[:, None, None]
    scores = jnp.where(valid, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhs,bshd->bhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
