"""Assigned input-shape sets and ShapeDtypeStruct stand-ins for the dry-run.

Every (arch x shape) cell is defined here; ``applicable()`` encodes the
documented skips (encoder-only archs have no decode step; full-attention
archs skip long_500k). ``input_specs()`` returns weak-type-correct,
shardable ShapeDtypeStructs — no device allocation ever happens for the
full-size configs.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig
from repro.models import transformer


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str              # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int
    microbatches: int = 1  # train only: gradient-accumulation steps


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256, microbatches=8),
    "prefill_8k": ShapeSpec("prefill_8k", "prefill", 8_192, 64),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}

SHAPE_IDS = tuple(SHAPES)


def expand_shape_names(spec: str) -> Tuple[str, ...]:
    """Expand a comma list of shape names and/or kinds into shape names.

    ``"decode"`` -> every decode-kind shape, ``"prefill_8k,decode"`` ->
    that shape plus the decode shapes, ``"all"`` -> everything. Raises
    ``KeyError`` on an unknown token.
    """
    if spec == "all":
        return SHAPE_IDS
    out = []
    for tok in spec.split(","):
        if tok in SHAPES:
            out.append(tok)
        elif tok in ("train", "prefill", "decode"):
            out.extend(n for n, s in SHAPES.items() if s.kind == tok)
        else:
            raise KeyError(f"unknown shape or kind {tok!r}; "
                           f"known: {', '.join(SHAPE_IDS)} + train/prefill/decode")
    return tuple(dict.fromkeys(out))


def applicable(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    if shape.kind == "decode" and not cfg.supports_decode:
        return False, "encoder-only arch: no decode step"
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "full-attention arch: 524k decode needs sub-quadratic attention"
    return True, ""


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def batch_axes(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """Logical axes for each batch leaf (for input shardings)."""
    b = _token_batch_axes(cfg, shape)
    return b


def _token_batch_axes(cfg, shape):
    ax: Dict[str, Any] = {}
    if shape.kind == "train":
        if cfg.frontend == "audio_frames":
            ax["frames"] = ("batch", "seq", None)
            ax["mask"] = ("batch", "seq")
        else:
            ax["tokens"] = ("batch", "seq")
        if cfg.frontend == "vit_patches":
            ax["patches"] = ("batch", None, None)
        ax["labels"] = ("batch", "seq")
    elif shape.kind == "prefill":
        if cfg.frontend == "audio_frames":
            ax["frames"] = ("batch", "seq", None)
        else:
            ax["tokens"] = ("batch", "seq")
        if cfg.frontend == "vit_patches":
            ax["patches"] = ("batch", None, None)
    else:  # decode
        ax["tokens"] = ("batch", None)
        ax["pos"] = ()
    return ax


def input_specs(cfg: ModelConfig, shape: ShapeSpec
                ) -> Tuple[Dict[str, Any], Optional[Any]]:
    """(batch SDS dict, cache SDS pytree or None) for one cell."""
    ok, why = applicable(cfg, shape)
    if not ok:
        raise ValueError(f"{cfg.name} x {shape.name}: {why}")
    b, s = shape.global_batch, shape.seq_len
    batch: Dict[str, Any] = {}
    cache = None

    if shape.kind in ("train", "prefill"):
        if cfg.frontend == "audio_frames":
            batch["frames"] = _sds((b, s, transformer.AUDIO_HIDDEN), jnp.bfloat16)
        elif cfg.frontend == "vit_patches":
            batch["tokens"] = _sds((b, s - cfg.n_vision_tokens), jnp.int32)
            batch["patches"] = _sds((b, cfg.n_vision_tokens,
                                     transformer.VIT_HIDDEN), jnp.bfloat16)
        else:
            batch["tokens"] = _sds((b, s), jnp.int32)
        if shape.kind == "train":
            lab_len = s if cfg.frontend != "vit_patches" else s - cfg.n_vision_tokens
            batch["labels"] = _sds((b, lab_len), jnp.int32)
            if cfg.frontend == "audio_frames":
                batch["mask"] = _sds((b, s), jnp.bool_)
    else:  # decode: one new token against a cache of seq_len
        batch["tokens"] = _sds((b, 1), jnp.int32)
        batch["pos"] = _sds((), jnp.int32)
        cache = transformer.abstract_cache(cfg, b, s)
    return batch, cache


def make_batch(cfg: ModelConfig, shape: ShapeSpec, key) -> Dict[str, Any]:
    """Materialize a random batch matching input_specs (smoke/e2e use)."""
    specs, cache = input_specs(cfg, shape)
    ks = jax.random.split(key, len(specs))
    out = {}
    for k_rng, (name, sds) in zip(ks, sorted(specs.items())):
        if sds.dtype == jnp.int32 and name in ("tokens", "labels"):
            out[name] = jax.random.randint(k_rng, sds.shape, 0, cfg.vocab,
                                           dtype=jnp.int32)
        elif sds.dtype == jnp.int32:
            out[name] = jnp.zeros(sds.shape, jnp.int32) + (shape.seq_len - 1)
        elif sds.dtype == jnp.bool_:
            out[name] = jax.random.bernoulli(k_rng, 0.3, sds.shape)
        else:
            out[name] = jax.random.normal(k_rng, sds.shape, jnp.float32) \
                .astype(sds.dtype)
    if cache is not None:
        cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), cache)
    return out, cache
