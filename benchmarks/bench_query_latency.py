"""Fig. 8 — impact of compaction on query latency (read p50/p95 per hour,
no-compaction vs table-10 vs hybrid-500). Latency comes from the metered
scan-planning + per-file-open cost model calibrated on the real data
pipeline (bench_pipeline_latency measures the real thing end-to-end)."""

from __future__ import annotations

from typing import List

from benchmarks.workload_sim import run_sim

STRATEGIES = ("none", "table-10", "hybrid-500")


def main(hours: int = 5) -> List[str]:
    rows = []
    for strat in STRATEGIES:
        res = run_sim(strategy=strat, hours=hours, seed=0)
        p50 = "|".join(f"{r['lat_p50']*1e3:.0f}" for r in res["hourly"])
        p95 = "|".join(f"{r['lat_p95']*1e3:.0f}" for r in res["hourly"])
        rows.append(f"fig8_read_p50_ms[{strat}],"
                    f"{res['hourly'][-1]['lat_p50']*1e3:.1f},hourly={p50}")
        rows.append(f"fig8_read_p95_ms[{strat}],"
                    f"{res['hourly'][-1]['lat_p95']*1e3:.1f},hourly={p95}")
        rows.append(f"fig8_duration_s[{strat}],{res['duration_s']:.1f},"
                    f"files={res['final_file_count']}")
    return rows


if __name__ == "__main__":
    for r in main():
        print(r)
