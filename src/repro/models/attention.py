"""Attention layers: GQA (optional QKV bias, optional sliding window) and
MLA (Multi-head Latent Attention, MiniCPM3/DeepSeek-style).

Each layer exposes ``specs(cfg)`` (parameter declarations) and
``apply(cfg, p, x, mode, cache, pos)`` -> (out, new_cache).

Cache layouts (per layer, no leading layers axis here):
  GQA : {"k": (B, S_c, Hkv, D), "v": (B, S_c, Hkv, D)}   S_c = window or seq
  MLA : {"latent": (B, S_c, kv_lora), "k_rope": (B, S_c, rope_dim)}
Cached K is stored *post-RoPE* (standard for ring buffers: relative property
is preserved because Q is rotated at query position).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ModelConfig
from repro.dist import collectives
from repro.dist.sharding import constrain, mesh_axis_size
from repro.models import common
from repro.models.common import Spec, blockwise_attention, decode_attention, apply_rope


# ---------------------------------------------------------------------------
# slot bookkeeping for (ring) caches
# ---------------------------------------------------------------------------

def cache_slot_positions(cache_len_total: int, size: int, pos) -> jnp.ndarray:
    """Absolute position held by each cache slot, -1 if empty.

    For a full cache (size >= max seq) slot i holds position i (valid iff
    i <= pos). For a ring buffer of ``size`` slots, slot i holds the largest
    p <= pos with p % size == i (valid iff p >= 0); assumes contiguous fill.
    ``pos`` may be a scalar (returns (S,)) or per-row (B,) (returns (B,S) —
    continuous batching, every request at its own position).
    """
    idx = jnp.arange(size, dtype=jnp.int32)
    pos = jnp.asarray(pos, jnp.int32)[..., None]     # () -> (1,), (B,) -> (B,1)
    if cache_len_total <= size:  # full cache
        return jnp.where(idx <= pos, idx, -1)        # (S,) or (B,S)
    p = pos - ((pos - idx) % size)
    return jnp.where(p >= 0, p, -1)


def ring_update(buf: jnp.ndarray, new: jnp.ndarray, pos) -> jnp.ndarray:
    """Write ``new`` (B, 1, ...) at slot pos % size of ``buf`` (B, size, ...).

    ``pos`` scalar writes one slot for the whole batch; per-row (B,) writes
    each row at its own slot (ragged continuous batching).
    """
    size = buf.shape[1]
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 0:
        start = (jnp.zeros((), jnp.int32), jax.lax.rem(pos, size)) \
            + (jnp.zeros((), jnp.int32),) * (buf.ndim - 2)
        return jax.lax.dynamic_update_slice(buf, new.astype(buf.dtype), start)
    slot = jax.lax.rem(pos, size)                            # (B,)
    hit = jnp.arange(size, dtype=jnp.int32)[None, :] == slot[:, None]
    hit = hit.reshape(hit.shape + (1,) * (buf.ndim - 2))
    return jnp.where(hit, new.astype(buf.dtype), buf)


def paged_decode_attention(q, k_pool, v_pool, page_table, k_positions, pos,
                           k_scale_pool=None, v_scale_pool=None):
    """Single-token attention reading one layer's K/V through a page table.

    ``k_pool``/``v_pool`` are page pools ``(n_pool, page, Hkv, D)`` (one
    layer of a ``registry.PagedStateStore`` state); ``page_table`` is the
    per-row table ``(B, pages_per_row)`` with -1 marking unallocated
    pages. The pools are gathered back to the dense per-row layout and
    handed to :func:`repro.models.common.decode_attention` unchanged, so
    the paged read is bit-identical to the dense one: junk gathered from
    unallocated (-1 -> clamped) entries sits at positions the
    ``k_positions``/``pos`` mask sends to NEG_INF before the softmax.
    Quantized (int8) pools pass their scale pools the same way.
    """
    from repro.kernels.paged_attn import gather_pages
    k = gather_pages(k_pool, page_table)
    v = gather_pages(v_pool, page_table)
    ks = None if k_scale_pool is None else gather_pages(k_scale_pool, page_table)
    vs = None if v_scale_pool is None else gather_pages(v_scale_pool, page_table)
    return decode_attention(q, k, v, k_positions, pos, ks, vs)


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

def gqa_specs(cfg: ModelConfig) -> Dict[str, Spec]:
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    s = {
        "wq": Spec((d, h, hd), ("embed", "heads", "head_dim")),
        "wk": Spec((d, hkv, hd), ("embed", "kv_heads", "head_dim")),
        "wv": Spec((d, hkv, hd), ("embed", "kv_heads", "head_dim")),
        "wo": Spec((h, hd, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        s["bq"] = Spec((h, hd), ("heads", "head_dim"), init="zeros")
        s["bk"] = Spec((hkv, hd), ("kv_heads", "head_dim"), init="zeros")
        s["bv"] = Spec((hkv, hd), ("kv_heads", "head_dim"), init="zeros")
    return s


def gqa_apply(cfg: ModelConfig, p, x: jnp.ndarray, mode: str,
              cache: Optional[dict], pos, cache_len_total: int,
              ) -> Tuple[jnp.ndarray, Optional[dict]]:
    b, s, _ = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = constrain(q, "batch", None, "heads", None)
    k = constrain(k, "batch", None, "kv_heads", None)
    v = constrain(v, "batch", None, "kv_heads", None)

    if mode == "decode":
        pos_bt = jnp.broadcast_to(jnp.asarray(pos, jnp.int32)[..., None],
                                  (b, 1))            # scalar or per-row (B,)
        q = apply_rope(q, pos_bt, cfg.rope_theta)
        k = apply_rope(k, pos_bt, cfg.rope_theta)
        size = cache["k"].shape[1]
        cache_sp = ("batch", "kv_seq", "kv_heads", None)
        storage = collectives.current_kv_storage()
        if storage == "int8":
            # int8-resident cache: quantize the new token's K/V per
            # position along the feature axis (blocks never span
            # positions, so a slot write touches only its own scales) and
            # store s8 values + f32 scales; decode_attention dequantizes
            # per block at read time.
            k, k_sc = collectives.quantize_int8_lastdim(k)
            v, v_sc = collectives.quantize_int8_lastdim(v)
            k_scale = constrain(ring_update(cache["k_scale"], k_sc, pos),
                                *cache_sp)
            v_scale = constrain(ring_update(cache["v_scale"], v_sc, pos),
                                *cache_sp)
        elif storage == "f8":
            # f8-resident cache: scale-free e4m3 cast of the new token's
            # K/V (no companion scale leaves; decode_attention upcasts per
            # block at read time).
            k = collectives.cast_f8(k)
            v = collectives.cast_f8(v)
        k_cache = constrain(ring_update(cache["k"], k, pos), *cache_sp)
        v_cache = constrain(ring_update(cache["v"], v, pos), *cache_sp)
        kpos = cache_slot_positions(cache_len_total + 1, size, pos)
        if cfg.attn_window:
            win_lo = jnp.asarray(pos, jnp.int32)[..., None] - cfg.attn_window
            kpos = jnp.where(kpos > win_lo, kpos, -1)
        # serve_sp: the cache is sequence-sharded; attention needs every
        # slot, so this is decode's activation all-gather (s8 under
        # act_transport="int8"). Gather to a head-replicated layout — a
        # pure all-gather over the sequence shards; the scores einsum then
        # slices heads locally against the head-sharded q. Under
        # serve_decode the cache is batch-resident and these constraints
        # move nothing. An int8-*resident* cache passes through the gather
        # as s8 (already compressed); its f32 scales reshard raw — they
        # are 1/block of the payload.
        gather_sp = ("batch", None, None, None)
        k_att = collectives.act_gather(k_cache, *gather_sp)
        v_att = collectives.act_gather(v_cache, *gather_sp)
        if storage == "int8":
            out = decode_attention(q, k_att, v_att, kpos, pos,
                                   k_scale=constrain(k_scale, *gather_sp),
                                   v_scale=constrain(v_scale, *gather_sp))
            new_cache = {"k": k_cache, "v": v_cache,
                         "k_scale": k_scale, "v_scale": v_scale}
        else:
            out = decode_attention(q, k_att, v_att, kpos, pos)
            new_cache = {"k": k_cache, "v": v_cache}
    else:
        positions = jnp.arange(s, dtype=jnp.int32)[None, :]
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        new_cache = None
        if mode == "prefill":
            size = cfg.attn_window or s
            new_cache = {"k": k[:, -size:].astype(common.COMPUTE_DTYPE),
                         "v": v[:, -size:].astype(common.COMPUTE_DTYPE)}
        # TP > kv_heads: replicate KV across query-head groups so attention
        # activations stay head-sharded (MaxText-style KV replication).
        tp = mesh_axis_size("model")
        h, hkv = cfg.n_heads, cfg.n_kv_heads
        if tp > 1 and h % tp == 0 and hkv % tp != 0:
            rep = h // hkv
            k = constrain(jnp.repeat(k, rep, axis=2), "batch", None, "heads", None)
            v = constrain(jnp.repeat(v, rep, axis=2), "batch", None, "heads", None)
        out = blockwise_attention(q, k, v, causal=cfg.causal,
                                  window=cfg.attn_window)
    y = constrain(jnp.einsum("bshk,hkd->bsd", out, p["wo"]),
                  "batch", None, "act_embed")
    return y, new_cache


def gqa_cache_shape(cfg: ModelConfig, batch: int, seq: int):
    size = min(cfg.attn_window, seq) if cfg.attn_window else seq
    kv = (batch, size, cfg.n_kv_heads, cfg.head_dim)
    return {"k": kv, "v": kv}


def gqa_cache_axes():
    """Logical axes of the GQA ring-buffer cache leaves (this family's
    contribution to the StateStore protocol; the stack prepends its
    "layers" axis). ``kv_seq`` marks the slice-admission axis — a
    windowed (ring) cache still carries it, but slot streaming admits it
    whole-row after an exact-length prefill."""
    kv = ("batch", "kv_seq", "kv_heads", "head_dim")
    return {"k": kv, "v": kv}


# ---------------------------------------------------------------------------
# MLA (latent KV cache)
# ---------------------------------------------------------------------------

def mla_specs(cfg: ModelConfig) -> Dict[str, Spec]:
    d, h = cfg.d_model, cfg.n_heads
    rq, rkv = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    return {
        "wq_a": Spec((d, rq), ("embed", "q_lora")),
        "wq_b": Spec((rq, h, dn + dr), ("q_lora", "heads", "head_dim")),
        "wkv_a": Spec((d, rkv + dr), ("embed", "kv_lora")),
        "wk_b": Spec((rkv, h, dn), ("kv_lora", "heads", "head_dim")),
        "wv_b": Spec((rkv, h, dv), ("kv_lora", "heads", "head_dim")),
        "wo": Spec((h, dv, d), ("heads", "head_dim", "embed")),
        "q_norm": Spec((rq,), ("q_lora",), init="ones"),
        "kv_norm": Spec((rkv,), ("kv_lora",), init="ones"),
    }


def _mla_qk(cfg, p, x, positions):
    """Project to per-head q (nope|rope) and latent kv. x:(B,S,d)."""
    dn, dr = cfg.nope_head_dim, cfg.rope_head_dim
    cq = common.rms_norm(jnp.einsum("bsd,dr->bsr", x, p["wq_a"]), p["q_norm"],
                         cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", cq, p["wq_b"])          # (B,S,H,dn+dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    kv = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"])           # (B,S,rkv+dr)
    latent = common.rms_norm(kv[..., :cfg.kv_lora_rank], p["kv_norm"],
                             cfg.norm_eps)
    k_rope = apply_rope(kv[..., None, cfg.kv_lora_rank:], positions,
                        cfg.rope_theta)[..., 0, :]          # (B,S,dr) shared
    return jnp.concatenate([q_nope, q_rope], -1), latent, k_rope


def _mla_expand(cfg, p, latent, k_rope):
    """Expand latent into per-head K (nope|rope-shared) and V."""
    k_nope = jnp.einsum("bsr,rhk->bshk", latent, p["wk_b"])
    v = jnp.einsum("bsr,rhk->bshk", latent, p["wv_b"])
    kr = jnp.broadcast_to(k_rope[:, :, None, :],
                          k_nope.shape[:3] + (cfg.rope_head_dim,))
    return jnp.concatenate([k_nope, kr], -1), v


def mla_apply(cfg: ModelConfig, p, x, mode, cache, pos, cache_len_total):
    b, s, _ = x.shape
    if mode == "decode":
        positions = jnp.broadcast_to(jnp.asarray(pos, jnp.int32)[..., None],
                                     (b, 1))
        q, latent, k_rope = _mla_qk(cfg, p, x, positions)
        storage = collectives.current_kv_storage()
        kr_new = k_rope[:, :, None, :]
        if storage == "f8":
            # f8-resident latent cache: scale-free e4m3, upcast at the
            # same read-time boundary as int8 (the latent expansion)
            latent = collectives.cast_f8(latent)
            kr_new = collectives.cast_f8(kr_new)
        if storage == "int8":
            # int8-resident latent cache (MLA's read-time boundary is the
            # per-head expansion, so dequantization happens just before
            # _mla_expand instead of inside decode_attention)
            latent, lat_sc = collectives.quantize_int8_lastdim(latent)
            kr_new, kr_sc = collectives.quantize_int8_lastdim(kr_new)
            lat_scale = constrain(ring_update(cache["latent_scale"], lat_sc,
                                              pos), "batch", "kv_seq", None)
            kr_scale = constrain(ring_update(cache["k_rope_scale"], kr_sc,
                                             pos), "batch", "kv_seq", None,
                                  None)
        lat_cache = constrain(ring_update(cache["latent"], latent, pos),
                              "batch", "kv_seq", None)
        kr_cache = constrain(ring_update(cache["k_rope"], kr_new, pos),
                             "batch", "kv_seq", None, None)
        # decode's activation all-gather (MLA form): the latent cache is
        # the compressed KV state — gather it (s8 under int8 transport, or
        # natively s8 when int8-resident) before the per-head expansion.
        lat_att = collectives.act_gather(lat_cache, "batch", None, None)
        kr_att = collectives.act_gather(kr_cache, "batch", None, None, None)
        if storage == "int8":
            lat_att = collectives.dequantize_int8_lastdim(
                lat_att, constrain(lat_scale, "batch", None, None))
            kr_att = collectives.dequantize_int8_lastdim(
                kr_att, constrain(kr_scale, "batch", None, None, None))
            lat_att = lat_att.astype(x.dtype)
            kr_att = kr_att.astype(x.dtype)
        elif storage == "f8":
            lat_att = collectives.uncast_f8(lat_att, x.dtype)
            kr_att = collectives.uncast_f8(kr_att, x.dtype)
        k, v = _mla_expand(cfg, p, lat_att, kr_att[..., 0, :])
        kpos = cache_slot_positions(cache_len_total + 1, lat_cache.shape[1], pos)
        out = decode_attention(q, k, v, kpos, pos)
        new_cache = {"latent": lat_cache, "k_rope": kr_cache}
        if storage == "int8":
            new_cache["latent_scale"] = lat_scale
            new_cache["k_rope_scale"] = kr_scale
    else:
        positions = jnp.arange(s, dtype=jnp.int32)[None, :]
        q, latent, k_rope = _mla_qk(cfg, p, x, positions)
        k, v = _mla_expand(cfg, p, latent, k_rope)
        out = blockwise_attention(q, k, v, causal=cfg.causal)
        new_cache = None
        if mode == "prefill":
            new_cache = {"latent": latent.astype(common.COMPUTE_DTYPE),
                         "k_rope": k_rope[:, :, None, :].astype(common.COMPUTE_DTYPE)}
    y = constrain(jnp.einsum("bshk,hkd->bsd", out, p["wo"]),
                  "batch", None, "act_embed")
    return y, new_cache


def mla_cache_shape(cfg: ModelConfig, batch: int, seq: int):
    return {"latent": (batch, seq, cfg.kv_lora_rank),
            "k_rope": (batch, seq, 1, cfg.rope_head_dim)}


def mla_cache_axes():
    """Logical axes of the MLA latent-cache leaves (StateStore protocol
    contribution; the stack prepends its "layers" axis)."""
    return {"latent": ("batch", "kv_seq", "kv_lora"),
            "k_rope": ("batch", "kv_seq", None, None)}
