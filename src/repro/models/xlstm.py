"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel training form) and
sLSTM (scalar memory, exact recurrent scan), per arXiv:2405.04517.

Block-diagonal (per-head) q/k/v and recurrent projections follow the official
block design. All recurrences are numerically stabilized with a running max
state m. Decode state is O(1) per token, so long_500k decode runs.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ModelConfig
from repro.models import common
from repro.models.common import Spec

CHUNK = 256
NEG = -1e30


def _logsig(x):
    return -jax.nn.softplus(-x)


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_specs(cfg: ModelConfig) -> Dict[str, Spec]:
    d = cfg.d_model
    di = int(cfg.proj_factor_mlstm * d)
    h = cfg.n_heads
    dh = di // h
    return {
        "ln": Spec((d,), ("embed",), init="ones"),
        "w_up": Spec((d, 2, di), ("embed", None, "ssm_inner")),
        "conv_w": Spec((4, di), ("conv", "ssm_inner")),
        "wq": Spec((h, dh, dh), ("heads", "head_dim", None)),
        "wk": Spec((h, dh, dh), ("heads", "head_dim", None)),
        "wv": Spec((h, dh, dh), ("heads", "head_dim", None)),
        "w_i": Spec((di, h), ("ssm_inner", "heads"), init="small"),
        "w_f": Spec((di, h), ("ssm_inner", "heads"), init="small"),
        "b_i": Spec((h,), ("heads",), init="zeros"),
        "b_f": Spec((h,), ("heads",), init="ones"),
        "out_norm": Spec((di,), ("ssm_inner",), init="ones"),
        "w_down": Spec((di, d), ("ssm_inner", "embed")),
    }


def _mlstm_qkvif(cfg, p, x_conv, x_raw):
    """Per-head projections. x_*: (B,S,di). Returns q,k,v (B,S,H,dh); i,f (B,S,H)."""
    h = cfg.n_heads
    b, s, di = x_conv.shape
    dh = di // h
    xch = x_conv.reshape(b, s, h, dh)
    xrh = x_raw.reshape(b, s, h, dh)
    q = jnp.einsum("bshd,hde->bshe", xch, p["wq"])
    k = jnp.einsum("bshd,hde->bshe", xch, p["wk"]) / np.sqrt(dh)
    v = jnp.einsum("bshd,hde->bshe", xrh, p["wv"])
    i = jnp.einsum("bsi,ih->bsh", x_raw, p["w_i"]).astype(jnp.float32) + p["b_i"].astype(jnp.float32)
    f = jnp.einsum("bsi,ih->bsh", x_raw, p["w_f"]).astype(jnp.float32) + p["b_f"].astype(jnp.float32)
    return q, k, v, i, f


def _mlstm_chunk(carry, blk):
    """One chunk of the stabilized chunkwise mLSTM.

    carry: C (B,H,dh,dh), n (B,H,dh), m (B,H)  [true state = exp(m) * C]
    blk: q,k,v (B,c,H,dh) ; i,f (B,c,H)
    """
    C, n, m = carry
    q, k, v, i, f = blk
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    c = q.shape[1]
    logf = _logsig(f)                                            # (B,c,H)
    b_cum = jnp.cumsum(logf, axis=1)                             # (B,c,H)
    # D[t,s] = b_t - b_s + i_s   for s <= t
    D = b_cum[:, :, None] - b_cum[:, None, :] + i[:, None, :]    # (B,t,s,H)
    tri = jnp.tril(jnp.ones((c, c), bool))
    D = jnp.where(tri[None, :, :, None], D, NEG)
    m_intra = jnp.max(D, axis=2)                                 # (B,t,H)
    m_inter = b_cum + m[:, None]                                 # (B,t,H)
    m_t = jnp.maximum(jnp.maximum(m_intra, m_inter), -NEG * 0)   # (B,t,H)
    m_t = jnp.maximum(m_intra, m_inter)
    w = jnp.exp(D - m_t[:, :, None, :])                          # (B,t,s,H)
    scores = jnp.einsum("bthd,bshd->btsh", qf, kf)               # (B,t,s,H)
    y_intra = jnp.einsum("btsh,btsh,bshd->bthd", w, scores, vf)
    inter_scale = jnp.exp(m_inter - m_t)                         # (B,t,H)
    y_inter = jnp.einsum("bthd,bhde->bthe", qf, C) * inter_scale[..., None]
    n_t = jnp.einsum("btsh,bshd->bthd", w, kf) \
        + n[:, None] * inter_scale[..., None]                    # (B,t,H,dh)
    denom = jnp.maximum(jnp.abs(jnp.einsum("bthd,bthd->bth", n_t, qf)),
                        jnp.exp(-m_t))
    y = (y_intra + y_inter) / denom[..., None]                   # (B,t,H,dh)
    # ---- state update to end of chunk ----
    b_last = b_cum[:, -1]                                        # (B,H)
    dec = b_last[:, None] - b_cum + i                            # (B,s,H)
    m_new = jnp.maximum(b_last + m, jnp.max(dec, axis=1))        # (B,H)
    wC = jnp.exp(dec - m_new[:, None])                           # (B,s,H)
    # C stored k-major: C[d, e] = sum_s decay_s * k_s[d] * v_s[e], so queries
    # contract over the k dimension (first index)
    C_new = C * jnp.exp(b_last + m - m_new)[..., None, None] \
        + jnp.einsum("bsh,bshd,bshe->bhde", wC, kf, vf)
    n_new = n * jnp.exp(b_last + m - m_new)[..., None] \
        + jnp.einsum("bsh,bshd->bhd", wC, kf)
    return (C_new, n_new, m_new), y


def mlstm_apply(cfg: ModelConfig, p, x, mode: str, cache: Optional[dict]
                ) -> Tuple[jnp.ndarray, Optional[dict]]:
    b, s, d = x.shape
    hh = cfg.n_heads
    di = int(cfg.proj_factor_mlstm * d)
    dh = di // hh
    xn = common.rms_norm(x, p["ln"], cfg.norm_eps)
    proj = jnp.einsum("bsd,dzi->bszi", xn, p["w_up"])
    xm, z = proj[:, :, 0], proj[:, :, 1]
    # causal conv (kernel 4) on the mlstm branch; decode carries the tail
    k4 = p["conv_w"].shape[0]
    if mode == "decode" and cache is not None:
        pad = cache["conv"].astype(xm.dtype)
    else:
        pad = jnp.zeros((b, k4 - 1, di), xm.dtype)
    xp = jnp.concatenate([pad, xm], axis=1)
    conv_tail = xp[:, -(k4 - 1):]
    xc = jax.nn.silu(sum(xp[:, i:i + s] * p["conv_w"][i] for i in range(k4)))
    q, k, v, i_pre, f_pre = _mlstm_qkvif(cfg, p, xc, xm)

    if mode == "decode":
        C, n, m = (cache["C"].astype(jnp.float32),
                   cache["n"].astype(jnp.float32),
                   cache["m"].astype(jnp.float32))
        logf = _logsig(f_pre[:, 0])
        m_new = jnp.maximum(logf + m, i_pre[:, 0])
        fs = jnp.exp(logf + m - m_new)[..., None, None]
        is_ = jnp.exp(i_pre[:, 0] - m_new)[..., None, None]
        kf = k[:, 0].astype(jnp.float32)
        vf = v[:, 0].astype(jnp.float32)
        C_new = fs * C + is_ * jnp.einsum("bhd,bhe->bhde", kf, vf)
        n_new = fs[..., 0] * n + is_[..., 0] * kf
        qf = q[:, 0].astype(jnp.float32)
        num = jnp.einsum("bhd,bhde->bhe", qf, C_new)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n_new, qf)),
                          jnp.exp(-m_new))
        y = (num / den[..., None])[:, None]                      # (B,1,H,dh)
        new_cache = {"C": C_new.astype(cache["C"].dtype),
                     "n": n_new.astype(cache["n"].dtype),
                     "m": m_new.astype(cache["m"].dtype),
                     "conv": conv_tail.astype(cache["conv"].dtype)}
    else:
        c = min(CHUNK, s)
        assert s % c == 0
        nc = s // c

        def to_chunks(t):
            return t.reshape(b, nc, c, *t.shape[2:]).swapaxes(0, 1)

        carry0 = (jnp.zeros((b, hh, dh, dh), jnp.float32),
                  jnp.zeros((b, hh, dh), jnp.float32),
                  jnp.zeros((b, hh), jnp.float32))
        carry, ys = jax.lax.scan(
            _mlstm_chunk, carry0,
            tuple(map(to_chunks, (q, k, v, i_pre, f_pre))))
        y = ys.swapaxes(0, 1).reshape(b, s, hh, dh)
        new_cache = None
        if mode == "prefill":
            new_cache = {"C": carry[0].astype(jnp.float32),
                         "n": carry[1].astype(jnp.float32),
                         "m": carry[2].astype(jnp.float32),
                         "conv": conv_tail.astype(jnp.bfloat16)}
    y = y.reshape(b, -1, di).astype(x.dtype)
    y = common.rms_norm(y, p["out_norm"], cfg.norm_eps)
    y = y * jax.nn.silu(z)
    return x + jnp.einsum("bsi,id->bsd", y, p["w_down"]), new_cache


def mlstm_cache_shape(cfg: ModelConfig, batch: int):
    di = int(cfg.proj_factor_mlstm * cfg.d_model)
    h = cfg.n_heads
    dh = di // h
    return {"C": (batch, h, dh, dh), "n": (batch, h, dh), "m": (batch, h),
            "conv": (batch, 3, di)}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_specs(cfg: ModelConfig) -> Dict[str, Spec]:
    d = cfg.d_model
    h = cfg.n_heads
    dh = d // h
    ff = int(cfg.proj_factor_slstm * d)
    return {
        "ln": Spec((d,), ("embed",), init="ones"),
        "w_gates": Spec((d, 4, d), ("embed", None, None)),        # z,i,f,o
        "r_gates": Spec((4, h, dh, dh), (None, "heads", "head_dim", None),
                        init="small"),
        "b_gates": Spec((4, d), (None, None), init="zeros"),
        "ln_ff": Spec((d,), ("embed",), init="ones"),
        "ff_gate": Spec((d, ff), ("embed", "mlp")),
        "ff_up": Spec((d, ff), ("embed", "mlp")),
        "ff_down": Spec((ff, d), ("mlp", "embed")),
    }


def _slstm_cell_raw(n_heads, r_gates, b_gates, x_t, state):
    """One sLSTM step. x_t: (B,4,d) pre-projected gates; state: 4x (B,d)."""
    h_prev, c_prev, n_prev, m_prev = state
    b = x_t.shape[0]
    d = h_prev.shape[-1]
    dh = d // n_heads
    hp = h_prev.reshape(b, n_heads, dh)
    rec = jnp.einsum("ghde,bhd->gbhe", r_gates.astype(jnp.float32),
                     hp.astype(jnp.float32)).reshape(4, b, d)
    pre = x_t.astype(jnp.float32).swapaxes(0, 1) + rec \
        + b_gates.astype(jnp.float32)[:, None]
    z_pre, i_pre, f_pre, o_pre = pre[0], pre[1], pre[2], pre[3]
    z = jnp.tanh(z_pre)
    logf = _logsig(f_pre)
    m_t = jnp.maximum(logf + m_prev, i_pre)
    f_s = jnp.exp(logf + m_prev - m_t)
    i_s = jnp.exp(i_pre - m_t)
    c_t = f_s * c_prev + i_s * z
    n_t = f_s * n_prev + i_s
    h_t = jax.nn.sigmoid(o_pre) * c_t / jnp.maximum(n_t, 1e-6)
    return h_t, c_t, n_t, m_t


def _slstm_cell(cfg, p, x_t, state):
    return _slstm_cell_raw(cfg.n_heads, p["r_gates"], p["b_gates"],
                           x_t, state)


# ---------------------------------------------------------------------------
# sLSTM sequence with deferred recurrent-weight-grad reduction.
#
# A plain scan makes XLA emit an all-reduce of dR (the recurrent matrix
# gradient, partial over the sharded batch) at EVERY timestep of the
# backward while-loop (measured: 24576 ARs on train_4k = the entire
# collective cost of the cell). This custom VJP saves the state sequence in
# forward, accumulates dR/db LOCALLY in the backward scan carry, and lets
# the (single) cross-device reduction happen after the loop.
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _slstm_sequence(n_heads, r_gates, b_gates, gates_x, state0):
    """gates_x: (S, B, 4, d). Returns (ys (S,B,d), final state)."""
    def step(state, x_t):
        new = _slstm_cell_raw(n_heads, r_gates, b_gates, x_t, state)
        return new, new[0]

    final, ys = jax.lax.scan(step, state0, gates_x)
    return ys, final


def _slstm_pre(n_heads, r_gates, b_gates, x_t, h_prev):
    """Gate pre-activations: W x (precomputed) + R h_{t-1} + b. -> (4,B,d)."""
    b = x_t.shape[0]
    d = h_prev.shape[-1]
    hp = h_prev.reshape(b, n_heads, d // n_heads)
    rec = jnp.einsum("ghde,bhd->gbhe", r_gates.astype(jnp.float32),
                     hp.astype(jnp.float32)).reshape(4, b, d)
    return x_t.astype(jnp.float32).swapaxes(0, 1) + rec \
        + b_gates.astype(jnp.float32)[:, None]


def _slstm_post(pre, state):
    """State update given pre-activations. pre: (4,B,d)."""
    _, c_prev, n_prev, m_prev = state
    z = jnp.tanh(pre[0])
    logf = _logsig(pre[2])
    m_t = jnp.maximum(logf + m_prev, pre[1])
    f_s = jnp.exp(logf + m_prev - m_t)
    i_s = jnp.exp(pre[1] - m_t)
    c_t = f_s * c_prev + i_s * z
    n_t = f_s * n_prev + i_s
    h_t = jax.nn.sigmoid(pre[3]) * c_t / jnp.maximum(n_t, 1e-6)
    return h_t, c_t, n_t, m_t


def _slstm_seq_fwd(n_heads, r_gates, b_gates, gates_x, state0):
    def step(state, x_t):
        new = _slstm_cell_raw(n_heads, r_gates, b_gates, x_t, state)
        return new, new

    final, states_seq = jax.lax.scan(step, state0, gates_x)
    ys = states_seq[0]
    return (ys, final), (r_gates, b_gates, gates_x, state0, states_seq)


def _slstm_seq_bwd(n_heads, res, cots):
    """Backward scan emits per-step d_pre; ALL weight-gradient contractions
    over (seq, batch) happen once after the loop, so the sharded-batch
    reduction is a single all-reduce instead of one per timestep."""
    r_gates, b_gates, gates_x, state0, states_seq = res
    g_ys, g_final = cots
    s, bsz = gates_x.shape[0], gates_x.shape[1]
    d = gates_x.shape[-1]
    dh = d // n_heads
    rf = r_gates.astype(jnp.float32)

    def prev_state(t):
        return jax.tree.map(
            lambda seq, s0: jnp.where(t > 0, seq[jnp.maximum(t - 1, 0)], s0),
            states_seq, state0)

    def bwd_step(d_state, t):
        d_state = (d_state[0] + g_ys[t],) + tuple(d_state[1:])
        sp = prev_state(t)
        pre = _slstm_pre(n_heads, r_gates, b_gates, gates_x[t], sp[0])

        _, vjp_fn = jax.vjp(_slstm_post, pre, sp)
        d_pre, d_prev = vjp_fn(tuple(d_state))
        # h_{t-1} also feeds the recurrence: dh += R^T d_pre  (local einsum)
        dpg = d_pre.reshape(4, bsz, n_heads, dh)
        dh_prev = jnp.einsum("ghde,gbhe->bhd", rf, dpg).reshape(bsz, d)
        d_prev = (d_prev[0] + dh_prev,) + tuple(d_prev[1:])
        return d_prev, d_pre

    (d_prev), d_pre_rev = jax.lax.scan(
        bwd_step, tuple(g_final), jnp.arange(s - 1, -1, -1))
    d_pre_seq = d_pre_rev[::-1]                       # (S,4,B,d)

    # deferred weight-grad contractions: ONE reduction over (S, B)
    h_prev_seq = jnp.concatenate(
        [state0[0][None], states_seq[0][:-1]], axis=0)  # (S,B,d)
    hps = h_prev_seq.reshape(s, bsz, n_heads, dh)
    dps = d_pre_seq.reshape(s, 4, bsz, n_heads, dh)
    dR = jnp.einsum("sgbhe,sbhd->ghde", dps, hps.astype(jnp.float32))
    db = jnp.sum(d_pre_seq, axis=(0, 2))              # (4,d)
    dxs = d_pre_seq.swapaxes(1, 2)                    # (S,B,4,d)
    return (dR.astype(r_gates.dtype), db.astype(b_gates.dtype),
            dxs.astype(gates_x.dtype), d_prev)


_slstm_sequence.defvjp(_slstm_seq_fwd, _slstm_seq_bwd)


def slstm_apply(cfg: ModelConfig, p, x, mode: str, cache: Optional[dict]
                ) -> Tuple[jnp.ndarray, Optional[dict]]:
    b, s, d = x.shape
    xn = common.rms_norm(x, p["ln"], cfg.norm_eps)
    gates_in = jnp.einsum("bsd,dge->bsge", xn, p["w_gates"])      # (B,S,4,d)

    if cache is not None and mode == "decode":
        state = (cache["h"].astype(jnp.float32), cache["c"].astype(jnp.float32),
                 cache["n"].astype(jnp.float32), cache["m"].astype(jnp.float32))
        h_t, c_t, n_t, m_t = _slstm_cell(cfg, p, gates_in[:, 0], state)
        ys = h_t[:, None]
        new_cache = {"h": h_t.astype(cache["h"].dtype),
                     "c": c_t.astype(cache["c"].dtype),
                     "n": n_t.astype(cache["n"].dtype),
                     "m": m_t.astype(cache["m"].dtype)}
    else:
        zeros = jnp.zeros((b, d), jnp.float32)
        state0 = (zeros, zeros, zeros, zeros)
        ys, state = _slstm_sequence(cfg.n_heads, p["r_gates"], p["b_gates"],
                                    gates_in.swapaxes(0, 1), state0)
        ys = ys.swapaxes(0, 1)                                    # (B,S,d)
        new_cache = None
        if mode == "prefill":
            new_cache = {"h": state[0].astype(jnp.float32),
                         "c": state[1].astype(jnp.float32),
                         "n": state[2].astype(jnp.float32),
                         "m": state[3].astype(jnp.float32)}
    x = x + ys.astype(x.dtype)
    # post FFN (gated, pf ~4/3)
    xf = common.rms_norm(x, p["ln_ff"], cfg.norm_eps)
    ff = common.swiglu(xf, p["ff_gate"], p["ff_up"], p["ff_down"])
    return x + ff, new_cache


def slstm_cache_shape(cfg: ModelConfig, batch: int):
    d = cfg.d_model
    return {"h": (batch, d), "c": (batch, d), "n": (batch, d), "m": (batch, d)}


def is_mlstm_layer(cfg: ModelConfig, idx: int) -> bool:
    return idx % cfg.mlstm_every == 0
