"""Pallas TPU kernels for the perf-critical hot spots.

Each kernel subpackage ships three modules:
  <name>.py -- pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target)
  ops.py    -- thin public wrapper over the tunable-op registry (api.py)
  ref.py    -- pure-jnp oracle used by the allclose/bit-match test sweeps

Shared surface (see kernels/README.md):
  api.py   -- tunable-op registry: axes + defaults + clamp + ref per op,
              one dispatch (`api.call`) replacing the four copy-pasted
              interpret/use_ref entry points
  tuned.py -- persisted tuned-point cache (experiments/tuned/, JSON,
              keyed op|shape_key with a device-kind guard)
  tune.py  -- block/grid sweep harness driving core.autotune.tune_design
              over any registered op

Kernels:
  compact_pack -- chunk-aligned token-run compaction (the AutoComp rewrite
                  inner loop adapted to TPU: scalar-prefetched DMA gather)
                  + fused filter+pack (rewrite-deletes-as-compaction)
  flash_attn   -- causal GQA flash attention (training/prefill)
  decode_attn  -- flash-decode over a KV cache (single-token serving)
  rmsnorm      -- fused RMSNorm
"""

from jax.experimental.pallas import tpu as _pltpu

# renamed TPUCompilerParams -> CompilerParams across jax versions; kernels
# import this single shim instead of guarding per-module
CompilerParams = getattr(_pltpu, "CompilerParams", None) \
    or getattr(_pltpu, "TPUCompilerParams")
