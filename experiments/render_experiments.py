"""Render the EXPERIMENTS.md §Dry-run + §Roofline tables from the JSON
records in experiments/dryrun (baseline) and experiments/perf (variants)."""

import glob
import json
import os

HERE = os.path.dirname(__file__)


def load(d):
    recs = {}
    for p in sorted(glob.glob(os.path.join(HERE, d, "*.json"))):
        r = json.load(open(p))
        recs[os.path.basename(p)[:-5]] = r
    return recs


def fmt_mem(m):
    if not m or m.get("temp_size_in_bytes") is None:
        return "-"
    return f"{(m['temp_size_in_bytes'] or 0)/2**30:.1f}"


def roofline_table():
    recs = load("dryrun")
    lines = ["| arch | shape | mesh | compute_s | memory_s | collective_s |"
             " dominant | MODEL/HLO | frac | temp GiB/dev |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    order = sorted(recs.values(), key=lambda r: (r["arch"], r["shape"],
                                                 r["mesh"]))
    for r in order:
        if r.get("status") == "skip":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} |"
                         f" skip | — | — | — | — | — | — |")
            continue
        if r.get("status") != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} |"
                         f" ERROR | | | | | | |")
            continue
        rf = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} |"
            f" {rf['compute_s']:.4f} | {rf['memory_s']:.4f} |"
            f" {rf['collective_s']:.4f} | {rf['dominant'].replace('_s','')} |"
            f" {rf['useful_flops_ratio']:.3f} |"
            f" {rf['roofline_fraction']:.4f} |"
            f" {fmt_mem(r.get('memory_analysis'))} |")
    return "\n".join(lines)


def perf_table():
    recs = load("perf")
    lines = ["| cell | mesh | variant | compute_s | memory_s |"
             " collective_s | dominant | frac |",
             "|---|---|---|---|---|---|---|---|"]
    for name, r in sorted(recs.items()):
        if r.get("status") != "ok":
            continue
        rf = r["roofline"]
        variant = name.split("__")[-1] if name.count("__") >= 3 else "baseline"
        lines.append(
            f"| {r['arch']}/{r['shape']} | {r['mesh']} | {variant} |"
            f" {rf['compute_s']:.4f} | {rf['memory_s']:.4f} |"
            f" {rf['collective_s']:.4f} | {rf['dominant'].replace('_s','')} |"
            f" {rf['roofline_fraction']:.4f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    print("## Roofline (baseline, both meshes)\n")
    print(roofline_table())
    print("\n## Perf variants\n")
    print(perf_table())
