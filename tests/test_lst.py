"""LST substrate: commit protocol, conflicts, snapshot isolation, metadata
persistence, quotas."""

import pytest

from repro.lst import Catalog, CommitConflict, InMemoryStore
from repro.lst.files import DataFile
from repro.lst.workload import SimClock

MB = 1 << 20


def mk_table(granularity="table", partition_spec="p"):
    clock = SimClock()
    store = InMemoryStore()
    cat = Catalog(store, now_fn=clock.now)
    t = cat.create_table("ns", "t", partition_spec,
                         properties={"conflict_granularity": granularity})
    t.now_fn = clock.now
    return cat, t, store, clock


def df(t, i, size=MB, part=None):
    path = f"{t.table_id}/data/f{i}.bin"
    t.store.put(path, b"x" * 64)
    return DataFile(path, size, 100, part)


class TestCommitProtocol:
    def test_append_and_scan(self):
        _, t, store, _ = mk_table()
        t.append([df(t, i) for i in range(5)])
        assert t.file_count() == 5
        assert len(t.scan()) == 5

    def test_appends_always_rebase(self):
        _, t, _, _ = mk_table()
        txn1 = t.new_transaction().append_files([df(t, 1)])
        txn2 = t.new_transaction().append_files([df(t, 2)])
        txn2.commit()
        txn1.commit()           # stale base, but appends commute
        assert t.file_count() == 2

    def test_rewrite_conflicts_at_table_granularity(self):
        _, t, _, _ = mk_table("table")
        files = [df(t, i, part=f"p{i%2}") for i in range(4)]
        t.append(files)
        txn1 = t.new_transaction().rewrite_files(files[:2], [df(t, 10)], "p0")
        txn2 = t.new_transaction().rewrite_files(files[2:], [df(t, 11)], "p1")
        txn2.commit()
        with pytest.raises(CommitConflict):  # disjoint partitions STILL clash
            txn1.commit()

    def test_rewrite_ok_at_partition_granularity(self):
        _, t, _, _ = mk_table("partition")
        files = [df(t, i, part=f"p{i%2}") for i in range(4)]
        t.append(files)
        txn1 = t.new_transaction().rewrite_files(
            [f for f in files if f.partition == "p0"], [df(t, 10, part="p0")], "p0")
        txn2 = t.new_transaction().rewrite_files(
            [f for f in files if f.partition == "p1"], [df(t, 11, part="p1")], "p1")
        txn2.commit()
        txn1.commit()           # disjoint partitions commute under the fix
        assert t.file_count() == 2

    def test_snapshot_isolation(self):
        _, t, _, _ = mk_table()
        t.append([df(t, 1)])
        sid = t.meta.current_snapshot_id
        t.append([df(t, 2)])
        assert len(t.current_files(sid)) == 1    # old reader unaffected
        assert len(t.current_files()) == 2

    def test_version_monotonic_and_metadata_persisted(self):
        _, t, store, _ = mk_table()
        v0 = t.version
        t.append([df(t, 1)])
        assert t.version == v0 + 1
        metas = [p for p in store.list(f"{t.table_id}/metadata/")
                 if "v" in p.split("/")[-1]]
        assert len(metas) >= 2                   # metadata churn is real

    def test_expire_snapshots_removes_orphans(self):
        _, t, store, _ = mk_table()
        files = [df(t, i) for i in range(3)]
        t.append(files)
        t.rewrite(files, [df(t, 99)])
        before = store.object_count
        removed = t.expire_snapshots(keep_last=1)
        assert removed > 0
        assert store.object_count < before


class TestCatalogQuota:
    def test_quota_utilization(self):
        cat, t, _, _ = mk_table()
        ns = cat.namespaces["ns"]
        ns.total_quota = 10
        t.append([df(t, i) for i in range(5)])
        assert ns.used_quota() == 5
        assert ns.quota_utilization() == 0.5

    def test_write_listener_fires(self):
        cat, t, _, _ = mk_table()
        seen = []
        cat.add_write_listener(lambda tab: seen.append(tab.table_id))
        t.append([df(t, 1)])
        cat.notify_write(t)
        assert seen == [t.table_id]
