"""Paged decode-attention: page-pool data movement + the jnp oracle.

The paged slot cache stores K/V as fixed-size pages in a shared pool with
a per-slot page table; attention reads the dense per-row view back
*through* the table. Paging is pure data movement — ``gather_pages`` is
the exact inverse of ``pack_pages`` for every live position, and
positions whose table entry is unallocated (-1) return junk that decode
attention's per-row length mask sends to NEG_INF before the softmax
(exp underflows to exactly 0). That is why the page size is a provably
*exact* tunable axis: it regroups the gather, never the reduction.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.decode_attn.ref import decode_attention_ref


def pack_pages(x: jnp.ndarray, page: int):
    """Split a dense per-row array ``(B, S, ...)`` into a page pool
    ``(B * S // page, page, ...)`` plus its ``(B, S // page)`` page table.

    The pool order is a fixed non-identity permutation (reversed page
    order), so every consumer exercises a real gather rather than a
    reshape the compiler could elide; the permutation is deterministic,
    keeping tuned-point sweeps replayable.
    """
    b, s = x.shape[:2]
    if s % page != 0:
        raise ValueError(f"page size {page} must divide the cache length {s}")
    n = b * (s // page)
    pages = x.reshape((n, page) + x.shape[2:])
    perm = jnp.arange(n - 1, -1, -1, dtype=jnp.int32)
    # pool[j] = pages[perm[j]]; reversal is its own inverse, so the table
    # mapping dense page i -> pool index is the same permutation
    return pages[perm], perm.reshape(b, s // page)


def gather_pages(pool: jnp.ndarray, page_table: jnp.ndarray) -> jnp.ndarray:
    """Dense ``(B, S, ...)`` view of a page pool read through the page
    table. Unallocated entries (-1) clamp to pool page 0 — junk the
    caller's per-row length masks hide."""
    b, ppr = page_table.shape
    page = pool.shape[1]
    idx = jnp.clip(jnp.asarray(page_table, jnp.int32).reshape(-1), 0)
    g = jnp.take(pool, idx, axis=0)
    return g.reshape((b, ppr * page) + pool.shape[2:])


def paged_attention_ref(q, k, v, lengths, page: int = 256):
    """Oracle: page the dense K/V, read them back through the table, run
    reference decode attention. The roundtrip is exact, so this equals
    dense decode attention bit-for-bit for every page size."""
    kp, pt = pack_pages(k, page)
    vp, _ = pack_pages(v, page)
    return decode_attention_ref(q, gather_pages(kp, pt),
                                gather_pages(vp, pt), lengths)
