"""Public fused-RMSNorm wrapper (auto interpret on non-TPU backends)."""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels.rmsnorm.rmsnorm import rmsnorm_kernel
from repro.kernels.rmsnorm.ref import rmsnorm_ref


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("eps", "block_rows", "use_ref"))
def rmsnorm(x, scale, *, eps=1e-6, block_rows=256, use_ref=False):
    if use_ref:
        return rmsnorm_ref(x, scale, eps)
    orig = x.shape
    x2 = x.reshape(-1, orig[-1])
    out = rmsnorm_kernel(x2, scale, eps=eps,
                         block_rows=min(block_rows, x2.shape[0]),
                         interpret=_use_interpret())
    return out.reshape(orig)
