from repro.kernels.paged_attn.ops import paged_attention, tuned_page_size
from repro.kernels.paged_attn.ref import (
    gather_pages,
    pack_pages,
    paged_attention_ref,
)

__all__ = ["paged_attention", "paged_attention_ref", "pack_pages",
           "gather_pages", "tuned_page_size"]
