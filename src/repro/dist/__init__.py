"""Distribution layer: logical-axis sharding rules and compressed collectives.

``repro.dist.sharding`` maps *logical* axis names (declared on every
parameter ``Spec`` and every activation ``constrain`` call in
``repro.models``) onto *mesh* axes, with divisibility fallback so one rule
set serves every architecture and mesh shape. ``repro.dist.collectives``
provides blockwise-int8 compressed reductions with error feedback for
cross-pod gradient traffic.
"""

from repro.dist import collectives, sharding  # noqa: F401
from repro.dist.sharding import (  # noqa: F401
    PRESETS,
    axis_rules,
    constrain,
    mesh_axis_size,
    resolve_spec,
    tree_shardings,
)
