"""Qwen3-MoE-235B-A22B [hf:Qwen/Qwen3-30B-A3B family; moe].

94L d_model=4096 64H (GQA kv=4) per-expert d_ff=1536 vocab=151936,
MoE 128 experts top-8.
"""
from repro.configs import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    d_ff=0,
    d_ff_expert=1536,
    n_experts=128,
    top_k=8,
    vocab=151936,
    head_dim=64,
    rope_theta=1e6,
)
