"""Kernel microbenchmarks (interpret-mode correctness + host timing) and the
RewriteBytesPerHour calibration for the GBHr cost trait (§4.2): measured
throughput of the compact_pack merge path on this host feeds the cost model
the simulations use."""

from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np


def _time_us(fn, *args, iters=3) -> float:
    fn(*args)  # compile/warm
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def main(quick: bool = False) -> List[str]:
    """``quick=True`` is the CI smoke mode: every workload shrinks so the
    whole suite exercises each kernel path in seconds — timings are then
    smoke numbers, not calibration data."""
    rows = []
    key = jax.random.PRNGKey(0)

    # compact_pack: oracle timing at realistic size (kernel timing in
    # interpret mode is not meaningful for throughput; oracle == same math)
    from repro.kernels.compact_pack import compact_chunks, plan_compaction
    from repro.kernels.compact_pack.compact_pack import CHUNK_TOKENS
    n_chunks = 256 if quick else 2048
    src = jax.random.randint(key, (n_chunks * CHUNK_TOKENS,), 0, 1 << 30,
                             dtype=jnp.int32)
    cm = plan_compaction([64] * (n_chunks // 64),
                         fragment_order=list(reversed(range(n_chunks // 64))))
    us = _time_us(lambda s: compact_chunks(s, cm, use_ref=True), src)
    byts = n_chunks * CHUNK_TOKENS * 4
    bph = byts / (us / 1e6) * 3600
    rows.append(f"kernel_compact_pack_ref,{us:.0f},"
                f"bytes={byts};rewrite_bytes_per_hour={bph:.3e}")
    usk = _time_us(lambda s: compact_chunks(s, cm), src)
    rows.append(f"kernel_compact_pack_interpret,{usk:.0f},correctness_path")

    # flash attention: kernel-vs-ref correctness scale + host us
    from repro.kernels.flash_attn import flash_attention
    seq = 128 if quick else 512
    q = jax.random.normal(key, (1, 4, seq, 64), jnp.float32).astype(jnp.bfloat16)
    k = jax.random.normal(key, (1, 2, seq, 64), jnp.float32).astype(jnp.bfloat16)
    v = jax.random.normal(key, (1, 2, seq, 64), jnp.float32).astype(jnp.bfloat16)
    us_ref = _time_us(lambda a, b, c: flash_attention(a, b, c, use_ref=True),
                      q, k, v)
    us_k = _time_us(lambda a, b, c: flash_attention(a, b, c, block_q=128,
                                                    block_k=128), q, k, v)
    rows.append(f"kernel_flash_attn_ref,{us_ref:.0f},B1H4S{seq}D64")
    rows.append(f"kernel_flash_attn_interpret,{us_k:.0f},B1H4S{seq}D64")

    # decode attention
    from repro.kernels.decode_attn import decode_attention
    clen = 512 if quick else 2048
    qd = jax.random.normal(key, (4, 8, 64), jnp.float32).astype(jnp.bfloat16)
    kc = jax.random.normal(key, (4, clen, 2, 64), jnp.float32).astype(jnp.bfloat16)
    vc = jax.random.normal(key, (4, clen, 2, 64), jnp.float32).astype(jnp.bfloat16)
    lens = jnp.array([clen, clen // 2, clen // 4, 100], jnp.int32)
    us_ref = _time_us(lambda a, b, c, l: decode_attention(a, b, c, l,
                                                          use_ref=True),
                      qd, kc, vc, lens)
    us_k = _time_us(lambda a, b, c, l: decode_attention(a, b, c, l,
                                                        block_k=512),
                    qd, kc, vc, lens)
    rows.append(f"kernel_decode_attn_ref,{us_ref:.0f},B4S{clen}")
    rows.append(f"kernel_decode_attn_interpret,{us_k:.0f},B4S{clen}")

    # rmsnorm
    from repro.kernels.rmsnorm import rmsnorm
    rows_n = 512 if quick else 4096
    x = jax.random.normal(key, (rows_n, 1024), jnp.float32).astype(jnp.bfloat16)
    sc = jnp.ones((1024,), jnp.bfloat16)
    us_ref = _time_us(lambda a, b: rmsnorm(a, b, use_ref=True), x, sc)
    us_k = _time_us(lambda a, b: rmsnorm(a, b), x, sc)
    rows.append(f"kernel_rmsnorm_ref,{us_ref:.0f},R{rows_n}D1024")
    rows.append(f"kernel_rmsnorm_interpret,{us_k:.0f},R{rows_n}D1024")
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke mode: tiny shapes, seconds not minutes")
    for r in main(quick=ap.parse_args().quick):
        print(r)
