"""The CI bench-trajectory gate (scripts/bench_diff.py): synthetic
trajectories prove the bench-smoke job fails on an injected >=15%
collective_s (or roofline_fraction) regression, passes within tolerance,
and tolerates a missing baseline on the first run."""

import importlib.util
import json
import os

import pytest

_SPEC = importlib.util.spec_from_file_location(
    "bench_diff",
    os.path.join(os.path.dirname(__file__), "..", "scripts", "bench_diff.py"))
bench_diff = importlib.util.module_from_spec(_SPEC)
_SPEC.loader.exec_module(bench_diff)


def _rec(arch="paper-lm-100m", shape="train_4k", mesh="16x16",
         preset="baseline", grad_transport="bf16", act_transport=None,
         collective_s=0.1, roofline_fraction=0.5, status="ok",
         microbatches=8, remat_block=1, capacity_factor=1.25):
    return {
        "arch": arch, "shape": shape, "mesh": mesh, "preset": preset,
        "grad_transport": grad_transport, "act_transport": act_transport,
        "microbatches": microbatches, "remat_block": remat_block,
        "capacity_factor": capacity_factor,
        "status": status,
        "roofline": {"collective_s": collective_s,
                     "roofline_fraction": roofline_fraction},
    }


def _traj(path, records):
    with open(path, "w") as f:
        json.dump({"cells": len(records), "rows": [], "records": records}, f)
    return str(path)


class TestDiffTrajectories:
    def test_no_regression_within_threshold(self):
        base = [_rec(collective_s=0.100), _rec(shape="decode_32k",
                                               collective_s=0.060)]
        cur = [_rec(collective_s=0.110),   # +10% < 15%: fine
               _rec(shape="decode_32k", collective_s=0.055)]  # improvement
        res = bench_diff.diff_trajectories(cur, base, threshold=0.15)
        assert res["compared"] == 2
        assert res["regressions"] == []

    def test_collective_s_regression_fails(self):
        base = [_rec(collective_s=0.100)]
        cur = [_rec(collective_s=0.120)]   # +20% > 15%
        res = bench_diff.diff_trajectories(cur, base, threshold=0.15)
        assert len(res["regressions"]) == 1
        r = res["regressions"][0]
        assert r["metric"] == "collective_s"
        assert r["change"] == pytest.approx(0.20, abs=1e-6)

    def test_roofline_fraction_drop_fails(self):
        """Higher-is-better metric: a drop is the regression direction."""
        base = [_rec(roofline_fraction=0.50)]
        cur = [_rec(roofline_fraction=0.40)]   # -20%
        res = bench_diff.diff_trajectories(cur, base)
        assert [r["metric"] for r in res["regressions"]] \
            == ["roofline_fraction"]
        # and a roofline_fraction *gain* never trips the gate
        res2 = bench_diff.diff_trajectories([_rec(roofline_fraction=0.9)],
                                            base)
        assert res2["regressions"] == []

    def test_threshold_is_configurable(self):
        base = [_rec(collective_s=0.100)]
        cur = [_rec(collective_s=0.110)]
        assert bench_diff.diff_trajectories(cur, base,
                                            threshold=0.05)["regressions"]
        assert not bench_diff.diff_trajectories(cur, base,
                                                threshold=0.15)["regressions"]

    def test_cells_matched_by_full_variant_key(self):
        """An int8 serve cell never diffs against its bf16 sibling."""
        base = [_rec(shape="decode_32k", grad_transport=None,
                     act_transport="bf16", collective_s=0.060)]
        cur = [_rec(shape="decode_32k", grad_transport=None,
                    act_transport="int8", collective_s=0.090)]
        res = bench_diff.diff_trajectories(cur, base)
        assert res["compared"] == 0
        assert res["regressions"] == []
        assert len(res["only_current"]) == 1

    def test_hyperparameter_variants_never_collide(self):
        """mb/rb/cf sweeps of the same cell are distinct gate keys — a
        current mb4 cell must not diff against an mb8 baseline."""
        base = [_rec(microbatches=8, collective_s=0.100)]
        cur = [_rec(microbatches=4, collective_s=0.200)]
        res = bench_diff.diff_trajectories(cur, base)
        assert res["compared"] == 0 and res["regressions"] == []
        assert bench_diff.cell_key(_rec(remat_block=2)) \
            != bench_diff.cell_key(_rec(remat_block=1))
        assert bench_diff.cell_key(_rec(capacity_factor=2.0)) \
            != bench_diff.cell_key(_rec())

    def test_non_ok_and_malformed_cells_are_ignored(self):
        base = [_rec(collective_s=0.1),
                _rec(shape="prefill_8k", status="skip")]
        cur = [_rec(collective_s=0.1),
               _rec(shape="prefill_8k", status="error"),
               {"arch": "x", "status": "ok"}]      # no roofline dict
        res = bench_diff.diff_trajectories(cur, base)
        assert res["compared"] == 1
        assert res["regressions"] == []


def _disagg_rec(**roofline):
    r = _rec(shape="decode_32k", grad_transport=None, act_transport="bf16")
    r["roofline"].update(roofline)
    return r


class TestSlotStreamAndF8Keys:
    """The continuous-streaming / f8-arm roofline keys are first-class
    gate metrics: per-slot wire bytes and transfer time regress when they
    grow, overlap efficiency when it shrinks, the f8 storage arm like any
    other combo."""

    def test_all_new_keys_are_gated(self):
        for t in ("bf16", "int8"):
            assert bench_diff.METRICS[f"slot_stream_transfer_s_{t}"] \
                == "lower"
            assert bench_diff.METRICS[f"slot_stream_wire_bytes_{t}"] \
                == "lower"
            for s in ("bf16", "int8", "f8"):
                assert bench_diff.METRICS[f"disagg_collective_s_{t}x{s}"] \
                    == "lower"
                assert bench_diff.METRICS[
                    f"slot_stream_overlap_frac_{t}x{s}"] == "higher"
        assert bench_diff.METRICS["disagg_decode_step_s_f8"] == "lower"
        assert bench_diff.METRICS["disagg_tuned_collective_s"] == "lower"

    def test_overlap_frac_drop_fails(self):
        """Overlap efficiency is higher-is-better: transfer time that
        stops hiding behind decode steps is a regression."""
        base = [_disagg_rec(slot_stream_overlap_frac_int8xf8=0.40)]
        cur = [_disagg_rec(slot_stream_overlap_frac_int8xf8=0.30)]  # -25%
        res = bench_diff.diff_trajectories(cur, base)
        assert [r["metric"] for r in res["regressions"]] \
            == ["slot_stream_overlap_frac_int8xf8"]
        # a gain never trips the gate
        res2 = bench_diff.diff_trajectories(
            [_disagg_rec(slot_stream_overlap_frac_int8xf8=0.9)], base)
        assert res2["regressions"] == []

    def test_slot_wire_and_f8_decode_step_growth_fails(self):
        base = [_disagg_rec(slot_stream_wire_bytes_int8=1000,
                            disagg_decode_step_s_f8=0.010)]
        cur = [_disagg_rec(slot_stream_wire_bytes_int8=1300,   # +30%
                           disagg_decode_step_s_f8=0.013)]     # +30%
        res = bench_diff.diff_trajectories(cur, base)
        assert sorted(r["metric"] for r in res["regressions"]) \
            == ["disagg_decode_step_s_f8", "slot_stream_wire_bytes_int8"]


class TestDisappearedKeys:
    """A gated metric the baseline has but the current artifact lost must
    fail loudly — before this rule a renamed roofline key silently
    stopped being gated."""

    def test_disappeared_metric_fails(self):
        base = [_disagg_rec(disagg_collective_s_bf16xbf16=0.06,
                            slot_stream_wire_bytes_int8=1000)]
        cur = [_disagg_rec(slot_stream_wire_bytes_int8=1000)]
        res = bench_diff.diff_trajectories(cur, base)
        assert res["regressions"] == []
        assert [m["metric"] for m in res["missing_metrics"]] \
            == ["disagg_collective_s_bf16xbf16"]

    def test_metric_absent_from_both_sides_is_skipped(self):
        """Old baselines without the new keys stay comparable."""
        res = bench_diff.diff_trajectories([_disagg_rec()], [_disagg_rec()])
        assert res["compared"] == 1
        assert res["missing_metrics"] == []

    def test_new_metric_only_in_current_is_fine(self):
        """Sweeps legitimately grow: a key the baseline never had is not
        a disappearance."""
        res = bench_diff.diff_trajectories(
            [_disagg_rec(slot_stream_overlap_frac_int8xf8=0.4)],
            [_disagg_rec()])
        assert res["missing_metrics"] == [] and res["regressions"] == []

    def test_ungated_key_disappearing_is_ignored(self):
        base = [_disagg_rec(some_debug_number=1.0)]
        res = bench_diff.diff_trajectories([_disagg_rec()], base)
        assert res["missing_metrics"] == []

    def test_disappeared_metric_exits_nonzero(self, tmp_path):
        base = _traj(tmp_path / "base.json",
                     [_disagg_rec(disagg_collective_s_bf16xbf16=0.06)])
        cur = _traj(tmp_path / "cur.json", [_disagg_rec()])
        assert bench_diff.main(["--current", cur, "--baseline", base]) == 1


def _fleet_rec(shape="fleet_48t_3c", **roofline):
    r = {"arch": "fleet-sim", "shape": shape, "mesh": None,
         "preset": "fleet", "grad_transport": None, "act_transport": None,
         "microbatches": None, "remat_block": None, "capacity_factor": None,
         "status": "ok",
         "roofline": {"fleet_p99_query_s": 2.0,
                      "fleet_file_count_final": 5000.0,
                      "fleet_gbhr_total": 3.0,
                      "fleet_starvation_max_cycles": 2.0}}
    r["roofline"].update(roofline)
    return r


class TestFleetKeys:
    """The fleet-sim artifact keys are gated lower-is-better: the storm is
    seeded, so metric growth is a scheduler behavior change, not noise."""

    def test_fleet_keys_are_gated_lower(self):
        for m in ("fleet_p99_query_s", "fleet_file_count_final",
                  "fleet_gbhr_total", "fleet_starvation_max_cycles"):
            assert bench_diff.METRICS[m] == "lower"

    def test_p99_and_file_count_growth_fails(self):
        base = [_fleet_rec()]
        cur = [_fleet_rec(fleet_p99_query_s=2.6,          # +30%
                          fleet_file_count_final=6500.0)]  # +30%
        res = bench_diff.diff_trajectories(cur, base)
        assert sorted(r["metric"] for r in res["regressions"]) \
            == ["fleet_file_count_final", "fleet_p99_query_s"]

    def test_starvation_bound_growth_fails(self):
        """An aging-invariant break (max skip cycles up 2 -> 3) trips the
        gate even though every latency number held."""
        res = bench_diff.diff_trajectories(
            [_fleet_rec(fleet_starvation_max_cycles=3.0)], [_fleet_rec()])
        assert [r["metric"] for r in res["regressions"]] \
            == ["fleet_starvation_max_cycles"]

    def test_improvement_passes(self):
        res = bench_diff.diff_trajectories(
            [_fleet_rec(fleet_p99_query_s=1.0, fleet_file_count_final=3000.0,
                        fleet_gbhr_total=2.0)],
            [_fleet_rec()])
        assert res["regressions"] == [] and res["missing_metrics"] == []

    def test_smoke_and_sweep_cells_never_collide(self):
        """The shape encodes the fleet size: the PR-smoke 48-table cell
        must not diff against the nightly 2000-table storm."""
        base = [_fleet_rec(shape="fleet_2000t_4c",
                           fleet_file_count_final=400_000.0)]
        cur = [_fleet_rec(shape="fleet_48t_3c")]
        res = bench_diff.diff_trajectories(cur, base)
        assert res["compared"] == 0 and res["regressions"] == []

    def test_lost_fleet_key_fails(self, tmp_path):
        base = _traj(tmp_path / "base.json", [_fleet_rec()])
        rec = _fleet_rec()
        del rec["roofline"]["fleet_starvation_max_cycles"]
        cur = _traj(tmp_path / "cur.json", [rec])
        assert bench_diff.main(["--current", cur, "--baseline", base]) == 1


def _retention_rec(**roofline):
    r = _fleet_rec(shape="fleet_48t_3c_ret",
                   fleet_rows_dropped=1_000_000.0,
                   fleet_retention_bytes_rewritten=5e9)
    r["roofline"].update(roofline)
    return r


class TestRetentionKeys:
    """PR 8's retention cells: rows_dropped is gated HIGHER (a change that
    starves deletes shrinks it), tier-2 rewrite bytes LOWER (aligned
    deletes must stay metadata-only)."""

    def test_directions(self):
        assert bench_diff.METRICS["fleet_rows_dropped"] == "higher"
        assert bench_diff.METRICS["fleet_retention_bytes_rewritten"] \
            == "lower"

    def test_rows_dropped_shrinking_fails(self):
        res = bench_diff.diff_trajectories(
            [_retention_rec(fleet_rows_dropped=700_000.0)],   # -30%
            [_retention_rec()])
        assert [r["metric"] for r in res["regressions"]] \
            == ["fleet_rows_dropped"]

    def test_rewrite_bytes_growth_fails(self):
        """A router change that sends boundary-aligned deletes to tier-2
        rewrites shows up as byte growth and trips the gate."""
        res = bench_diff.diff_trajectories(
            [_retention_rec(fleet_retention_bytes_rewritten=7e9)],  # +40%
            [_retention_rec()])
        assert [r["metric"] for r in res["regressions"]] \
            == ["fleet_retention_bytes_rewritten"]

    def test_more_deletes_fewer_bytes_passes(self):
        res = bench_diff.diff_trajectories(
            [_retention_rec(fleet_rows_dropped=2_000_000.0,
                            fleet_retention_bytes_rewritten=1e9)],
            [_retention_rec()])
        assert res["regressions"] == []

    def test_retention_cell_is_its_own_lineage(self, tmp_path):
        """Turning --retention on starts a fresh `_ret` cell; the old
        non-retention cell disappearing entirely is NOT a lost-key
        failure (cells present on only one side never diff)."""
        base = _traj(tmp_path / "base.json",
                     [_fleet_rec(shape="fleet_48t_3c")])
        cur = _traj(tmp_path / "cur.json", [_retention_rec()])
        assert bench_diff.main(["--current", cur, "--baseline", base]) == 0


def _kernel_rec(shape="compact_pack:nsrc128_nout128:int32", **roofline):
    r = {"arch": "kernel", "shape": shape, "mesh": None,
         "preset": "kernel-quick", "grad_transport": None,
         "act_transport": None, "microbatches": None, "remat_block": None,
         "capacity_factor": None, "status": "ok",
         "roofline": {"kernel_compact_pack_default_s": 0.004,
                      "kernel_compact_pack_tuned_s": 0.001}}
    r["roofline"].update(roofline)
    return r


class TestKernelKeys:
    """Tunable-kernel cells (bench_kernels --json): the tuned step time per
    op and the fused filter path's time + plan-derived HBM traffic are
    gated lower-is-better."""

    def test_kernel_keys_are_gated_lower(self):
        for op in ("compact_pack", "flash_attn", "decode_attn",
                   "paged_attn", "rmsnorm", "expert_a2a"):
            assert bench_diff.METRICS[f"kernel_{op}_tuned_s"] == "lower"
        assert bench_diff.METRICS["kernel_compact_filter_s"] == "lower"
        assert bench_diff.METRICS["kernel_compact_filter_hbm_bytes"] \
            == "lower"

    def test_every_registered_op_has_a_gated_tuned_key(self):
        """New kernels registered on repro.kernels.api must join the
        bench gate — a registered op whose kernel_<op>_tuned_s key is
        absent from METRICS would emit ungated trajectory points."""
        from repro.kernels import api
        for name in api.ops():
            assert bench_diff.METRICS.get(f"kernel_{name}_tuned_s") \
                == "lower", name

    def test_expert_a2a_tuned_regression_fails(self):
        base = [_kernel_rec(kernel_expert_a2a_tuned_s=0.001)]
        cur = [_kernel_rec(kernel_expert_a2a_tuned_s=0.0013)]  # +30%
        res = bench_diff.diff_trajectories(cur, base)
        assert [r["metric"] for r in res["regressions"]] \
            == ["kernel_expert_a2a_tuned_s"]

    def test_tuned_regression_fails_default_drift_does_not(self):
        """The serving path reads the tuned point, so only the tuned
        trajectory gates; the default timing is context."""
        res = bench_diff.diff_trajectories(
            [_kernel_rec(kernel_compact_pack_tuned_s=0.0013)],  # +30%
            [_kernel_rec()])
        assert [r["metric"] for r in res["regressions"]] \
            == ["kernel_compact_pack_tuned_s"]
        res2 = bench_diff.diff_trajectories(
            [_kernel_rec(kernel_compact_pack_default_s=0.04)],
            [_kernel_rec()])
        assert res2["regressions"] == []

    def test_filter_hbm_bytes_growth_fails(self):
        """The HBM model is plan-derived (deterministic): a plan change
        that starts re-reading dropped rows must fail even if the
        stopwatch happens to be quiet."""
        fshape = "compact_filter:n128_drop50"
        base = [_kernel_rec(shape=fshape,
                            kernel_compact_filter_s=0.005,
                            kernel_compact_filter_hbm_bytes=786432.0)]
        cur = [_kernel_rec(shape=fshape,
                           kernel_compact_filter_s=0.005,
                           kernel_compact_filter_hbm_bytes=1800000.0)]
        res = bench_diff.diff_trajectories(cur, base)
        assert [r["metric"] for r in res["regressions"]] \
            == ["kernel_compact_filter_hbm_bytes"]

    def test_quick_and_full_presets_never_collide(self):
        base = [_kernel_rec()]
        cur = [_kernel_rec()]
        cur[0]["preset"] = "kernel-full"
        cur[0]["roofline"]["kernel_compact_pack_tuned_s"] = 0.9
        res = bench_diff.diff_trajectories(cur, base)
        assert res["compared"] == 0 and res["regressions"] == []

    def test_lost_tuned_key_fails(self, tmp_path):
        base = _traj(tmp_path / "base.json", [_kernel_rec()])
        rec = _kernel_rec()
        del rec["roofline"]["kernel_compact_pack_tuned_s"]
        cur = _traj(tmp_path / "cur.json", [rec])
        assert bench_diff.main(["--current", cur, "--baseline", base]) == 1


class TestFanInAndPagedKeys:
    """Fan-in arbitration and paged-slot-cache keys (decode cells,
    serve.fanin_report): admission wait, eviction count, and the paged
    table's live-page HBM rent are all lower-is-better — the simulation
    is seeded, so any drift is a queue-discipline or paging change."""

    def test_all_new_keys_are_gated_lower(self):
        for m in ("fanin_admission_wait_s", "fanin_evictions",
                  "paged_hbm_bytes_per_slot"):
            assert bench_diff.METRICS[m] == "lower"

    def test_admission_wait_growth_fails(self):
        base = [_disagg_rec(fanin_admission_wait_s=0.010)]
        cur = [_disagg_rec(fanin_admission_wait_s=0.013)]   # +30%
        res = bench_diff.diff_trajectories(cur, base)
        assert [r["metric"] for r in res["regressions"]] \
            == ["fanin_admission_wait_s"]

    def test_eviction_thrash_and_paged_rent_growth_fail(self):
        base = [_disagg_rec(fanin_evictions=4.0,
                            paged_hbm_bytes_per_slot=10000)]
        cur = [_disagg_rec(fanin_evictions=6.0,                # +50%
                           paged_hbm_bytes_per_slot=13000)]    # +30%
        res = bench_diff.diff_trajectories(cur, base)
        assert sorted(r["metric"] for r in res["regressions"]) \
            == ["fanin_evictions", "paged_hbm_bytes_per_slot"]
        # fewer evictions / smaller rent never trips the gate
        res2 = bench_diff.diff_trajectories(
            [_disagg_rec(fanin_evictions=1.0,
                         paged_hbm_bytes_per_slot=6000)], base)
        assert res2["regressions"] == []

    def test_lost_paged_key_fails(self, tmp_path):
        """A paging change that stops emitting the HBM-per-slot key must
        fail the gate, not silently drop out of it."""
        base = _traj(tmp_path / "base.json",
                     [_disagg_rec(paged_hbm_bytes_per_slot=10000)])
        cur = _traj(tmp_path / "cur.json", [_disagg_rec()])
        assert bench_diff.main(["--current", cur, "--baseline", base]) == 1


class TestMainGate:
    def test_missing_baseline_tolerated(self, tmp_path):
        cur = _traj(tmp_path / "cur.json", [_rec()])
        assert bench_diff.main(["--current", cur,
                                "--baseline",
                                str(tmp_path / "nope.json")]) == 0

    def test_unreadable_baseline_tolerated(self, tmp_path):
        cur = _traj(tmp_path / "cur.json", [_rec()])
        bad = tmp_path / "bad.json"
        bad.write_text("not json{")
        assert bench_diff.main(["--current", cur,
                                "--baseline", str(bad)]) == 0

    def test_missing_current_fails(self, tmp_path):
        base = _traj(tmp_path / "base.json", [_rec()])
        assert bench_diff.main(["--current", str(tmp_path / "nope.json"),
                                "--baseline", base]) == 1

    def test_regression_exits_nonzero(self, tmp_path):
        base = _traj(tmp_path / "base.json", [_rec(collective_s=0.100)])
        cur = _traj(tmp_path / "cur.json", [_rec(collective_s=0.130)])
        assert bench_diff.main(["--current", cur, "--baseline", base]) == 1

    def test_green_trajectory_passes(self, tmp_path):
        recs = [_rec(collective_s=0.100, roofline_fraction=0.5),
                _rec(shape="decode_32k", grad_transport=None,
                     act_transport="int8", collective_s=0.031)]
        base = _traj(tmp_path / "base.json", recs)
        cur = _traj(tmp_path / "cur.json", json.loads(json.dumps(recs)))
        assert bench_diff.main(["--current", cur, "--baseline", base]) == 0
