"""Unit coverage for repro.dist.collectives beyond the hypothesis bounds in
test_dist.py: zero blocks, ragged tails, and the compressed_psum carry API."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.collectives import (compressed_psum, dequantize_int8,
                                    quantize_int8)


class TestQuantize:
    def test_zero_vector_roundtrips_exactly(self):
        x = jnp.zeros((300,), jnp.float32)
        q, s = quantize_int8(x, block=128)
        assert q.dtype == jnp.int8
        out = dequantize_int8(q, s, 300)
        np.testing.assert_array_equal(np.asarray(out), 0.0)

    def test_ragged_tail_padding(self):
        rng = np.random.RandomState(0)
        x = jnp.asarray(rng.randn(1000), jnp.float32)   # 1000 % 256 != 0
        q, s = quantize_int8(x, block=256)
        assert q.shape == (4, 256) and s.shape == (4,)
        out = dequantize_int8(q, s, 1000)
        assert out.shape == (1000,)
        bound = float(jnp.max(jnp.abs(x))) / 127.0 + 1e-6
        assert float(jnp.max(jnp.abs(out - x))) <= bound

    def test_jit_compatible(self):
        x = jnp.linspace(-3.0, 3.0, 512)

        @jax.jit
        def roundtrip(v):
            q, s = quantize_int8(v, block=64)
            return dequantize_int8(q, s, v.shape[0])

        out = roundtrip(x)
        assert float(jnp.max(jnp.abs(out - x))) <= 3.0 / 127.0 + 1e-6


class TestCompressedPsum:
    def test_single_device_identity_with_error_feedback(self):
        """axis_name=None degenerates to quantize->dequantize; carrying the
        residual keeps the accumulated sum unbiased (DRAGONN-style EF)."""
        rng = np.random.RandomState(3)
        x = jnp.asarray(rng.randn(512), jnp.float32)
        err = jnp.zeros_like(x)
        acc = jnp.zeros_like(x)
        steps = 16
        for _ in range(steps):
            out, err = compressed_psum(x, None, err, block=64)
            acc = acc + out
        rel = float(jnp.linalg.norm(acc - steps * x)
                    / jnp.linalg.norm(steps * x))
        assert rel < 0.02

    def test_first_step_accepts_none_err(self):
        x = jnp.ones((64,), jnp.float32)
        out, err = compressed_psum(x, None, None, block=32)
        assert out.shape == x.shape and err.shape == x.shape

    def test_preserves_dtype_and_shape(self):
        x = jnp.ones((4, 32), jnp.bfloat16)
        out, err = compressed_psum(x, None, None, block=16)
        assert out.dtype == jnp.bfloat16 and out.shape == (4, 32)
        assert err.dtype == jnp.float32


class TestCacheStreamQuantizers:
    """Seq-axis blockwise quantization — the disagg cache-stream wire
    format (quantize on the prefill mesh, dequantize on arrival)."""

    def test_seqaxis_roundtrip_error_bound(self):
        from repro.dist.collectives import (dequantize_int8_seqaxis,
                                            quantize_int8_seqaxis)
        rng = np.random.RandomState(7)
        x = jnp.asarray(rng.randn(3, 8, 512, 2, 16), jnp.float32)  # seq=dim2
        q, s = quantize_int8_seqaxis(x, 2, block=256)
        assert q.dtype == jnp.int8 and q.shape == (3, 8, 2, 16, 512)
        assert s.shape == (3, 8, 2, 16, 2)          # 512 / 256 blocks
        out = dequantize_int8_seqaxis(q, s, 2)
        assert out.shape == x.shape
        # error <= half a quantization step of each block's abs-max
        step = jnp.moveaxis(jnp.repeat(s, 256, axis=-1), -1, 2)
        assert float(jnp.max(jnp.abs(out - x) - step / 2)) <= 1e-6

    def test_lastdim_blocks_fallback(self):
        from repro.dist.collectives import lastdim_blocks
        assert lastdim_blocks(512, 256) == (256, 2)
        assert lastdim_blocks(48, 256) == (48, 1)   # non-divisible: one block

    def test_stream_int8_identity_out_of_context(self):
        """Outside axis_rules, stream_int8 is pure quantize->dequantize:
        same values the real two-mesh transfer delivers."""
        from repro.dist.collectives import (dequantize_int8_seqaxis,
                                            quantize_int8_seqaxis,
                                            stream_int8)
        rng = np.random.RandomState(8)
        x = jnp.asarray(rng.randn(2, 64, 4), jnp.bfloat16)
        out = stream_int8(x, "batch", "kv_seq", None, seq_axis=1, block=32)
        assert out.dtype == x.dtype and out.shape == x.shape
        ref = dequantize_int8_seqaxis(
            *quantize_int8_seqaxis(x, 1, block=32), 1).astype(x.dtype)
        assert (out == ref).all()

    def test_all_gather_int8_passes_s8_through(self):
        """An int8-resident cache leaf must not be re-quantized by the
        int8 act transport — it crosses as-is."""
        from repro.dist.collectives import all_gather_int8
        q = jnp.asarray(np.arange(-8, 8, dtype=np.int8).reshape(4, 4))
        out = all_gather_int8(q, "batch", None)
        assert out.dtype == jnp.int8
        assert (out == q).all()

    def test_slot_stream_writes_one_row_and_matches_stream(self):
        """stream_slot_int8 == stream_int8 on the slice + a slot-row
        write: the admitted row carries exactly the wire-roundtripped
        slice, every other row is untouched."""
        from repro.dist.collectives import stream_int8, stream_slot_int8
        rng = np.random.RandomState(11)
        cache = jnp.asarray(rng.randn(2, 4, 64, 3), jnp.bfloat16)
        slc = jnp.asarray(rng.randn(2, 1, 64, 3), jnp.bfloat16)
        la = ("layers", "batch", "kv_seq", None)
        for slot in (0, 2, 3):
            out = stream_slot_int8(cache, slc, slot, *la, seq_axis=2,
                                   batch_axis=1, block=32)
            ref = stream_int8(slc, *la, seq_axis=2, block=32)
            assert (out[:, slot] == ref[:, 0]).all()
            keep = np.delete(np.asarray(out, np.float32), slot, axis=1)
            want = np.delete(np.asarray(cache, np.float32), slot, axis=1)
            np.testing.assert_array_equal(keep, want)

    def test_slot_stream_accepts_traced_slot(self):
        from repro.dist.collectives import stream_slot_int8
        cache = jnp.zeros((1, 3, 32, 2), jnp.bfloat16)
        slc = jnp.ones((1, 1, 32, 2), jnp.bfloat16)
        fn = jax.jit(lambda c, s, i: stream_slot_int8(
            c, s, i, "layers", "batch", "kv_seq", None,
            seq_axis=2, batch_axis=1, block=32))
        for slot in (0, 2):
            out = fn(cache, slc, jnp.asarray(slot, jnp.int32))
            got = np.asarray(out, np.float32)
            assert (got[:, slot] == 1.0).all()
            assert got.sum() == 32 * 2   # only that row written


class TestF8Storage:
    """Scale-free e4m3 cache storage: the cast clips to the finite f8
    range (e4m3fn overflows to nan, not inf) and the upcast is exact, so
    cast -> uncast -> cast is idempotent over the whole f8 domain."""

    def test_roundtrip_error_within_e4m3_precision(self):
        from repro.dist.collectives import cast_f8, uncast_f8
        rng = np.random.RandomState(13)
        x = jnp.asarray(rng.randn(4, 257) * 3, jnp.float32)
        out = np.asarray(uncast_f8(cast_f8(x)))
        # 3 mantissa bits: relative error <= 2^-4, plus the subnormal
        # floor near zero
        np.testing.assert_allclose(out, np.asarray(x),
                                   rtol=2 ** -4, atol=2 ** -9)

    def test_overflow_saturates_to_finite_max(self):
        from repro.dist.collectives import F8_MAX, cast_f8, uncast_f8
        x = jnp.asarray([1e4, -1e5, np.inf, -np.inf], jnp.float32)
        out = np.asarray(uncast_f8(cast_f8(x)))
        assert np.isfinite(out).all()
        np.testing.assert_array_equal(out, [F8_MAX, -F8_MAX,
                                            F8_MAX, -F8_MAX])

    def test_zero_roundtrips_exactly(self):
        from repro.dist.collectives import cast_f8, uncast_f8
        out = uncast_f8(cast_f8(jnp.zeros((16,), jnp.bfloat16)))
        np.testing.assert_array_equal(np.asarray(out), 0.0)

    def test_cast_uncast_idempotent_over_entire_f8_domain(self):
        """Exhaustive property: for every finite e4m3 bit pattern q,
        cast(uncast(q)) == q bit-for-bit — the storage write/read pair
        never drifts a resident value."""
        from repro.dist.collectives import F8_DTYPE, cast_f8, uncast_f8
        bits = jnp.arange(256, dtype=jnp.uint8)
        q = jax.lax.bitcast_convert_type(bits, F8_DTYPE)
        finite = ~jnp.isnan(uncast_f8(q))
        rt = jax.lax.bitcast_convert_type(cast_f8(uncast_f8(q)), jnp.uint8)
        same = np.asarray((rt == bits) | ~finite)
        assert same.all(), np.asarray(bits)[~same]

    def test_all_gather_int8_passes_f8_through(self):
        """An f8-resident cache leaf crosses the int8 act transport as-is
        — already 1 byte/element, re-quantizing would only add error."""
        from repro.dist.collectives import F8_DTYPE, all_gather_int8
        rng = np.random.RandomState(17)
        x = jnp.asarray(rng.randn(4, 8), jnp.float32).astype(F8_DTYPE)
        out = all_gather_int8(x, "batch", None)
        assert out.dtype == F8_DTYPE
        assert (jax.lax.bitcast_convert_type(out, jnp.uint8)
                == jax.lax.bitcast_convert_type(x, jnp.uint8)).all()

    def test_passthrough_property_s8_f8_identity_many_shapes(self):
        """Property over random shapes/values: for both compressed
        dtypes, the transport is the identity (bit-preserving)."""
        from repro.dist.collectives import F8_DTYPE, all_gather_int8
        rng = np.random.RandomState(19)
        for shape in [(3,), (2, 5), (2, 3, 4), (1, 1, 7, 3)]:
            s8 = jnp.asarray(
                rng.randint(-127, 128, size=shape), jnp.int8)
            axes = ("batch",) + (None,) * (len(shape) - 1)
            assert (all_gather_int8(s8, *axes) == s8).all()
            f8 = jnp.asarray(rng.randn(*shape), jnp.float32
                             ).astype(F8_DTYPE)
            out = all_gather_int8(f8, *axes)
            assert out.dtype == F8_DTYPE
            assert (jax.lax.bitcast_convert_type(out, jnp.uint8)
                    == jax.lax.bitcast_convert_type(f8, jnp.uint8)).all()
