#!/usr/bin/env python
"""Bench trajectory gate: diff a fresh ``BENCH_roofline.json`` against the
previous run's artifact and fail on performance regressions.

The CI ``bench-smoke`` job downloads the ``BENCH_roofline`` artifact from
the last successful main run and calls::

    python scripts/bench_diff.py --current BENCH_roofline.json \
        --baseline baseline/BENCH_roofline.json

Cells are matched by (arch, shape, mesh, preset, grad_transport,
act_transport). A cell regresses when a lower-is-better metric
(``collective_s``) grows, or a higher-is-better metric
(``roofline_fraction``) shrinks, by more than ``--threshold`` (default
15%). A missing/unreadable baseline is tolerated (first run, expired
artifact): the gate passes with a note. Cells present on only one side are
reported but never fail the gate — sweeps legitimately grow.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Any, Dict, List, Optional, Tuple

# metric -> direction: "lower" means growth is a regression, "higher"
# means shrinkage is
METRICS: Dict[str, str] = {
    "collective_s": "lower",
    "roofline_fraction": "higher",
    # disaggregated-decode design space (decode cells only; missing in
    # either record => skipped, so pre-disagg baselines stay comparable).
    # The per-batch transfer and per-token decode-step components are
    # gated individually: the combo sum is transfer-dominated, so a large
    # decode-step regression would hide inside it.
    "disagg_collective_s_bf16xbf16": "lower",
    "disagg_collective_s_bf16xint8": "lower",
    "disagg_collective_s_int8xbf16": "lower",
    "disagg_collective_s_int8xint8": "lower",
    "disagg_transfer_s_bf16": "lower",
    "disagg_transfer_s_int8": "lower",
    "disagg_decode_step_s_bf16": "lower",
    "disagg_decode_step_s_int8": "lower",
}

DEFAULT_THRESHOLD = 0.15


def cell_key(rec: Dict[str, Any]) -> Tuple:
    # every field that names a distinct dry-run variant must participate,
    # or variant cells silently collide and diff against the wrong baseline
    return (rec.get("arch"), rec.get("shape"), rec.get("mesh"),
            rec.get("preset"), rec.get("grad_transport"),
            rec.get("act_transport"), rec.get("microbatches"),
            rec.get("remat_block"), rec.get("capacity_factor"))


def _ok_cells(records: List[Dict[str, Any]]) -> Dict[Tuple, Dict[str, Any]]:
    return {cell_key(r): r for r in records
            if r.get("status") == "ok" and isinstance(r.get("roofline"), dict)}


def diff_trajectories(current: List[Dict[str, Any]],
                      baseline: List[Dict[str, Any]],
                      threshold: float = DEFAULT_THRESHOLD,
                      metrics: Optional[Dict[str, str]] = None
                      ) -> Dict[str, Any]:
    """Compare two record lists; returns {regressions, compared, only_*}.

    Each regression is ``{key, metric, baseline, current, change}`` with
    ``change`` the signed relative move in the bad direction (e.g. +0.30
    for a 30% collective_s growth).
    """
    metrics = METRICS if metrics is None else metrics
    cur = _ok_cells(current)
    base = _ok_cells(baseline)
    regressions: List[Dict[str, Any]] = []
    compared = 0
    for key, crec in cur.items():
        brec = base.get(key)
        if brec is None:
            continue
        compared += 1
        for metric, direction in metrics.items():
            cval = crec["roofline"].get(metric)
            bval = brec["roofline"].get(metric)
            if not isinstance(cval, (int, float)) \
                    or not isinstance(bval, (int, float)) or bval == 0:
                continue
            rel = (cval - bval) / abs(bval)
            bad = rel if direction == "lower" else -rel
            if bad > threshold:
                regressions.append({
                    "key": key, "metric": metric,
                    "baseline": bval, "current": cval,
                    "change": round(bad, 4),
                })
    return {
        "regressions": regressions,
        "compared": compared,
        "only_current": sorted(str(k) for k in cur.keys() - base.keys()),
        "only_baseline": sorted(str(k) for k in base.keys() - cur.keys()),
    }


def load_records(path: str) -> Optional[List[Dict[str, Any]]]:
    """Records list from a BENCH_roofline.json payload; None if unusable."""
    if not os.path.exists(path):
        return None
    try:
        with open(path) as f:
            payload = json.load(f)
        recs = payload.get("records") if isinstance(payload, dict) else None
        return recs if isinstance(recs, list) else None
    except (OSError, ValueError):
        return None


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--current", required=True,
                    help="fresh BENCH_roofline.json")
    ap.add_argument("--baseline", required=True,
                    help="previous run's BENCH_roofline.json "
                         "(missing => tolerated, gate passes)")
    ap.add_argument("--threshold", type=float, default=DEFAULT_THRESHOLD,
                    help="relative regression tolerance (default 0.15)")
    args = ap.parse_args(argv)

    current = load_records(args.current)
    if current is None:
        print(f"[bench-diff] FAIL: current trajectory {args.current!r} "
              "missing or unreadable")
        return 1
    baseline = load_records(args.baseline)
    if baseline is None:
        print(f"[bench-diff] no usable baseline at {args.baseline!r} "
              "(first run or expired artifact) — gate passes")
        return 0

    res = diff_trajectories(current, baseline, threshold=args.threshold)
    print(f"[bench-diff] compared {res['compared']} cells "
          f"(threshold {args.threshold:.0%}); "
          f"{len(res['only_current'])} new, "
          f"{len(res['only_baseline'])} baseline-only")
    for k in res["only_current"]:
        print(f"  new cell (not gated): {k}")
    for k in res["only_baseline"]:
        print(f"  dropped cell (not gated): {k}")
    if not res["regressions"]:
        print("[bench-diff] OK: no regression beyond threshold")
        return 0
    for r in res["regressions"]:
        print(f"  REGRESSION {r['key']}: {r['metric']} "
              f"{r['baseline']:.6g} -> {r['current']:.6g} "
              f"({r['change']:+.1%} in the bad direction)")
    print(f"[bench-diff] FAIL: {len(res['regressions'])} regression(s)")
    return 1


if __name__ == "__main__":
    sys.exit(main())
