"""Public flash-decode wrapper (auto interpret on non-TPU backends)."""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels.decode_attn.decode_attn import decode_attention_kernel
from repro.kernels.decode_attn.ref import decode_attention_ref


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("block_k", "use_ref"))
def decode_attention(q, k, v, lengths, *, block_k=512, use_ref=False):
    if use_ref:
        return decode_attention_ref(q, k, v, lengths)
    return decode_attention_kernel(q, k, v, lengths, block_k=block_k,
                                   interpret=_use_interpret())
