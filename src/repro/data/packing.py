"""Sequence packing + the compaction merge_fn for token shards.

``merge_shards_fn`` is what AutoComp's Act phase calls when the candidate is
a token-shard table: it concatenates the chunk-aligned payloads of the input
shards and runs the compact_pack Pallas kernel to produce the merged shard —
the measured RewriteBytesPerHour of this path calibrates the GBHr cost trait.

With ``filter_fn`` it becomes a rewrite-delete: deletes applied AT
compaction time, in the same pass, via the fused filter+pack kernel
(``compact_chunks(..., keep_mask=)``) — dropped rows never round-trip
through a second read. ``fused_filter=False`` routes the identical mask
through the two-pass filter-then-pack reference instead; the outputs are
bit-identical, only the HBM traffic differs.
"""

from __future__ import annotations

from typing import Callable, List, Optional, Sequence, Tuple, Union

import jax.numpy as jnp
import numpy as np

from repro.data import shards as sh
from repro.kernels.compact_pack import compact_chunks, plan_compaction
from repro.kernels.compact_pack.compact_pack import (
    CHUNK_COLS, CHUNK_ROWS, CHUNK_TOKENS)
from repro.lst.compaction import CompactionTask
from repro.lst.files import DataFile
from repro.lst.table import LogStructuredTable


def pack_tokens(stream: np.ndarray, batch: int, seq_len: int) -> np.ndarray:
    """Pack a flat token stream into (n_batches, batch, seq_len+1) slabs
    (the +1 provides next-token labels)."""
    per = batch * (seq_len + 1)
    n = stream.shape[0] // per
    return stream[: n * per].reshape(n, batch, seq_len + 1)


def valid_row_mask(counts: Sequence[int], lengths: Sequence[int]
                   ) -> np.ndarray:
    """Which 128-token rows of the padded, fragment-concatenated stream
    hold real tokens: fragment i occupies counts[i] chunks; its first
    ceil(lengths[i] / 128) rows are content, the rest padding."""
    total = sum(counts) * CHUNK_ROWS
    valid = np.zeros(total, bool)
    row0 = 0
    for c, ln in zip(counts, lengths):
        valid[row0: row0 + -(-ln // CHUNK_COLS)] = True
        row0 += c * CHUNK_ROWS
    return valid


def merge_shards_fn(table: LogStructuredTable, task: CompactionTask,
                    out_path: str,
                    filter_fn: Optional[Callable] = None,
                    fused_filter: bool = True
                    ) -> Union[DataFile, Tuple[DataFile, int]]:
    """Compaction merge for token shards (kernel-backed).

    ``filter_fn(rows, task) -> keep`` makes the merge a rewrite-delete at
    128-token-row granularity: ``rows`` is the (n_rows, 128) view of the
    packed stream, ``keep`` a bool mask over it. Padding rows (beyond each
    fragment's true length) are dropped regardless of the mask, so a
    filtered merge also squeezes out inter-fragment padding; a partially
    valid boundary row that the mask keeps is kept verbatim, trailing pad
    included. Returns (DataFile, rows_dropped) — dropped counts only
    content rows the FILTER removed, not padding.
    """
    payloads = []
    lengths = []
    for f in task.inputs:
        raw = table.store.get(f.path)
        payloads.append(sh.decode_shard_padded(raw))
        lengths.append(len(sh.decode_shard(raw)))
    flat = np.concatenate(payloads) if payloads else np.zeros(0, np.int32)
    counts = [p.shape[0] // CHUNK_TOKENS for p in payloads]
    chunk_map = plan_compaction(counts)

    if filter_fn is not None:
        # merge_shards_fn plans fragments in input order, so the packed
        # stream IS the concatenated stream and the row views coincide.
        rows = flat.reshape(-1, CHUNK_COLS) if flat.size else \
            np.zeros((0, CHUNK_COLS), np.int32)
        valid = valid_row_mask(counts, lengths)
        keep = np.asarray(filter_fn(rows, task), bool).reshape(-1) & valid
        merged = np.asarray(compact_chunks(
            jnp.asarray(flat), chunk_map, use_ref=not fused_filter,
            keep_mask=keep))
        tokens = merged[: int(keep.sum()) * CHUNK_COLS]
        raw = sh.encode_shard(tokens)
        table.store.put(out_path, raw)
        out = DataFile(path=out_path, size_bytes=len(raw),
                       num_rows=int(tokens.shape[0]), partition=task.scope,
                       created_at=table.now_fn())
        return out, int(valid.sum() - keep.sum())

    merged = np.asarray(compact_chunks(jnp.asarray(flat), chunk_map))
    # re-encode with the true concatenated length (drop inter-shard padding
    # bookkeeping: lengths are tracked per fragment)
    tokens = np.concatenate([
        merged[sum(c * CHUNK_TOKENS for c in counts[:i]):][:lengths[i]]
        for i in range(len(counts))]) if counts else merged[:0]
    raw = sh.encode_shard(tokens)
    table.store.put(out_path, raw)
    return DataFile(path=out_path, size_bytes=len(raw),
                    num_rows=int(tokens.shape[0]), partition=task.scope,
                    created_at=table.now_fn())
