"""Per-architecture smoke tests: a REDUCED config of each assigned family
runs one forward/train step on CPU (shape + finiteness asserts), plus
prefill->decode consistency for every decoder arch."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config, smoke_config
from repro.configs.shapes import SHAPES, ShapeSpec, applicable, make_batch
from repro.models import transformer
from repro.train import optimizer as opt_lib
from repro.train import step as step_lib

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def smoke_state():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = smoke_config(arch)
            cache[arch] = (cfg, transformer.init_params(cfg, KEY))
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ARCH_IDS)
class TestArchSmoke:
    def test_forward_train(self, arch, smoke_state):
        cfg, params = smoke_state(arch)
        batch, _ = make_batch(cfg, ShapeSpec("t", "train", 16, 2, 2), KEY)
        loss, metrics = transformer.forward(cfg, params, batch, "train")
        assert loss.shape == ()
        assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"
        assert float(loss) > 0

    def test_train_step_updates_params(self, arch, smoke_state):
        cfg, params = smoke_state(arch)
        opt = opt_lib.init_state(params)
        ts = step_lib.make_train_step(cfg, opt_lib.AdamWConfig(),
                                      microbatches=2)
        batch, _ = make_batch(cfg, ShapeSpec("t", "train", 16, 4, 2), KEY)
        new_params, new_opt, metrics = jax.jit(ts)(params, opt, batch)
        assert bool(jnp.isfinite(metrics["loss"]))
        assert int(new_opt["step"]) == 1
        # at least one big leaf actually moved
        moved = any(
            float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                  - b.astype(jnp.float32)))) > 0
            for a, b in zip(jax.tree.leaves(params),
                            jax.tree.leaves(new_params)))
        assert moved

    def test_decode_matches_prefill(self, arch, smoke_state):
        cfg, params = smoke_state(arch)
        if not cfg.supports_decode:
            pytest.skip("encoder-only")
        B, S = 2, 16
        toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab, dtype=jnp.int32)
        batch_full = {"tokens": toks}
        batch_pre = {"tokens": toks[:, :-1]}
        if cfg.frontend == "vit_patches":
            patches = jax.random.normal(
                KEY, (B, cfg.n_vision_tokens, transformer.VIT_HIDDEN),
                jnp.float32).astype(jnp.bfloat16)
            batch_full["patches"] = patches
            batch_pre["patches"] = patches
        full_logits, _ = transformer.forward(cfg, params, batch_full, "prefill")
        _, cache = transformer.forward(cfg, params, batch_pre, "prefill")
        nvis = cfg.n_vision_tokens if cfg.frontend == "vit_patches" else 0
        total = S + nvis
        target = transformer.abstract_cache(cfg, B, total)
        cache = jax.tree.map(
            lambda c, t: jnp.pad(
                c, [(0, tt - ss) for ss, tt in zip(c.shape, t.shape)]
            ).astype(t.dtype), cache, target)
        dec_logits, _ = transformer.forward(
            cfg, params,
            {"tokens": toks[:, -1:], "pos": jnp.asarray(total - 1, jnp.int32)},
            "decode", cache=cache, cache_len_total=total)
        err = float(jnp.max(jnp.abs(dec_logits.astype(jnp.float32)
                                    - full_logits.astype(jnp.float32))))
        scale = float(jnp.max(jnp.abs(full_logits.astype(jnp.float32)))) + 1e-9
        # MoE: dropped-token routing differs between prefill groups and the
        # single-token decode group => inherent small deviation
        tol = 0.12 if cfg.family == "moe" else 0.02
        assert err / scale < tol, f"{arch}: rel err {err/scale:.4f}"

    def test_encoder_encode_mode(self, arch, smoke_state):
        cfg, params = smoke_state(arch)
        if cfg.supports_decode:
            pytest.skip("decoder arch")
        batch, _ = make_batch(cfg, ShapeSpec("p", "prefill", 16, 2), KEY)
        logits, _ = transformer.forward(cfg, params, batch, "encode")
        assert logits.shape == (2, 16, cfg.vocab)
        assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


class TestFullConfigsAbstract:
    """Full (published) configs are exercised abstractly: parameter counts
    match the advertised sizes and input_specs are well-formed for every
    applicable (arch x shape) cell — no allocation."""

    EXPECTED_PARAMS = {
        "qwen3-moe-235b-a22b": (235e9, 0.10),
        "qwen3-moe-30b-a3b": (30e9, 0.12),
        "qwen1.5-110b": (110e9, 0.08),
        "yi-34b": (34e9, 0.08),
        "minicpm3-4b": (4e9, 0.25),
        "granite-3-8b": (8e9, 0.15),
        "hubert-xlarge": (1e9, 0.4),
        "hymba-1.5b": (1.5e9, 0.4),
        "internvl2-2b": (2e9, 0.25),
        "xlstm-125m": (125e6, 0.4),
    }

    @pytest.mark.parametrize("arch", ARCH_IDS)
    def test_param_count_matches_published(self, arch):
        cfg = get_config(arch)
        n = cfg.param_count()
        target, tol = self.EXPECTED_PARAMS[arch]
        assert abs(n - target) / target < tol, \
            f"{arch}: {n/1e9:.2f}B vs {target/1e9:.2f}B"

    @pytest.mark.parametrize("arch", ARCH_IDS)
    @pytest.mark.parametrize("shape_name", list(SHAPES))
    def test_input_specs_well_formed(self, arch, shape_name):
        from repro.configs.shapes import input_specs
        cfg = get_config(arch)
        shape = SHAPES[shape_name]
        ok, why = applicable(cfg, shape)
        if not ok:
            with pytest.raises(ValueError):
                input_specs(cfg, shape)
            return
        batch, cache = input_specs(cfg, shape)
        for sds in jax.tree.leaves(batch):
            assert all(d > 0 for d in sds.shape)
        if shape.kind == "decode":
            assert cache is not None

    def test_moe_active_params(self):
        cfg = get_config("qwen3-moe-235b-a22b")
        active = cfg.active_param_count()
        assert 18e9 < active < 26e9           # ~A22B
