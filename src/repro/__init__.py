"""repro: AutoComp-managed multi-pod JAX training/inference framework.

Layers:
  repro.lst      -- log-structured table substrate (Iceberg-semantics)
  repro.core     -- AutoComp: the paper's OODA compaction framework
  repro.data     -- tokenized data pipeline stored on LSTs
  repro.models   -- the 10 assigned architectures
  repro.kernels  -- Pallas TPU kernels (interpret-validated on CPU)
  repro.dist     -- mesh / logical sharding rules / collectives
  repro.train    -- optimizer, train/serve steps, checkpoints, runner
  repro.launch   -- mesh factory, multi-pod dry-run, train/serve drivers
  repro.configs  -- architecture configs + input shapes
"""

__version__ = "0.1.0"
