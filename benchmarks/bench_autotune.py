"""Fig. 9 — auto-tuning compaction triggers: iterations of threshold search
vs end-to-end duration, for small-file-count and entropy triggers, on
read-heavy (TPC-DS-WP1-like: benefits from compaction) and write-heavy
(TPC-H-like: compaction can be a net loss) profiles."""

from __future__ import annotations

from typing import List

from benchmarks.workload_sim import run_sim
from repro.core.autotune import tune_threshold


def main(hours: int = 3) -> List[str]:
    rows = []
    for profile in ("read_heavy", "write_heavy"):
        for trig, (lo, hi) in (("small_files", (50, 2000)),
                               ("entropy", (0.5, 6.0))):
            def objective(thr: float) -> float:
                return run_sim(strategy="table-10", trigger=trig,
                               threshold=thr, hours=hours, seed=3,
                               profile=profile)["duration_s"]

            res = tune_threshold(objective, lo, hi, coarse=3, refine_rounds=1)
            base = run_sim(strategy="none", hours=hours, seed=3,
                           profile=profile)["duration_s"]
            hist = "|".join(f"{t:.1f}:{d:.1f}" for t, d in res.history)
            rows.append(
                f"fig9_autotune[{profile};{trig}],{res.best_objective:.1f},"
                f"best_thr={res.best_threshold:.1f};no_comp={base:.1f};"
                f"iters={hist}")
    return rows


if __name__ == "__main__":
    for r in main():
        print(r)
