"""Fleet-scale compaction benchmark: ~2k tables under one GBHr budget.

Drives the Arc-style small-file storm (``FleetSpec``: a storm fraction
ingesting tens of files per write, bursty interactive tables, a cold long
tail) against the ``FleetScheduler`` for N cycles and reports the
end-state the nightly gate cares about:

  fleet_p99_query_s            p99 client read latency in the final cycle
                               (the small-file pain queries actually feel)
  fleet_file_count_final       total files across the fleet at the end
  fleet_gbhr_total             compaction compute actually spent
  fleet_starvation_max_cycles  worst aging any fragmented table saw

``--json`` writes a BENCH_roofline-shaped artifact ({"records": [...]})
whose cell key encodes the fleet size, so the PR-smoke small fleet and the
nightly 2k-table storm each keep their own regression lineage in
``scripts/bench_diff.py``.

CLI::

  PYTHONPATH=src python benchmarks/bench_fleet.py \
      --tables 2000 --cycles 4 --storm-frac 0.15 --budget 12 \
      --json BENCH_fleet.json
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Any, Dict, List, Optional

import numpy as np

if __package__ in (None, ""):               # `python benchmarks/bench_fleet.py`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

from benchmarks.workload_sim import make_fleet
from repro.lst.retention import PredicateDelete, RetentionPolicy
from repro.lst.workload import FleetSpec

MB = 1 << 20


def submit_retention_ops(fleet, catalog, fspec: FleetSpec) -> None:
    """The retention scenario: a standing fleet-wide TTL (routes to tier-1
    file drops every cycle) plus a one-shot GDPR-style predicate delete on
    every Nth table (routes to rewrite-deletes priced into the shared
    budget). The predicate hashes the stable synthetic row id, so the same
    ~selectivity of rows drops deterministically on every run."""
    fleet.submit_retention(RetentionPolicy(
        "ttl", max_age_hours=fspec.retention_max_age_hours))
    stride = max(1, fspec.gdpr_table_stride)
    tids = sorted(t.table_id for t in catalog.tables())[::stride]
    sel = fspec.gdpr_selectivity

    def gdpr_rows(rows, task, _s=sel):
        ids = np.asarray(rows)[:, 0].astype(np.int64)
        return ((ids * 2654435761) % (1 << 32)) < int(_s * (1 << 32))

    fleet.submit_delete(PredicateDelete(
        "gdpr-erasure", row_predicate=gdpr_rows, est_selectivity=sel,
        tables=tuple(tids)))


def run_fleet(n_tables: int = 200, cycles: int = 4, seed: int = 0,
              storm_fraction: float = 0.15, budget_gbhr: float = 12.0,
              starvation_cycles: int = 4,
              substeps: int = 1, retention: bool = False) -> Dict[str, Any]:
    fspec = FleetSpec(n_tables=n_tables, storm_fraction=storm_fraction,
                      tables_per_db=min(50, max(4, n_tables // 8)),
                      seed=seed)
    clock, catalog, gen, tracker, fleet = make_fleet(
        fspec, budget_gbhr=budget_gbhr,
        starvation_cycles=starvation_cycles)
    if retention:
        submit_retention_ops(fleet, catalog, fspec)

    per_cycle: List[Dict[str, Any]] = []
    last_read_lat: List[float] = []
    for cyc in range(cycles):
        events = gen.run_hour(substeps=substeps)
        tracker.record(events)
        rep = fleet.run_cycle()
        last_read_lat = sorted(e.latency for e in events
                               if e.kind == "read") or [0.0]
        per_cycle.append({
            "cycle": cyc + 1,
            "file_count": gen.total_file_count(),
            "candidates": rep.n_candidates,
            "selected": rep.n_selected,
            "spent_gbhr": rep.spent_gbhr,
            "gbhr": rep.gbhr,
            "files_removed": rep.files_removed,
            "max_skip_cycles": rep.max_skip_cycles,
            "class_counts": rep.class_counts,
            "rows_dropped": rep.rows_dropped,
            "files_dropped": rep.files_dropped,
            "wall_s": rep.wall_s,
        })

    def pct(lat: List[float], p: float) -> float:
        return lat[min(len(lat) - 1, int(p * len(lat)))]

    collectors = [p.stats for p in fleet.pipelines.values()]
    hits = sum(c.memo_hits for c in collectors)
    misses = sum(c.memo_misses for c in collectors)
    totals = fleet.totals()
    return {
        "n_tables": n_tables,
        "cycles": cycles,
        "seed": seed,
        "retention": retention,
        "fleet_rows_dropped": totals["rows_dropped"],
        "fleet_files_dropped": totals["files_dropped"],
        "fleet_retention_bytes_rewritten":
            totals["retention_bytes_rewritten"],
        "fleet_bytes_reclaimed": totals["bytes_reclaimed"],
        "per_cycle": per_cycle,
        "fleet_p99_query_s": pct(last_read_lat, 0.99),
        "fleet_p50_query_s": pct(last_read_lat, 0.50),
        "fleet_file_count_final": gen.total_file_count(),
        "fleet_small_frac_final": gen.small_file_fraction(
            fspec.target_file_mb * MB),
        "fleet_gbhr_total": totals["gbhr"],
        "fleet_starvation_max_cycles": totals["max_skip_cycles"],
        "fleet_files_removed_total": totals["files_removed"],
        "fleet_observe_memo_hit_rate":
            hits / max(1, hits + misses),
        "fleet_cycle_wall_s": float(np.mean(
            [p["wall_s"] for p in per_cycle])),
    }


# the roofline keys bench_diff gates for this cell
ARTIFACT_KEYS = ("fleet_p99_query_s", "fleet_file_count_final",
                 "fleet_gbhr_total", "fleet_starvation_max_cycles")


def to_record(res: Dict[str, Any]) -> Dict[str, Any]:
    """One BENCH_roofline-shaped record; the shape encodes the fleet size
    (and a ``_ret`` suffix for retention runs, which change file counts and
    spend — a separate lineage) so unlike runs never diff against each
    other."""
    roofline = {k: float(res[k]) for k in ARTIFACT_KEYS}
    roofline["fleet_small_frac_final"] = float(res["fleet_small_frac_final"])
    roofline["fleet_observe_memo_hit_rate"] = \
        float(res["fleet_observe_memo_hit_rate"])
    suffix = ""
    if res.get("retention"):
        # gated: a scheduler change that starves deletes shrinks
        # rows_dropped ("higher"); boundary-aligned drops must stay
        # metadata-only, so rewrite bytes regress upward ("lower")
        roofline["fleet_rows_dropped"] = float(res["fleet_rows_dropped"])
        roofline["fleet_retention_bytes_rewritten"] = float(
            res["fleet_retention_bytes_rewritten"])
        suffix = "_ret"
    return {
        "arch": "fleet-sim",
        "shape": f"fleet_{res['n_tables']}t_{res['cycles']}c{suffix}",
        "mesh": None, "preset": "fleet",
        "grad_transport": None, "act_transport": None,
        "microbatches": None, "remat_block": None, "capacity_factor": None,
        "status": "ok",
        "roofline": roofline,
    }


def main(n_tables: int = 64, cycles: int = 3, seed: int = 0) -> List[str]:
    """benchmarks.run entry point: small-fleet rows, CSV-ish."""
    res = run_fleet(n_tables=n_tables, cycles=cycles, seed=seed,
                    budget_gbhr=4.0)
    rows = [
        f"fleet_p99_query_s,{res['fleet_p99_query_s']:.4f},"
        f"tables={n_tables};cycles={cycles}",
        f"fleet_file_count_final,{res['fleet_file_count_final']},"
        f"small_frac={res['fleet_small_frac_final']:.3f}",
        f"fleet_gbhr_total,{res['fleet_gbhr_total']:.4f},"
        f"files_removed={res['fleet_files_removed_total']}",
        f"fleet_starvation_max_cycles,{res['fleet_starvation_max_cycles']},"
        f"bound=4",
        f"fleet_observe_memo_hit_rate,"
        f"{res['fleet_observe_memo_hit_rate']:.3f},"
        f"sub-linear re-observation",
    ]
    return rows


def cli(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--tables", type=int, default=200)
    ap.add_argument("--cycles", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--storm-frac", type=float, default=0.15)
    ap.add_argument("--budget", type=float, default=12.0,
                    help="shared GBHr budget per cycle")
    ap.add_argument("--starvation-cycles", type=int, default=4)
    ap.add_argument("--retention", action="store_true",
                    help="run the retention scenario: standing TTL + "
                         "one-shot GDPR delete through the fleet pool "
                         "(emits the fleet_rows_dropped / "
                         "fleet_retention_bytes_rewritten gated cells)")
    ap.add_argument("--json", default=None,
                    help="write a BENCH_roofline-shaped artifact here")
    args = ap.parse_args(argv)

    res = run_fleet(n_tables=args.tables, cycles=args.cycles,
                    seed=args.seed, storm_fraction=args.storm_frac,
                    budget_gbhr=args.budget,
                    starvation_cycles=args.starvation_cycles,
                    retention=args.retention)
    keys = ["fleet_p99_query_s", "fleet_file_count_final",
            "fleet_gbhr_total", "fleet_starvation_max_cycles",
            "fleet_small_frac_final", "fleet_observe_memo_hit_rate",
            "fleet_cycle_wall_s"]
    if args.retention:
        keys += ["fleet_rows_dropped", "fleet_files_dropped",
                 "fleet_retention_bytes_rewritten", "fleet_bytes_reclaimed"]
    for row in (f"{k},{res[k]}" for k in keys):
        print(row)
    if args.json:
        payload = {"cells": 1, "records": [to_record(res)],
                   "config": {"tables": args.tables, "cycles": args.cycles,
                              "seed": args.seed,
                              "storm_frac": args.storm_frac,
                              "budget_gbhr": args.budget,
                              "retention": args.retention}}
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"wrote {args.json}")
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(cli())
