"""Public compaction op: plan (host, numpy) + execute (Pallas / oracle).

``plan_compaction`` converts ragged fragment descriptors into the
chunk-permutation consumed by the kernel; ``compact_chunks`` executes it.
``compact_chunks(..., keep_mask=)`` is the fused filter+pack variant —
the kernel substrate for rewrite-deletes-as-compaction: the mask drops
128-token rows in ONE pass, fully-dropped chunks are never DMA'd, and the
output bit-matches the filter-then-pack reference. The data layer
(repro.data.packing) feeds real token shards through this.

Registered on the tunable-op registry (repro.kernels.api) as
``compact_pack`` with one axis, ``block_chunks``: the DMA gather
granularity. The wrapper coarsens the plan to the largest grouping <= the
tuned value that the chunk map supports (runs of consecutive chunks,
which fragment plans are), so a tuned point cached from one plan can
never mis-gather another — an unsupported grouping degrades to finer
blocks, deterministically.
"""

from __future__ import annotations

from functools import partial
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import api
from repro.kernels.compact_pack.compact_pack import (
    CHUNK_TOKENS, CHUNK_ROWS, CHUNK_COLS, DROP_SLOT,
    compact_chunks_kernel, compact_filter_kernel)
from repro.kernels.compact_pack.ref import (
    compact_chunks_ref, compact_filter_ref)

BLOCK_CHUNKS_CANDIDATES = (1, 2, 4, 8, 16)


def plan_compaction(fragment_chunk_counts: Sequence[int],
                    fragment_order: Sequence[int] | None = None
                    ) -> np.ndarray:
    """Host-side planning: fragments (each a run of chunks laid out
    back-to-back in the source buffer) -> output chunk map.

    fragment_chunk_counts[i]: chunks in source fragment i.
    fragment_order: output order of fragments (default: input order).
    """
    counts = np.asarray(fragment_chunk_counts, dtype=np.int64)
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    order = np.arange(len(counts)) if fragment_order is None \
        else np.asarray(fragment_order)
    out: List[np.ndarray] = [starts[f] + np.arange(counts[f]) for f in order]
    if not out:
        return np.zeros((0,), np.int32)
    return np.concatenate(out).astype(np.int32)


def coarsen_plan(chunk_map: np.ndarray, n_src: int, block_chunks: int
                 ) -> Tuple[int, np.ndarray]:
    """Largest grouping g <= block_chunks the plan supports.

    A group of g output chunks can ride one DMA block iff they map to a
    consecutive, g-aligned run of source chunks. Fragment plans are runs,
    so realistic maps coarsen well; any map degrades to g=1 (the seed
    behavior) rather than mis-gathering.
    """
    cm = np.asarray(chunk_map, dtype=np.int64)
    g = 1
    for cand in sorted(set(BLOCK_CHUNKS_CANDIDATES)):
        if cand <= g or cand > max(1, int(block_chunks)):
            continue
        if n_src % cand or cm.shape[0] % cand:
            continue
        grouped = cm.reshape(-1, cand)
        if (grouped[:, 0] % cand == 0).all() and \
           (grouped == grouped[:, :1] + np.arange(cand)).all():
            g = cand
    return g, (cm[::g] // g).astype(np.int32) if g > 1 \
        else cm.astype(np.int32)


def plan_filter(chunk_map: np.ndarray, keep_mask: np.ndarray
                ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray,
                           int]:
    """Host planning for the fused kernel: per-chunk keep counts -> the
    scalar-prefetch tables that drive the gather.

    keep_mask: (len(chunk_map) * CHUNK_ROWS,) bool over the rows of the
    *packed* (plan-order) stream, one flag per 128-token row.

    Returns (chunk_sel, dest, completed, out_idx, n_out); fully-dropped
    chunks simply do not appear in chunk_sel.
    """
    cm = np.asarray(chunk_map, dtype=np.int64)
    keep = np.asarray(keep_mask, dtype=bool).reshape(cm.shape[0], CHUNK_ROWS)
    kept_per_chunk = keep.sum(axis=1)
    touched = np.flatnonzero(kept_per_chunk > 0)
    k = kept_per_chunk[touched]
    n_kept = int(k.sum())
    n_out = -(-n_kept // CHUNK_ROWS)
    if n_kept == 0:
        z = np.zeros((0,), np.int32)
        return z, z, z, z, 0
    start = np.concatenate([[0], np.cumsum(k)[:-1]])   # kept rows before
    carry_in = start % CHUNK_ROWS
    completed = ((carry_in + k) >= CHUNK_ROWS).astype(np.int32)
    out_idx = (start // CHUNK_ROWS).astype(np.int32)
    keepr = keep[touched]
    rank = np.cumsum(keepr, axis=1) - keepr            # exclusive, per chunk
    dest = np.where(keepr, carry_in[:, None] + rank,
                    DROP_SLOT).astype(np.int32).reshape(-1)
    chunk_sel = cm[touched].astype(np.int32)
    if completed[-1] and n_kept % CHUNK_ROWS:
        # the last step completed a chunk AND spilled rows into the carry:
        # no step is assigned to the final partial output chunk yet.
        # Append a flush step (same source chunk re-read, every row
        # dropped) whose W[:8] write emits the carry zero-padded.
        chunk_sel = np.append(chunk_sel, chunk_sel[-1]).astype(np.int32)
        dest = np.append(dest, [DROP_SLOT] * CHUNK_ROWS).astype(np.int32)
        completed = np.append(completed, 0).astype(np.int32)
        out_idx = np.append(out_idx, n_out - 1).astype(np.int32)
    return (chunk_sel, dest, completed, out_idx, n_out)


@partial(jax.jit, static_argnames=("interpret",))
def _run(src3, chunk_map, interpret):
    return compact_chunks_kernel(src3, chunk_map, interpret=interpret)


@partial(jax.jit, static_argnames=("n_out", "interpret"))
def _run_filter(src3, chunk_sel, dest, completed, out_idx, n_out, interpret):
    return compact_filter_kernel(src3, chunk_sel, dest, completed, out_idx,
                                 n_out, interpret=interpret)


def _as_chunks(src_tokens: jnp.ndarray) -> jnp.ndarray:
    n = src_tokens.shape[0]
    assert n % CHUNK_TOKENS == 0, n
    return src_tokens.reshape(-1, CHUNK_ROWS, CHUNK_COLS)


def _run_pack(point: Dict[str, int], src_tokens: jnp.ndarray,
              chunk_map: np.ndarray,
              keep_mask: Optional[np.ndarray] = None) -> jnp.ndarray:
    src3 = _as_chunks(src_tokens)
    if keep_mask is not None:
        chunk_sel, dest, completed, out_idx, n_out = plan_filter(
            chunk_map, keep_mask)
        if n_out == 0:
            return jnp.zeros((0,), src_tokens.dtype)
        out = _run_filter(src3, jnp.asarray(chunk_sel),
                          jnp.asarray(dest), jnp.asarray(completed),
                          jnp.asarray(out_idx), n_out, api.use_interpret())
        return out.reshape(-1)
    g, cm = coarsen_plan(chunk_map, src3.shape[0],
                         point.get("block_chunks", 1))
    srcg = src3.reshape(-1, g * CHUNK_ROWS, CHUNK_COLS) if g > 1 else src3
    out = _run(srcg, jnp.asarray(cm, jnp.int32), api.use_interpret())
    return out.reshape(-1)


def _ref_pack(src_tokens: jnp.ndarray, chunk_map: np.ndarray,
              keep_mask: Optional[np.ndarray] = None) -> jnp.ndarray:
    src3 = _as_chunks(src_tokens)
    cm = jnp.asarray(np.asarray(chunk_map, np.int32))
    if keep_mask is not None:
        if not np.asarray(keep_mask, bool).any():
            return jnp.zeros((0,), src_tokens.dtype)
        return compact_filter_ref(src3, cm, keep_mask).reshape(-1)
    return compact_chunks_ref(src3, cm).reshape(-1)


def _clamp(point, src_tokens, chunk_map, keep_mask=None):
    n_out = max(1, int(np.asarray(chunk_map).shape[0]))
    return {"block_chunks": api.fit_block(point.get("block_chunks", 1),
                                          n_out)}


def _shape_key(src_tokens, chunk_map, keep_mask=None):
    n_src = src_tokens.shape[0] // CHUNK_TOKENS
    suffix = "_filter" if keep_mask is not None else ""
    return (f"nsrc{n_src}_nout{np.asarray(chunk_map).shape[0]}"
            f":{jnp.asarray(src_tokens).dtype.name}{suffix}")


def _example(quick: bool):
    n_chunks = 128 if quick else 1024
    frag = 16 if quick else 64
    src = (jnp.arange(n_chunks * CHUNK_TOKENS) % 971).astype(jnp.int32)
    cm = plan_compaction([frag] * (n_chunks // frag),
                         fragment_order=list(
                             reversed(range(n_chunks // frag))))
    return (src, cm), {}


api.register(api.TunableOp(
    name="compact_pack",
    axes={"block_chunks": BLOCK_CHUNKS_CANDIDATES},
    default={"block_chunks": 1},
    run=_run_pack,
    ref=_ref_pack,
    clamp=_clamp,
    shape_key=_shape_key,
    example=_example,
    exact_axes=frozenset({"block_chunks"}),   # pure data movement
    tol=0.0,
))


def compact_chunks(src_tokens: jnp.ndarray, chunk_map: np.ndarray,
                   use_ref: bool = False,
                   keep_mask: Optional[np.ndarray] = None,
                   block_chunks: Optional[int] = None) -> jnp.ndarray:
    """Compact a flat, CHUNK_TOKENS-aligned token buffer.

    src_tokens: (n_chunks * CHUNK_TOKENS,) -- aligned token buffer
    chunk_map:  (n_out,) int32
    keep_mask:  optional (n_out * CHUNK_ROWS,) bool over the packed
        128-token rows -- fused filter+pack: returns the kept rows dense,
        zero-padded to CHUNK_TOKENS alignment
    block_chunks: explicit DMA granularity override (else tuned/default)
    returns (n_out * CHUNK_TOKENS,) -- or (ceil(kept / CHUNK_ROWS) *
        CHUNK_TOKENS,) when filtering
    """
    if np.asarray(chunk_map).shape[0] == 0:
        return jnp.zeros((0,), src_tokens.dtype)
    point = None if block_chunks is None else {"block_chunks": block_chunks}
    return api.call("compact_pack", src_tokens, chunk_map,
                    keep_mask=keep_mask, point=point, use_ref=use_ref)
