"""Pure-jnp oracles for the compaction gather and the filter+pack path."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.kernels.compact_pack.compact_pack import CHUNK_COLS, CHUNK_ROWS


def compact_chunks_ref(src: jnp.ndarray, chunk_map: jnp.ndarray
                       ) -> jnp.ndarray:
    return jnp.take(src, chunk_map, axis=0)


def compact_filter_ref(src: jnp.ndarray, chunk_map: jnp.ndarray,
                       keep_mask: np.ndarray) -> jnp.ndarray:
    """Filter-then-pack reference: gather EVERY planned chunk, re-read the
    packed rows, drop the masked ones, zero-pad to chunk alignment. Two
    full passes over the data — exactly the HBM round-trip the fused
    kernel removes. Bit-identical output by construction.

    src: (n_src_chunks, CHUNK_ROWS, CHUNK_COLS)
    keep_mask: (len(chunk_map) * CHUNK_ROWS,) bool over the packed rows
    returns (ceil(n_kept / CHUNK_ROWS), CHUNK_ROWS, CHUNK_COLS)
    """
    keep = np.asarray(keep_mask, dtype=bool).reshape(-1)
    packed = jnp.take(src, chunk_map, axis=0)            # pass 1: pack all
    rows = packed.reshape(-1, CHUNK_COLS)                # pass 2: filter
    kept_idx = np.flatnonzero(keep)
    kept = jnp.take(rows, jnp.asarray(kept_idx, jnp.int32), axis=0)
    n_kept = kept_idx.size
    pad = (-n_kept) % CHUNK_ROWS
    if pad:
        kept = jnp.concatenate(
            [kept, jnp.zeros((pad, CHUNK_COLS), kept.dtype)], axis=0)
    return kept.reshape(-1, CHUNK_ROWS, CHUNK_COLS)
