"""Logical-axis-rule sharding (flax ``logical_axis_rules`` style, no flax).

Model code never names mesh axes. Parameters declare logical axes via
``Spec`` and activations pass them to :func:`constrain`; a *rule set* maps
each logical name to an ordered tuple of candidate mesh axes. Resolution is
mesh-aware:

- a candidate mesh axis that is absent from the mesh is skipped (the same
  ``baseline`` rules drive the local ``(data, model)`` mesh and the
  production ``(pod, data, model)`` mesh);
- a dimension that is not divisible by a candidate axis size stays
  unsharded on that axis (yi-34b's 56 heads on model=16 fall back to
  replicated rather than erroring);
- each mesh axis is used at most once per array (PartitionSpec invariant).

:func:`constrain` is a no-op outside an :func:`axis_rules` context so model
code runs unmodified in single-device tests, and lowers to
``with_sharding_constraint`` inside one.
"""

from __future__ import annotations

import threading
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# A rule maps one logical axis name to an ordered tuple of candidate mesh
# axes; a dimension takes every candidate (in order) that is present in the
# mesh, unused by this array, and divides the remaining dimension size.
Rules = Tuple[Tuple[str, Tuple[str, ...]], ...]

_WEIGHT_RULES: Rules = (
    ("embed", ("data",)),            # FSDP/ZeRO: weights sharded over data
    ("mlp", ("model",)),
    ("expert_mlp", ("model",)),
    ("experts", ("model",)),
    ("heads", ("model",)),
    ("kv_heads", ("model",)),
    ("vocab", ("model",)),
    ("ssm_inner", ("model",)),
    ("kv_lora", ("model",)),
    ("q_lora", ("model",)),
)

BASE_RULES: Rules = (("batch", ("pod", "data")),) + _WEIGHT_RULES

# Expert parallelism for the MoE configs: experts distribute over the data
# axis (each device holds whole experts — the classic EP layout; dispatch
# becomes an all-to-all over data) while the expert hidden dim keeps tensor
# parallelism over model. Baseline instead puts experts on the model axis,
# which starves the expert_mlp contraction of its axis.
_EP_RULES: Rules = (("batch", ("pod", "data")),) + tuple(
    (name, ("data",)) if name == "experts" else (name, targets)
    for name, targets in _WEIGHT_RULES)

# Pod-level FSDP: ZeRO weight shards span the pod axis too, so parameters
# and optimizer moments divide across the DCI before the data axis — 2x less
# state per chip on the 2x16x16 mesh at the cost of cross-pod all-gathers.
_FSDP_RULES: Rules = (("batch", ("pod", "data")),) + tuple(
    (name, ("pod", "data")) if name == "embed" else (name, targets)
    for name, targets in _WEIGHT_RULES)

# Sharded serving: sequence parallelism on the serve path. Prefill keeps
# the residual stream sequence-sharded over model between blocks ("seq_res",
# gathered at the attention/MLP boundary via collectives.act_gather); the
# KV cache shards over data (batch dim) x model (sequence dim, "kv_seq"),
# so decode's dominant collective is the cache all-gather feeding
# single-token attention — the gather the int8 act_transport compresses.
# Weights drop the FSDP embed shard (replicated over data, TP over model):
# serving is read-only, so a per-token weight regather would just dilute
# the wire with traffic HBM can hold resident. Ragged continuous batching
# is untouched: batch stays on data, per-row positions/masks are
# elementwise over batch.
_SERVE_SP_RULES: Rules = (("batch", ("pod", "data")),) + tuple(
    (name, ()) if name == "embed" else (name, targets)
    for name, targets in _WEIGHT_RULES) \
    + (("seq_res", ("model",)), ("kv_seq", ("model",)),
       ("slots", ("pod", "data")), ("pages", ("pod", "data")))

# Disaggregated decode: the batch-heavy layout for a dedicated decode mesh.
# serve_sp minus the sequence shards — the KV cache stays fully resident
# per batch shard ("kv_seq" unmapped, and the KV head/latent dims
# deliberately unmapped too so the cache never picks up a model-axis shard
# that would force a per-step regather), so single-token attention reads
# it with ZERO per-step cache collectives; the only decode wire left is
# the tiny tensor-parallel activation reduction behind the q/o
# projections (which keep "heads" -> model). The tradeoff vs serve_sp is
# cache HBM (replicated over model instead of sequence-sharded), which is
# exactly what the kv_storage="int8"/"f8" arms halve. Prefill never runs
# under this preset — it keeps serve_sp on its own compute-bound mesh and
# hands the cache over as a (quantized) stream, whole-batch or per slot.
# "slots" is the slot-table axis of continuous streaming: the decode
# cache's batch dim doubles as the slot dim, and the admission step
# (serve.make_slot_admit_step) constrains the written slot rows through
# this axis — mapped to the same mesh axes the batch occupies, so an
# admission touches exactly the slot row's home devices.
_SERVE_DECODE_RULES: Rules = (("batch", ("pod", "data")),) + tuple(
    (name, ()) if name in ("embed", "kv_heads", "kv_lora") else (name, targets)
    for name, targets in _WEIGHT_RULES) \
    + (("slots", ("pod", "data")), ("pages", ("pod", "data")))

# Named rule presets consumed by ``repro.launch.dryrun --preset``.
PRESETS: Dict[str, Rules] = {
    # data-parallel batch + FSDP weights + tensor-parallel contractions
    "baseline": BASE_RULES,
    # Megatron sequence parallelism: the residual-stream anchor
    # ("seq_res") additionally shards saved activations over model
    "sp": BASE_RULES + (("seq_res", ("model",)),),
    # pure data parallelism (weights replicated) — roofline control arm
    "ddp": (("batch", ("pod", "data", "model")),),
    # expert parallelism over data + tensor parallelism inside experts
    "ep": _EP_RULES,
    # pod-level FSDP: weight/moment shards cross the pod boundary
    "fsdp": _FSDP_RULES,
    # serve-side sequence parallelism: residual stream + KV cache over
    # model's sequence dim, batch over data (see Serving transport in
    # dist/README.md)
    "serve_sp": _SERVE_SP_RULES,
    # disaggregated decode mesh: batch over data, cache resident (no
    # sequence shard), TP over model — see Disaggregated serving in
    # dist/README.md
    "serve_decode": _SERVE_DECODE_RULES,
}

DEFAULT_RULES = PRESETS["baseline"]


def _axis_sizes(mesh) -> Dict[str, int]:
    """name -> size for ``jax.sharding.Mesh`` and ``AbstractMesh`` alike."""
    return dict(zip(mesh.axis_names, mesh.axis_sizes))


def _rule_map(rules: Optional[Rules]) -> Dict[str, Tuple[str, ...]]:
    out: Dict[str, Tuple[str, ...]] = {}
    for name, targets in (DEFAULT_RULES if rules is None else rules):
        if targets is None:
            out[name] = ()
        elif isinstance(targets, str):
            out[name] = (targets,)
        else:
            out[name] = tuple(targets)
    return out


def resolve_spec(shape: Sequence[int],
                 logical_axes: Sequence[Optional[str]],
                 mesh, rules: Optional[Rules] = None) -> P:
    """Resolve one array's logical axes to a ``PartitionSpec`` on ``mesh``."""
    if len(shape) != len(logical_axes):
        raise ValueError(f"rank mismatch: shape {tuple(shape)} vs "
                         f"logical axes {tuple(logical_axes)}")
    rmap = _rule_map(rules)
    sizes = _axis_sizes(mesh)
    used: set = set()
    entries: list = []
    for dim, name in zip(shape, logical_axes):
        targets = rmap.get(name, ()) if name is not None else ()
        chosen: list = []
        prod = 1
        for t in targets:
            if t not in sizes or t in used:
                continue
            if dim % (prod * sizes[t]) == 0:
                chosen.append(t)
                prod *= sizes[t]
        used.update(chosen)
        if not chosen:
            entries.append(None)
        elif len(chosen) == 1:
            entries.append(chosen[0])
        else:
            entries.append(tuple(chosen))
    while entries and entries[-1] is None:   # P(a, None) != P(a) in jax
        entries.pop()
    return P(*entries)


def spec_shard_count(spec: P, mesh) -> int:
    """Number of shards a resolved ``PartitionSpec`` splits an array into
    on ``mesh`` (per-device size = global size / this)."""
    sizes = _axis_sizes(mesh)
    n = 1
    for entry in spec:
        for ax in (entry if isinstance(entry, tuple) else (entry,)):
            if ax is not None:
                n *= sizes[ax]
    return n


def mesh_axis_size(name: str) -> int:
    """Size of mesh axis ``name`` in the active context (1 outside one)."""
    active = _current()
    if active is None:
        return 1
    mesh, _ = active
    return _axis_sizes(mesh).get(name, 1)


def tree_shardings(abs_tree: Any, axes_tree: Any, mesh,
                   rules: Optional[Rules] = None) -> Any:
    """Pytree of ``NamedSharding`` matching a pytree of abstract leaves.

    ``axes_tree`` mirrors ``abs_tree`` with a tuple of logical names at each
    leaf position (tuples are NOT traversed into — ``tree.map`` flattens up
    to ``abs_tree``'s leaves).
    """
    return jax.tree.map(
        lambda leaf, axes: NamedSharding(
            mesh, resolve_spec(leaf.shape, tuple(axes), mesh, rules)),
        abs_tree, axes_tree)


# ---------------------------------------------------------------------------
# context: activate (mesh, rules) for constrain() / mesh_axis_size()
# ---------------------------------------------------------------------------

class _Stack(threading.local):
    def __init__(self):
        self.items: list = []


_ctx = _Stack()


def _current():
    return _ctx.items[-1] if _ctx.items else None


class axis_rules:
    """``with axis_rules(mesh, rules): ...`` — re-entrant and reusable."""

    def __init__(self, mesh, rules: Optional[Rules] = None):
        self.mesh = mesh
        self.rules = DEFAULT_RULES if rules is None else rules

    def __enter__(self) -> "axis_rules":
        _ctx.items.append((self.mesh, self.rules))
        return self

    def __exit__(self, *exc) -> bool:
        _ctx.items.pop()
        return False


def constrain(x, *logical_axes: Optional[str]):
    """Annotate ``x`` with the resolved sharding; identity out of context."""
    active = _current()
    if active is None:
        return x
    mesh, rules = active
    spec = resolve_spec(x.shape, logical_axes, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
