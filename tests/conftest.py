"""Shared test bootstrap.

1. Puts ``src/`` on ``sys.path`` so the suite runs without PYTHONPATH.
2. Guards the optional ``hypothesis`` dependency: prefer the real package
   (installed via ``requirements-dev.txt``); fall back to the deterministic
   shim in ``_hypothesis_fallback.py``; and if even the shim cannot load,
   ``collect_ignore`` the hypothesis-based modules so collection never
   hard-errors on a missing optional dep (importorskip semantics).
3. Patches ``jax.sharding.AbstractMesh`` to accept the newer
   ``(axis_sizes, axis_names)`` signature on older jax (0.4.x takes a
   ``((name, size), ...)`` tuple) so mesh-metadata tests run on either.
"""

from __future__ import annotations

import importlib.util
import pathlib
import sys

_ROOT = pathlib.Path(__file__).resolve().parents[1]
_SRC = _ROOT / "src"
if str(_SRC) not in sys.path:
    sys.path.insert(0, str(_SRC))

# modules that import `hypothesis` at module scope
_HYPOTHESIS_MODULES = ["test_core_properties.py", "test_dist.py",
                       "test_fleet.py", "test_xlstm_vjp.py"]

collect_ignore: list = []


def _install_hypothesis_fallback() -> None:
    path = pathlib.Path(__file__).with_name("_hypothesis_fallback.py")
    spec = importlib.util.spec_from_file_location("_hypothesis_fallback", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    mod.install()


try:
    import hypothesis  # noqa: F401
except ImportError:
    try:
        _install_hypothesis_fallback()
    except Exception:  # last resort: skip, never a collection error
        collect_ignore += _HYPOTHESIS_MODULES


def _patch_abstract_mesh() -> None:
    import jax.sharding as jsh

    try:
        jsh.AbstractMesh((1,), ("x",))
        return                            # jax already takes (sizes, names)
    except TypeError:
        pass

    _Orig = jsh.AbstractMesh

    class AbstractMesh(_Orig):
        def __init__(self, axis_sizes, axis_names=None, **kwargs):
            if axis_names is not None:
                super().__init__(tuple(zip(axis_names, axis_sizes)), **kwargs)
            else:                         # old-style ((name, size), ...)
                super().__init__(axis_sizes, **kwargs)

    jsh.AbstractMesh = AbstractMesh


_patch_abstract_mesh()
