"""Metered object stores — the HDFS/ADLS stand-in.

All reads/writes of data files AND metadata go through an ObjectStore, which
meters the NameNode-pressure observables from §2/§7 of the paper: object
count, open()/create()/delete() RPCs, bytes moved. Benchmarks read these
counters to reproduce Fig. 10c (file count over time) and Fig. 11b (open()
calls).
"""

from __future__ import annotations

import os
import threading
import time
from typing import Dict, Iterable, List, Optional


class StoreMetrics:
    def __init__(self) -> None:
        self.open_calls = 0
        self.create_calls = 0
        self.delete_calls = 0
        self.list_calls = 0
        self.bytes_read = 0
        self.bytes_written = 0

    @property
    def rpc_total(self) -> int:
        return (self.open_calls + self.create_calls + self.delete_calls
                + self.list_calls)

    def snapshot(self) -> Dict[str, int]:
        return {k: getattr(self, k) for k in
                ("open_calls", "create_calls", "delete_calls", "list_calls",
                 "bytes_read", "bytes_written")} | {"rpc_total": self.rpc_total}


class ObjectStore:
    """Abstract metered object store."""

    def __init__(self) -> None:
        self.metrics = StoreMetrics()
        self._lock = threading.RLock()

    # -- interface -----------------------------------------------------------
    def put(self, path: str, data: bytes) -> None:
        raise NotImplementedError

    def get(self, path: str) -> bytes:
        raise NotImplementedError

    def delete(self, path: str) -> None:
        raise NotImplementedError

    def exists(self, path: str) -> bool:
        raise NotImplementedError

    def list(self, prefix: str = "") -> List[str]:
        raise NotImplementedError

    @property
    def object_count(self) -> int:
        raise NotImplementedError

    def count(self, prefix: str) -> int:
        return len([p for p in self.list(prefix)])


class InMemoryStore(ObjectStore):
    def __init__(self) -> None:
        super().__init__()
        self._objects: Dict[str, bytes] = {}

    def put(self, path: str, data: bytes) -> None:
        with self._lock:
            self.metrics.create_calls += 1
            self.metrics.bytes_written += len(data)
            self._objects[path] = bytes(data)

    def get(self, path: str) -> bytes:
        with self._lock:
            self.metrics.open_calls += 1
            if path not in self._objects:
                raise FileNotFoundError(path)
            data = self._objects[path]
            self.metrics.bytes_read += len(data)
            return data

    def delete(self, path: str) -> None:
        with self._lock:
            self.metrics.delete_calls += 1
            self._objects.pop(path, None)

    def exists(self, path: str) -> bool:
        with self._lock:
            return path in self._objects

    def list(self, prefix: str = "") -> List[str]:
        with self._lock:
            self.metrics.list_calls += 1
            return sorted(p for p in self._objects if p.startswith(prefix))

    @property
    def object_count(self) -> int:
        return len(self._objects)

    def size_of(self, path: str) -> int:
        return len(self._objects[path])


class LocalFSStore(ObjectStore):
    """On-disk store (used by the end-to-end training example)."""

    def __init__(self, root: str) -> None:
        super().__init__()
        self.root = root
        os.makedirs(root, exist_ok=True)
        self._index: set = set()
        for dirpath, _, files in os.walk(root):
            for f in files:
                rel = os.path.relpath(os.path.join(dirpath, f), root)
                self._index.add(rel)

    def _abs(self, path: str) -> str:
        return os.path.join(self.root, path)

    def put(self, path: str, data: bytes) -> None:
        with self._lock:
            self.metrics.create_calls += 1
            self.metrics.bytes_written += len(data)
            ap = self._abs(path)
            os.makedirs(os.path.dirname(ap), exist_ok=True)
            tmp = ap + ".tmp"
            with open(tmp, "wb") as f:
                f.write(data)
            os.replace(tmp, ap)          # atomic publish
            self._index.add(path)

    def get(self, path: str) -> bytes:
        with self._lock:
            self.metrics.open_calls += 1
            try:
                with open(self._abs(path), "rb") as f:
                    data = f.read()
            except OSError as e:
                raise FileNotFoundError(path) from e
            self.metrics.bytes_read += len(data)
            return data

    def delete(self, path: str) -> None:
        with self._lock:
            self.metrics.delete_calls += 1
            try:
                os.remove(self._abs(path))
            except OSError:
                pass
            self._index.discard(path)

    def exists(self, path: str) -> bool:
        return path in self._index or os.path.exists(self._abs(path))

    def list(self, prefix: str = "") -> List[str]:
        with self._lock:
            self.metrics.list_calls += 1
            return sorted(p for p in self._index if p.startswith(prefix))

    @property
    def object_count(self) -> int:
        return len(self._index)
