"""Execution triggers (§5): periodic ("pull") and optimize-after-write
("push").

Optimize-after-write supports both variants from the paper:
  * immediate: if a trait crosses its threshold right after a write, run
    compaction for that candidate now (unconstrained-budget regime);
  * decoupled: the hook only marks the candidate dirty; the standalone
    service recalculates traits and schedules within its budget.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Set

from repro.core.decide import ThresholdPolicy
from repro.core.model import Candidate, Scope
from repro.lst.catalog import Catalog
from repro.lst.table import LogStructuredTable


@dataclasses.dataclass
class PeriodicTrigger:
    """Fire every ``interval_hours`` of logical time."""
    interval_hours: float
    now_fn: Callable[[], float]
    last_fired: float = float("-inf")

    def should_fire(self) -> bool:
        return (self.now_fn() - self.last_fired) >= self.interval_hours

    def mark_fired(self) -> None:
        self.last_fired = self.now_fn()


class OptimizeAfterWriteHook:
    """Engine-side hook: registered as a catalog write listener."""

    def __init__(self, catalog: Catalog,
                 policy: Optional[ThresholdPolicy] = None,
                 observe_fn: Optional[Callable] = None,
                 immediate_fn: Optional[Callable] = None) -> None:
        self.catalog = catalog
        self.policy = policy
        self.observe_fn = observe_fn      # candidate -> stats+traits
        self.immediate_fn = immediate_fn  # candidate -> compact now
        self.dirty: Set[str] = set()
        self.fired: List[str] = []
        catalog.add_write_listener(self.on_write)

    def on_write(self, table: LogStructuredTable) -> None:
        self.dirty.add(table.table_id)
        if self.policy is None or self.observe_fn is None:
            return
        cand = Candidate(table, Scope.TABLE)
        self.observe_fn(cand)
        if self.policy.triggered(cand):
            self.fired.append(table.table_id)
            if self.immediate_fn is not None:
                self.immediate_fn(cand)

    def drain_dirty(self) -> Set[str]:
        d, self.dirty = self.dirty, set()
        return d
