"""Public fused-RMSNorm wrapper, registered on the tunable-op registry.

``block_rows`` only tiles independent rows — each row's variance and
scale never see another row — so it is an exact axis: any value yields
bit-identical output, and the tuned point is purely a data-movement
choice. Clamped divisor-safe to the (flattened) row count, which also
fixes the pre-registry gap where ``min(block_rows, r)`` could still trip
the ``r % br == 0`` grid assert on a non-dividing shorter shape.
"""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels import api
from repro.kernels.rmsnorm.rmsnorm import DEFAULT_BLOCK_ROWS, rmsnorm_kernel
from repro.kernels.rmsnorm.ref import rmsnorm_ref

BLOCK_ROWS_CANDIDATES = (64, 128, 256, 512, 1024)


@partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def _run_jit(x2, scale, *, eps, block_rows, interpret):
    return rmsnorm_kernel(x2, scale, eps=eps, block_rows=block_rows,
                          interpret=interpret)


def _run(point, x2, scale, *, eps=1e-6):
    return _run_jit(x2, scale, eps=eps, block_rows=point["block_rows"],
                    interpret=api.use_interpret())


def _ref(x2, scale, *, eps=1e-6):
    return rmsnorm_ref(x2, scale, eps)


def _clamp(point, x2, scale, **kw):
    return {"block_rows": api.fit_block(point["block_rows"], x2.shape[0])}


def _shape_key(x2, scale, **kw):
    return f"r{x2.shape[0]}d{x2.shape[1]}:{x2.dtype.name}"


def _example(quick: bool):
    import jax.numpy as jnp
    r = 512 if quick else 4096
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (r, 1024), jnp.float32).astype(jnp.bfloat16)
    sc = jnp.ones((1024,), jnp.bfloat16)
    return (x, sc), {}


api.register(api.TunableOp(
    name="rmsnorm",
    axes={"block_rows": BLOCK_ROWS_CANDIDATES},
    default={"block_rows": DEFAULT_BLOCK_ROWS},
    run=_run,
    ref=_ref,
    clamp=_clamp,
    shape_key=_shape_key,
    example=_example,
    exact_axes=frozenset({"block_rows"}),
    tol=1e-1,
))


def rmsnorm(x, scale, *, eps=1e-6, block_rows=None, use_ref=False):
    orig = x.shape
    x2 = x.reshape(-1, orig[-1])
    point = None if block_rows is None else {"block_rows": block_rows}
    out = api.call("rmsnorm", x2, scale, eps=eps, point=point,
                   use_ref=use_ref)
    return out.reshape(orig)
