"""Fused RMSNorm kernel: one HBM read + one write per row (XLA unfused does
read-for-variance + read-for-scale). Grid over row blocks; full feature dim
in VMEM (d_model <= 8192 -> <= 4 MB bf16 per 256-row block)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 256


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps)
                  ).astype(o_ref.dtype) * s_ref[...].astype(o_ref.dtype)


def rmsnorm_kernel(x: jnp.ndarray, scale: jnp.ndarray, *,
                   eps: float = 1e-6,
                   block_rows: int = DEFAULT_BLOCK_ROWS,
                   interpret: bool = False) -> jnp.ndarray:
    """x: (R, D); scale: (D,) -> (R, D)."""
    r, d = x.shape
    br = min(block_rows, r)
    assert r % br == 0
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(r // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, d), x.dtype),
        interpret=interpret,
    )(x, scale)
