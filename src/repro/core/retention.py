"""Fleet-side retention queue: pending delete ops become pool candidates.

The LST layer (``lst.retention``) knows how to ROUTE and EXECUTE a delete;
this module decides WHEN, by turning pending operations into priced
``Candidate``s that compete in the ``FleetScheduler`` pool against ordinary
compaction — same min-max normalization, same query-frequency weighting,
same starvation bound, same shared GBHr budget. One candidate per
(operation, table) carries the routed ``DeleteRoute`` and three traits:

  compute_cost   GBHr of the tier-2 rewrite bytes (the paper's §4.2 cost
                 model). A pure file-drop candidate costs an EXPLICIT 0.0 —
                 priced-free, budget-admissible, never conservative-skipped
                 as unpriced: dropping metadata entries rewrites nothing.
  reclaim_bytes  dropped-file bytes + est_selectivity x rewrite bytes; the
                 benefit term ``decide.pooled_benefit`` adds to file-count
                 reduction so drop-heavy candidates can win the budget.
  file_count_reduction  files that leave the table (drops + binning).

Lifecycle: ``RetentionPolicy`` is STANDING — re-routed every cycle, a
candidate appears whenever files currently age out, and an empty route just
means nothing to do this cycle. ``PredicateDelete`` is ONE-SHOT — it stays
pending (surviving deferral, conflicts, and service requeues) until its
rewrite fully succeeds on a table, then ``note_executed`` retires that
(op, table) pair; the op itself is dropped once every target table is done.
"""

from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.core.model import Candidate, CandidateStats, Scope
from repro.lst.retention import PredicateDelete, route_delete

MB = 1 << 20


class RetentionQueue:
    def __init__(self, target_file_bytes: int = 512 * MB,
                 executor_memory_gb: float = 8.0,
                 rewrite_bytes_per_hour: float = 256e9) -> None:
        self.target_file_bytes = target_file_bytes
        self.executor_memory_gb = executor_memory_gb
        self.rewrite_bytes_per_hour = rewrite_bytes_per_hour
        self.ops: List = []                       # pending, submission order
        self._done: Set[Tuple[str, str]] = set()  # finished (op.name, table)

    # ------------------------------------------------------------- lifecycle
    def submit(self, op) -> None:
        """Queue a RetentionPolicy or PredicateDelete (idempotent by name:
        resubmitting a name replaces the old op and resets its progress)."""
        self.ops = [o for o in self.ops if o.name != op.name]
        self._done = {d for d in self._done if d[0] != op.name}
        self.ops.append(op)

    def has_pending(self) -> bool:
        return bool(self.ops)

    def _op_pending_for(self, op, table_id: str) -> bool:
        return op.applies_to(table_id) and \
            (op.name, table_id) not in self._done

    def note_executed(self, cand: Candidate) -> None:
        """Called by the fleet after act: a one-shot op is done for this
        table once every routed result committed. Standing policies are
        never retired — next cycle re-routes whatever newly aged out."""
        route = cand.delete_route
        results = getattr(cand, "delete_results", [])
        if (isinstance(route.op, PredicateDelete) and results
                and all(r.success for r in results)):
            self._done.add((route.op.name, cand.table.table_id))
            self._gc(route.op)

    def _gc(self, op) -> None:
        """Drop a one-shot op once all its (known) target tables are done;
        fleet-wide ops (tables=None) stay queued — the done-set keeps their
        finished tables out of propose()."""
        if getattr(op, "tables", None) and all(
                (op.name, tid) in self._done for tid in op.tables):
            self.ops.remove(op)

    # --------------------------------------------------------------- propose
    def target_tables(self, catalog) -> List:
        """Tables with a pending op — so an after_write fleet cycle (which
        only looks at dirty tables) still sees retention work on tables
        nobody is writing to."""
        if not self.ops:
            return []
        return [t for t in sorted(catalog.tables(), key=lambda t: t.table_id)
                if any(self._op_pending_for(op, t.table_id)
                       for op in self.ops)]

    def propose(self, tables: Sequence, activity=None,
                now: Optional[float] = None) -> List[Candidate]:
        """Route every pending op against every applicable table and emit
        one priced candidate per non-empty route. Deterministic: tables
        sorted by id, ops in submission order (NFR2)."""
        cands: List[Candidate] = []
        for t in sorted(tables, key=lambda t: t.table_id):
            for op in list(self.ops):
                if not self._op_pending_for(op, t.table_id):
                    continue
                route = route_delete(t, op, now)
                if route.empty:
                    if isinstance(op, PredicateDelete):
                        # nothing routable (e.g. empty table): one-shot done
                        self._done.add((op.name, t.table_id))
                        self._gc(op)
                    continue
                cands.append(self._candidate(t, route, activity))
        return cands

    def _candidate(self, table, route, activity) -> Candidate:
        files = table.current_files()
        stats = CandidateStats(
            file_count=len(files),
            total_bytes=sum(f.size_bytes for f in files),
            small_file_count=0, small_bytes=0, size_histogram=(),
            partition_count=len({f.partition or "" for f in files}),
            created_at=table.meta.created_at,
            last_write_at=table.meta.last_write_at)
        if activity is not None:
            stats.custom["query_freq"] = activity.read_rate(table.table_id)
        c = Candidate(table, Scope.TABLE, stats=stats, delete_route=route)
        sel = getattr(route.op, "est_selectivity", 0.0)
        n_rw = len(route.rewrite_files)
        est_out = 0 if n_rw == 0 else min(n_rw, max(1, math.ceil(
            route.rewrite_bytes * (1.0 - sel) / self.target_file_bytes)))
        c.traits["file_count_reduction"] = float(
            len(route.file_drops) + (n_rw - est_out))
        c.traits["reclaim_bytes"] = float(route.est_reclaim_bytes)
        # §4.2 GBHr over the REWRITTEN bytes only; file drops move none
        c.traits["compute_cost"] = (self.executor_memory_gb
                                    * route.rewrite_bytes
                                    / self.rewrite_bytes_per_hour)
        return c
