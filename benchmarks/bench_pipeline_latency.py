"""Fig. 3 analogue on the REAL framework path — not the latency model.

TPC-DS's "maintenance degrades queries; compaction restores them" becomes:
  1. bulk-load a token shard table (well-sized shards), measure data-load
     step time;
  2. run a trickle "maintenance" phase (CDC-style small appends ~ +3% data),
     measure again (degraded: more files => more open() RPCs + plan time);
  3. AutoComp compacts the table (Pallas compact_pack merge); measure again.

Wall-clock times are real reads through the metered object store on this
host; file counts and RPC counts come from the store metrics."""

from __future__ import annotations

import time
from typing import List

from benchmarks.workload_sim import make_pipeline
from repro.data import DataPipeline, TokenShardWriter
from repro.data.packing import merge_shards_fn
from repro.lst import Catalog, InMemoryStore
from repro.lst.workload import SimClock


def _measure(table, batch=8, seq=256) -> dict:
    pipe = DataPipeline(table, batch=batch, seq_len=seq)
    t0 = time.perf_counter()
    n = sum(1 for _ in pipe.batches())
    wall = time.perf_counter() - t0
    st = pipe.stats()
    return {"wall_s": wall, "batches": n, **st}


def main() -> List[str]:
    clock = SimClock()
    store = InMemoryStore()
    catalog = Catalog(store, now_fn=clock.now)
    table = catalog.create_table("bench", "corpus",
                                 properties={"conflict_granularity": "table"})
    table.now_fn = clock.now
    w = TokenShardWriter(table, vocab=32000, seed=0)
    w.bulk_append(total_tokens=2_000_000, target_file_tokens=250_000)

    base = _measure(table)
    rows = [f"fig3_load_s[initial],{base['wall_s']:.3f},"
            f"files={int(base['files_scanned'])}"]

    # maintenance phase: trickle appends (~5% of data across many small
    # files — the paper's 3% modification producing 1.53x degradation)
    for _ in range(40):
        w.trickle_append(n_files=40, tokens_per_file=1200)
    degraded = _measure(table)
    rows.append(f"fig3_load_s[after_maintenance],{degraded['wall_s']:.3f},"
                f"files={int(degraded['files_scanned'])};"
                f"slowdown={degraded['wall_s']/base['wall_s']:.2f}x")

    pipe = make_pipeline("table", k=5, target=1 << 22)
    pipe.scheduler.merge_fn = merge_shards_fn
    rep = pipe.run_cycle(catalog)
    restored = _measure(table)
    rows.append(f"fig3_load_s[after_compaction],{restored['wall_s']:.3f},"
                f"files={int(restored['files_scanned'])};"
                f"removed={rep.files_removed};"
                f"recovery={degraded['wall_s']/restored['wall_s']:.2f}x")
    rows.append(f"fig3_open_rpc_total,{store.metrics.open_calls},"
                f"bytes_read={store.metrics.bytes_read}")
    return rows


if __name__ == "__main__":
    for r in main():
        print(r)
