"""NFR2 (determinism) + garbage-hygiene regressions for the compaction
executor: plan IDs and output paths must be identical across runs on the
same catalog state, and aborted rewrites must not leave orphaned
``compacted-*`` blobs in the store."""

from repro.lst import Catalog, InMemoryStore
from repro.lst import compaction as comp
from repro.lst.files import DataFile
from repro.lst.workload import SimClock

MB = 1 << 20


def make_table(granularity="table", n_files=10, parts=("a", "b")):
    clock = SimClock()
    store = InMemoryStore()
    cat = Catalog(store, now_fn=clock.now)
    t = cat.create_table("ns", "t", "p",
                         properties={"conflict_granularity": granularity})
    t.now_fn = clock.now
    files = []
    for i in range(n_files):
        path = f"{t.table_id}/data/f{i}.bin"
        t.store.put(path, b"x" * 128)
        files.append(DataFile(path, 4 * MB, 10, parts[i % len(parts)]))
    t.append(files)
    return t, store


def plan_fingerprint(tasks):
    return [(t.task_id, t.scope, tuple(f.path for f in t.inputs))
            for t in tasks]


class TestPlanDeterminism:
    def test_plan_table_identical_across_runs(self):
        t1, _ = make_table()
        t2, _ = make_table()
        a = comp.plan_table(t1, target_bytes=64 * MB)
        b = comp.plan_table(t2, target_bytes=64 * MB)
        assert plan_fingerprint(a) == plan_fingerprint(b)

    def test_replanning_same_state_identical(self):
        t, _ = make_table()
        a = comp.plan_table(t, target_bytes=64 * MB)
        b = comp.plan_table(t, target_bytes=64 * MB)
        assert plan_fingerprint(a) == plan_fingerprint(b)

    def test_task_ids_plan_scoped_not_global(self):
        """No module-global counter: every plan starts at task_id 1 and IDs
        are unique within the plan (across partitions)."""
        t, _ = make_table()
        for _ in range(2):
            tasks = comp.plan_table(t, target_bytes=64 * MB)
            ids = [task.task_id for task in tasks]
            assert ids == list(range(1, len(ids) + 1))

    def test_execute_paths_identical_across_runs(self):
        t1, _ = make_table()
        t2, _ = make_table()
        for t in (t1, t2):
            for task in comp.plan_table(t, target_bytes=64 * MB):
                assert comp.execute_task(t, task).success
        paths1 = sorted(f.path for f in t1.current_files())
        paths2 = sorted(f.path for f in t2.current_files())
        assert paths1 == paths2

    def test_successive_cycles_do_not_collide(self):
        """Output names embed the snapshot basis version, so a later cycle
        never reuses (and overwrites) the name of an earlier cycle's live
        output — the hazard of plan-scoped IDs alone."""
        t, store = make_table(n_files=8)
        for task in comp.plan_table(t, target_bytes=64 * MB):
            assert comp.execute_task(t, task).success
        cycle1 = {f.path: store.get(f.path) for f in t.current_files()
                  if "compacted-" in f.path}
        assert cycle1
        # append more small files and compact again
        extra = []
        for i in range(8):
            path = f"{t.table_id}/data/g{i}.bin"
            store.put(path, b"y" * 128)
            extra.append(DataFile(path, 4 * MB, 10, ("a", "b")[i % 2]))
        t.append(extra)
        for task in comp.plan_table(t, target_bytes=64 * MB):
            assert comp.execute_task(t, task).success
        live = {f.path for f in t.current_files()}
        for p in live:                          # nothing dangling
            assert store.exists(p)
        for p, blob in cycle1.items():          # survivors bit-identical
            if p in live:
                assert store.get(p) == blob


class TestFailureHygiene:
    def interleave_two_appends(self, table, task):
        n = getattr(self, "_n", 0)
        for j in range(2):   # cross the stale-metadata threshold
            path = f"{table.table_id}/data/x{n}-{j}.bin"
            table.store.put(path, b"y")
            table.append([DataFile(path, MB, 1, "a")])
        self._n = n + 1

    def test_exhausted_retries_sets_error_and_deletes_output(self):
        t, store = make_table("table")
        tasks = comp.plan_table(t, target_bytes=64 * MB)
        res = comp.execute_task(t, tasks[0], max_retries=0,
                                interleave_fn=self.interleave_two_appends)
        assert not res.success and res.conflict
        assert res.error and "exhausted" in res.error
        # the merged blob never committed -> it must not survive in the store
        assert store.list(f"{t.table_id}/data/compacted-") == []
        for f in t.current_files():   # untouched inputs still present
            assert store.exists(f.path)

    def test_dead_inputs_abort_deletes_output(self):
        t, store = make_table("table")
        tasks = comp.plan_table(t, target_bytes=64 * MB)

        def delete_inputs(table, _task):
            table.delete_files(list(_task.inputs))

        res = comp.execute_task(t, tasks[0], interleave_fn=delete_inputs)
        assert not res.success
        assert res.error == "inputs no longer live after conflict"
        assert store.list(f"{t.table_id}/data/compacted-") == []

    def test_atomic_dead_inputs_do_not_resurrect_rows(self):
        """A concurrent delete of the inputs mid-rewrite must abort the
        atomic commit — not land compacted copies of the deleted rows."""
        t, store = make_table("table")
        tasks = comp.plan_table(t, target_bytes=64 * MB)

        def delete_all_inputs(table, _task):
            live = [f for f in table.current_files()
                    if "compacted-" not in f.path]
            if live:
                table.delete_files(live)

        res = comp.execute_tasks_atomic(t, tasks,
                                        interleave_fn=delete_all_inputs)
        assert not res.success
        assert res.error == "inputs no longer live after conflict"
        assert t.current_files() == ()      # the delete stands
        assert store.list(f"{t.table_id}/data/compacted-") == []

    def test_atomic_failure_deletes_all_outputs(self):
        t, store = make_table("table")
        tasks = comp.plan_table(t, target_bytes=64 * MB)
        res = comp.execute_tasks_atomic(
            t, tasks, max_retries=0,
            interleave_fn=self.interleave_two_appends)
        assert not res.success
        assert res.error and "exhausted" in res.error
        assert store.list(f"{t.table_id}/data/compacted-") == []
        for f in t.current_files():   # original files untouched
            assert store.exists(f.path)


class TestSnapshotMetadataDeterminism:
    """Snapshot IDs were allocated from a module-global itertools.count
    shared by every table in the process, so identical catalog states got
    different snapshot IDs / manifest paths depending on what else had
    committed first — the same NFR2 violation once fixed for task IDs.
    IDs are now per-table, seeded from the table's own metadata."""

    @staticmethod
    def _run_once():
        t, store = make_table()
        tasks = comp.plan_table(t, target_bytes=64 * MB)
        res = comp.execute_tasks_atomic(t, tasks)
        assert res.success
        return t, store

    def test_identical_runs_serialize_identical_metadata(self):
        t1, _ = self._run_once()
        t2, _ = self._run_once()
        assert t1.meta.serialize() == t2.meta.serialize()

    def test_other_tables_do_not_perturb_snapshot_ids(self):
        """Interleaving commits to an unrelated table must not shift this
        table's IDs (the failure mode of the global counter)."""
        t1, _ = self._run_once()
        noise, _ = make_table()          # burns IDs under a global counter
        for _ in range(3):
            noise.append([])
        t2, _ = self._run_once()
        assert t1.meta.serialize() == t2.meta.serialize()

    def test_manifest_paths_identical_across_runs(self):
        t1, s1 = self._run_once()
        t2, s2 = self._run_once()
        m1 = sorted(p for p in s1.list(f"{t1.table_id}/metadata/"))
        m2 = sorted(p for p in s2.list(f"{t2.table_id}/metadata/"))
        assert m1 == m2

    def test_snapshot_ids_seeded_from_metadata(self):
        t, _ = self._run_once()
        ids = [s.snapshot_id for s in t.meta.snapshots]
        assert ids == list(range(1, len(ids) + 1))
