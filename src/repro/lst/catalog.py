"""Catalog + namespaces with object-count quotas — the OpenHouse stand-in.

A Namespace models the paper's "database": a logical group of tables owned
by a tenant, with an HDFS-namespace-object quota. AutoComp's production
weight adaptation (§7) reads ``quota_utilization`` from here:
    w1 = 0.5 * (1 + UsedQuota / TotalQuota).
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Dict, Iterable, List, Optional

from repro.lst.storage import ObjectStore
from repro.lst.table import LogStructuredTable


@dataclasses.dataclass
class Namespace:
    name: str
    total_quota: int                    # max namespace objects (files)
    tables: Dict[str, LogStructuredTable] = dataclasses.field(default_factory=dict)

    def used_quota(self) -> int:
        return sum(t.file_count() for t in self.tables.values())

    def quota_utilization(self) -> float:
        if self.total_quota <= 0:
            return 0.0
        return min(1.0, self.used_quota() / self.total_quota)


class Catalog:
    def __init__(self, store: ObjectStore, now_fn=None) -> None:
        self.store = store
        self.namespaces: Dict[str, Namespace] = {}
        self._lock = threading.RLock()
        self._write_listeners: List = []
        self.now_fn = now_fn

    def create_namespace(self, name: str, total_quota: int = 1_000_000
                         ) -> Namespace:
        with self._lock:
            ns = self.namespaces.get(name)
            if ns is None:
                ns = Namespace(name, total_quota)
                self.namespaces[name] = ns
            return ns

    def create_table(self, namespace: str, table: str,
                     partition_spec: Optional[str] = None,
                     properties: Optional[Dict] = None) -> LogStructuredTable:
        with self._lock:
            ns = self.create_namespace(namespace)
            tid = f"{namespace}/{table}"
            kwargs = {}
            if self.now_fn is not None:
                kwargs["now_fn"] = self.now_fn
            t = LogStructuredTable(self.store, tid, partition_spec,
                                   properties, **kwargs)
            ns.tables[table] = t
            return t

    def get_table(self, namespace: str, table: str) -> LogStructuredTable:
        return self.namespaces[namespace].tables[table]

    def tables(self) -> List[LogStructuredTable]:
        with self._lock:
            return [t for ns in self.namespaces.values()
                    for t in ns.tables.values()]

    def namespace_of(self, table: LogStructuredTable) -> Namespace:
        ns_name = table.table_id.split("/", 1)[0]
        return self.namespaces[ns_name]

    # --- optimize-after-write hook plumbing (§5 "push" mode) ---------------
    def add_write_listener(self, fn) -> None:
        self._write_listeners.append(fn)

    def notify_write(self, table: LogStructuredTable) -> None:
        for fn in self._write_listeners:
            fn(table)
