"""Roofline instrumentation.

XLA's ``cost_analysis()`` visits a ``while`` body once, so any program built
on ``lax.scan`` (our layer stacks, microbatch accumulation, blockwise
attention) under-reports FLOPs/bytes by the trip count. Two fixes:

1. ``jaxpr_cost(fn, *args)`` — walks the jaxpr, multiplying through ``scan``
   lengths: exact global dot FLOPs and an HBM-traffic estimate (each
   dot_general streams operands+outputs through HBM once; elementwise chains
   are assumed fused and counted at 1 flop / output element, 0 extra bytes).

2. ``hlo_collective_bytes(text)`` — parses the compiled per-device HLO,
   builds the computation call graph, extracts while-loop trip counts from
   their condition computations, and multiplies collective bytes through the
   loop nest.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

# ---------------------------------------------------------------------------
# jaxpr cost
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    dot_flops: float = 0.0
    hbm_bytes: float = 0.0

    def __iadd__(self, o):
        self.flops += o.flops
        self.dot_flops += o.dot_flops
        self.hbm_bytes += o.hbm_bytes
        return self

    def scaled(self, k: float) -> "Cost":
        return Cost(self.flops * k, self.dot_flops * k, self.hbm_bytes * k)


def _aval_bytes(v) -> float:
    aval = v.aval
    if not hasattr(aval, "shape"):
        return 0.0
    return float(np.prod(aval.shape, dtype=np.float64)) * aval.dtype.itemsize


def _aval_size(v) -> float:
    aval = v.aval
    if not hasattr(aval, "shape"):
        return 0.0
    return float(np.prod(aval.shape, dtype=np.float64))


def _dot_cost(eqn) -> Cost:
    (lhs_c, rhs_c), (lhs_b, rhs_b) = eqn.params["dimension_numbers"]
    lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
    batch = float(np.prod([lhs.shape[i] for i in lhs_b], dtype=np.float64)) or 1.0
    k = float(np.prod([lhs.shape[i] for i in lhs_c], dtype=np.float64)) or 1.0
    m = float(np.prod([s for i, s in enumerate(lhs.shape)
                       if i not in lhs_c and i not in lhs_b], dtype=np.float64)) or 1.0
    n = float(np.prod([s for i, s in enumerate(rhs.shape)
                       if i not in rhs_c and i not in rhs_b], dtype=np.float64)) or 1.0
    flops = 2.0 * batch * m * n * k
    byts = _aval_bytes(eqn.invars[0]) + _aval_bytes(eqn.invars[1]) \
        + sum(_aval_bytes(o) for o in eqn.outvars)
    return Cost(flops=flops, dot_flops=flops, hbm_bytes=byts)


_SUBJAXPR_PARAMS = ("jaxpr", "call_jaxpr", "fun_jaxpr", "cond_jaxpr")
_ZERO_FLOP_PRIMS = {
    "reshape", "transpose", "broadcast_in_dim", "convert_element_type",
    "squeeze", "slice", "dynamic_slice", "dynamic_update_slice",
    "concatenate", "pad", "rev", "copy", "stop_gradient", "iota",
    "gather", "scatter", "split", "sharding_constraint",
}


def _jaxpr_cost(jaxpr) -> Cost:
    total = Cost()
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim == "dot_general":
            total += _dot_cost(eqn)
        elif prim == "scan":
            inner = _jaxpr_cost(eqn.params["jaxpr"].jaxpr)
            total += inner.scaled(float(eqn.params["length"]))
        elif prim == "while":
            inner = _jaxpr_cost(eqn.params["body_jaxpr"].jaxpr)
            total += inner  # unknown trips; we do not emit raw while loops
        elif prim == "cond":
            branches = eqn.params["branches"]
            costs = [_jaxpr_cost(b.jaxpr) for b in branches]
            worst = max(costs, key=lambda c: c.flops, default=Cost())
            total += worst
        else:
            recursed = False
            for key in _SUBJAXPR_PARAMS:
                sub = eqn.params.get(key) if hasattr(eqn, "params") else None
                if sub is not None:
                    total += _jaxpr_cost(getattr(sub, "jaxpr", sub))
                    recursed = True
                    break
            if not recursed and prim not in _ZERO_FLOP_PRIMS:
                total += Cost(flops=sum(_aval_size(o) for o in eqn.outvars))
    return total


def jaxpr_cost(fn, *abstract_args) -> Dict[str, float]:
    closed = jax.make_jaxpr(fn)(*abstract_args)
    c = _jaxpr_cost(closed.jaxpr)
    return {"flops": c.flops, "dot_flops": c.dot_flops,
            "hbm_bytes": c.hbm_bytes}


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS: 6*N*D train (N_active for MoE), 2*N*D forward-only."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch  # decode: 1 token/seq


# ---------------------------------------------------------------------------
# HLO collective parsing with loop trip counts
# ---------------------------------------------------------------------------

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
# iota form "replica_groups=[2,4]<=[8]" and list form "replica_groups={{0,2},..."
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
# computation header: "%name (args...) -> type {" — args may contain nested
# parens (tuple-typed params), so only anchor on the leading name.
_COMP_START_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_WHILE_RE = re.compile(
    r"while\(.*?\)[^{]*?condition=%?([\w.\-]+)[^{]*?body=%?([\w.\-]+)")
_CALL_RE = re.compile(r"(?:calls|to_apply|body|condition|branch_computations)="
                      r"\{?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)\}?")
_CONST_RE = re.compile(r"=\s*s32\[\]\s*constant\((\d+)\)")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _split_computations(text: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    depth = 0
    for line in text.splitlines():
        s = line.strip()
        if cur is None:
            m = _COMP_START_RE.match(s)
            if m and s.endswith("{") and "->" in s:
                cur = m.group(1)
                comps[cur] = []
                depth = 1
            continue
        depth += s.count("{") - s.count("}")
        if depth <= 0:
            cur = None
            continue
        comps[cur].append(s)
    return comps


def _group_size(s: str) -> int:
    """Replica-group size of a collective line; 0 when unparseable."""
    m = _GROUPS_IOTA_RE.search(s)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(s)
    if m:
        return len([t for t in m.group(1).split(",") if t.strip()])
    return 0


def _wire_bytes(op: str, full_bytes: float, g: int) -> float:
    """Per-device link traffic under the standard ring algorithms.

    ``full_bytes`` is the logical full-array payload (the result shape for
    all ops except reduce-scatter, whose result is 1/g of it). Ring
    all-reduce moves 2(g-1)/g of the array (reduce-scatter + all-gather
    phases); all-gather / reduce-scatter / all-to-all move (g-1)/g; a
    permute moves the array once. Unknown group size assumes a large group.
    """
    frac = (g - 1) / g if g > 1 else (1.0 if g == 0 else 0.0)
    if op == "all-reduce":
        return 2.0 * frac * full_bytes
    if op == "collective-permute":
        return float(full_bytes)
    return frac * full_bytes


def _collective_line_bytes(s: str
                           ) -> Optional[Tuple[str, int, int, int, int, int]]:
    """(op, bytes, bf16-eq bytes, wire bytes, bf16-eq wire bytes, s8 wire).

    ``bytes`` is the result-shape payload (legacy metric); ``wire_bytes``
    models what actually crosses the links (see :func:`_wire_bytes`). The
    CPU backend promotes bf16 dots to f32, so weight/activation collectives
    appear at 2x their TPU size; the bf16-equivalent numbers halve f32
    collective payloads (TPU keeps them bf16). The trailing element is the
    bf16-eq wire bytes of the *int8 part* of the payload — how much of the
    line's traffic a quantized transport actually moved as s8 (scales and
    other operands excluded), used by the serve act_transport comparison.
    """
    for op in COLLECTIVE_OPS:
        idx = s.find(op + "(")
        if idx < 0 or op + "-done" in s:
            continue
        eq = s.find(" = ")
        if eq < 0 or eq > idx:
            continue
        result = s[eq + 3:idx]
        byts = 0
        byts_eq = 0.0
        byts_eq_s8 = 0.0
        for m in _SHAPE_RE.finditer(result):
            b = _shape_bytes(m.group(1), m.group(2))
            byts += b
            byts_eq += b * (0.5 if m.group(1) == "f32" else 1.0)
            if m.group(1) == "s8":
                byts_eq_s8 += b
        g = _group_size(s)
        if op == "reduce-scatter":
            mul = g if g else 1
            byts *= mul
            byts_eq *= mul
            byts_eq_s8 *= mul
        wire = _wire_bytes(op, byts, g)
        wire_eq = _wire_bytes(op, byts_eq, g)
        wire_eq_s8 = _wire_bytes(op, byts_eq_s8, g)
        return op, byts, int(byts_eq), int(wire), int(wire_eq), int(wire_eq_s8)
    return None


def _cond_trip_count(lines: List[str]) -> int:
    consts = [int(m.group(1)) for line in lines for m in _CONST_RE.finditer(line)]
    return max(consts) if consts else 1


def hlo_collective_bytes(text: str) -> Dict[str, Any]:
    comps = _split_computations(text)
    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_START_RE.match(line.strip())
            if m:
                entry = m.group(1)
    if entry is None:  # fall back: flat scan, no multipliers
        entry_lines = [l for ls in comps.values() for l in ls]
        comps = {"__entry__": entry_lines}
        entry = "__entry__"

    memo: Dict[str, Dict[str, Any]] = {}
    _KEYS = ("count", "bytes", "bytes_bf16eq", "wire_bytes",
             "wire_bytes_bf16eq", "wire_bytes_bf16eq_s8")

    def zero():
        return {op: {k: 0 for k in _KEYS} for op in COLLECTIVE_OPS}

    def visit(name: str, stack=()) -> Dict[str, Any]:
        if name in memo:
            return memo[name]
        if name in stack or name not in comps:
            return zero()
        agg = zero()
        for s in comps[name]:
            hit = _collective_line_bytes(s)
            if hit:
                op, byts, byts_eq, wire, wire_eq, wire_eq_s8 = hit
                agg[op]["count"] += 1
                agg[op]["bytes"] += byts
                agg[op]["bytes_bf16eq"] += byts_eq
                agg[op]["wire_bytes"] += wire
                agg[op]["wire_bytes_bf16eq"] += wire_eq
                agg[op]["wire_bytes_bf16eq_s8"] += wire_eq_s8
            wm = _WHILE_RE.search(s)
            if wm:
                cond, body = wm.group(1), wm.group(2)
                trips = _cond_trip_count(comps.get(cond, []))
                sub = visit(body, stack + (name,))
                for op in COLLECTIVE_OPS:
                    for k in _KEYS:
                        agg[op][k] += sub[op][k] * trips
                continue
            for cm in _CALL_RE.finditer(s):
                for callee in re.split(r",\s*%?", cm.group(1)):
                    if callee in ("", name) or callee in (wm.groups() if wm else ()):
                        continue
                    sub = visit(callee, stack + (name,))
                    for op in COLLECTIVE_OPS:
                        for k in _KEYS:
                            agg[op][k] += sub[op][k]
        memo[name] = agg
        return agg

    agg = visit(entry)
    for k in ("bytes", "bytes_bf16eq", "wire_bytes", "wire_bytes_bf16eq",
              "wire_bytes_bf16eq_s8"):
        agg["total_" + k] = sum(v[k] for v in agg.values()
                                if isinstance(v, dict))
    return agg


def top_collectives(text: str, n: int = 20):
    """Dynamic (trip-count-multiplied) collective tally grouped by shape —
    the §Perf profiling view."""
    comps = _split_computations(text)
    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            entry = _COMP_START_RE.match(line.strip()).group(1)
    tally: Dict[Tuple[str, str], List[float]] = {}

    def visit(name, mult, stack=()):
        if name in stack or name not in comps:
            return
        for s in comps[name]:
            hit = _collective_line_bytes(s)
            if hit:
                op, byts = hit[0], hit[1]
                shape = s.split(" = ")[1].split(" ")[0][:70]
                c, b = tally.get((op, shape), (0, 0))
                tally[(op, shape)] = (c + mult, b + byts * mult)
            wm = _WHILE_RE.search(s)
            if wm:
                trips = _cond_trip_count(comps.get(wm.group(1), []))
                visit(wm.group(2), mult * trips, stack + (name,))
                continue
            for cm in _CALL_RE.finditer(s):
                for callee in re.split(r",\s*%?", cm.group(1)):
                    if callee and callee != name:
                        visit(callee, mult, stack + (name,))

    visit(entry, 1)
    rows = sorted(tally.items(), key=lambda kv: -kv[1][1])[:n]
    return [{"op": op, "shape": shape, "count": c, "bytes": b}
            for (op, shape), (c, b) in rows]
