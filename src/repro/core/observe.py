"""Observe phase (§3.3/§4.1): extract statistics for each candidate.

The standardized stats layout supports generic metrics (file counts/sizes)
plus platform-specific custom metrics injected through ``custom_fns`` —
e.g. access frequency from the data-pipeline reader, or checkpoint age from
the training runner.
"""

from __future__ import annotations

import math
from typing import Callable, Dict, Iterable, List, Optional

from repro.core.model import Candidate, CandidateStats

BUCKETS = 16  # power-of-two size buckets starting at 1 MiB


def size_bucket(size_bytes: int) -> int:
    mb = max(size_bytes / (1 << 20), 1e-6)
    b = int(math.floor(math.log2(mb))) + 1 if mb >= 1 else 0
    return min(max(b, 0), BUCKETS - 1)


class StatsCollector:
    def __init__(self, target_file_bytes: int,
                 custom_fns: Optional[Dict[str, Callable]] = None) -> None:
        self.target = target_file_bytes
        self.custom_fns = custom_fns or {}

    def observe(self, cand: Candidate) -> CandidateStats:
        files = cand.files()
        hist = [0] * BUCKETS
        small = 0
        small_bytes = 0
        total = 0
        for f in files:
            hist[size_bucket(f.size_bytes)] += 1
            total += f.size_bytes
            if f.size_bytes < self.target:
                small += 1
                small_bytes += f.size_bytes
        stats = CandidateStats(
            file_count=len(files),
            total_bytes=total,
            small_file_count=small,
            small_bytes=small_bytes,
            size_histogram=tuple(hist),
            partition_count=len({f.partition for f in files}),
            created_at=cand.table.meta.created_at,
            last_write_at=cand.table.meta.last_write_at,
        )
        for name, fn in self.custom_fns.items():
            stats.custom[name] = fn(cand)
        cand.stats = stats
        return stats

    def observe_all(self, cands: Iterable[Candidate]) -> List[Candidate]:
        out = []
        for c in cands:
            self.observe(c)
            out.append(c)
        return out
