"""The sLSTM deferred-reduction custom VJP (EXPERIMENTS.md §4.1) must stay
numerically identical to plain-scan autodiff — it is the transform that
took xlstm-125m/train_4k from 0.002 to 0.64 roofline fraction."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models.xlstm import _slstm_cell_raw, _slstm_sequence


def run_pair(S, B, H, d, seed):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    r = jax.random.normal(ks[0], (4, H, d // H, d // H), jnp.float32) * 0.1
    bg = jax.random.normal(ks[1], (4, d), jnp.float32) * 0.1
    gx = jax.random.normal(ks[2], (S, B, 4, d), jnp.float32)
    z = jnp.zeros((B, d), jnp.float32)
    s0 = (z, z, z, z)

    def ref(r, bg, gx):
        def step(state, x_t):
            new = _slstm_cell_raw(H, r, bg, x_t, state)
            return new, new[0]
        final, ys = jax.lax.scan(step, s0, gx)
        return jnp.sum(ys ** 2) + sum(jnp.sum(f) for f in final)

    def custom(r, bg, gx):
        ys, final = _slstm_sequence(H, r, bg, gx, s0)
        return jnp.sum(ys ** 2) + sum(jnp.sum(f) for f in final)

    v1, g1 = jax.value_and_grad(ref, argnums=(0, 1, 2))(r, bg, gx)
    v2, g2 = jax.value_and_grad(custom, argnums=(0, 1, 2))(r, bg, gx)
    return v1, g1, v2, g2


@given(st.integers(min_value=1, max_value=16),
       st.integers(min_value=1, max_value=4),
       st.sampled_from([(1, 4), (2, 8), (4, 16)]),
       st.integers(min_value=0, max_value=100))
@settings(max_examples=12, deadline=None)
def test_custom_vjp_matches_autodiff(S, B, Hd, seed):
    H, d = Hd
    v1, g1, v2, g2 = run_pair(S, B, H, d, seed)
    assert abs(float(v1 - v2)) < 1e-5
    for a, b in zip(g1, g2):
        assert float(jnp.max(jnp.abs(a - b))) < 1e-4


def test_custom_vjp_under_jit_and_remat():
    def loss(r, bg, gx):
        z = jnp.zeros((2, 8), jnp.float32)
        ys, _ = _slstm_sequence(2, r, bg, gx, (z, z, z, z))
        return jnp.sum(ys ** 2)

    key = jax.random.PRNGKey(0)
    r = jax.random.normal(key, (4, 2, 4, 4), jnp.float32) * 0.1
    bg = jnp.zeros((4, 8), jnp.float32)
    gx = jax.random.normal(key, (6, 2, 4, 8), jnp.float32)
    g_plain = jax.grad(loss)(r, bg, gx)
    g_jit = jax.jit(jax.grad(loss))(r, bg, gx)
    g_remat = jax.grad(jax.checkpoint(loss))(r, bg, gx)
    assert float(jnp.max(jnp.abs(g_plain - g_jit))) < 1e-5
    assert float(jnp.max(jnp.abs(g_plain - g_remat))) < 1e-4
