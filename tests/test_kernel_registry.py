"""Tunable-kernel registry (repro.kernels.api / tuned / tune): every
registered op bit-matches its reference across the tunable-axis grid
(exact axes bit-for-bit, the rest within the op's fp tolerance), tuned
points round-trip through the persisted cache (including the
stale-device-kind miss), oversized cached points clamp to shorter
operands instead of tripping grid asserts, and a second sweep of a tuned
cell is served from cache with ZERO re-evaluations."""

import itertools
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import api, tune, tuned
from repro.kernels.flash_attn.ops import flash_attention
from repro.kernels.rmsnorm.ops import rmsnorm


@pytest.fixture()
def tuned_dir(tmp_path, monkeypatch):
    """Point the tuned-point cache at a throwaway dir for this test."""
    monkeypatch.setenv("REPRO_TUNED_DIR", str(tmp_path))
    tuned.invalidate_memo()
    yield tmp_path
    tuned.invalidate_memo()


class TestFitBlock:
    @pytest.mark.parametrize("value,extent,expect", [
        (512, 256, 256),      # clamp to extent
        (512, 512, 512),      # exact fit
        (128, 512, 128),      # already a divisor
        (512, 100, 100),      # clamp, divides
        (96, 256, 32),        # 256 % 96 != 0 -> gcd
        (256, 300, 4),        # gcd fallback on awkward extents
        (7, 512, 1),          # coprime -> 1, never asserts
        (512, 0, 512),        # degenerate extent: leave value alone
    ])
    def test_table(self, value, extent, expect):
        got = api.fit_block(value, extent)
        assert got == expect
        if extent > 0:
            assert extent % got == 0      # the invariant every grid needs


class TestRegistry:
    def test_builtin_ops_registered(self):
        names = set(api.ops())
        assert {"compact_pack", "flash_attn", "decode_attn",
                "paged_attn", "rmsnorm", "expert_a2a"} <= names

    def test_register_rejects_default_outside_candidates(self):
        bad = api.TunableOp(
            name="bad", axes={"b": (1, 2)}, default={"b": 3},
            run=lambda p: None, ref=lambda: None,
            clamp=lambda p: p, shape_key=lambda: "x",
            example=lambda q: ((), {}))
        with pytest.raises(ValueError):
            api.register(bad)

    def test_explicit_point_ignores_unknown_axes(self):
        op = api.get_op("rmsnorm")
        x = jnp.ones((64, 128), jnp.float32)
        sc = jnp.ones((128,), jnp.float32)
        out = api.call("rmsnorm", x, sc,
                       point={"block_rows": 64, "bogus_axis": 999})
        ref = op.ref(x, sc)
        assert np.allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


class TestGridBitMatch:
    """The property the registry exists to defend: every candidate point
    is a correct implementation — the tuner can only trade speed."""

    @pytest.mark.parametrize("name", ["compact_pack", "flash_attn",
                                      "decode_attn", "paged_attn",
                                      "rmsnorm", "expert_a2a"])
    def test_every_grid_point_matches_ref(self, name):
        op = api.get_op(name)
        args, kwargs = op.example(True)
        axes = api.clamped_axes(op, *args, **kwargs)
        ref = np.asarray(op.ref(*args, **kwargs), np.float32)
        outs = {}
        for combo in itertools.product(*axes.values()):
            point = dict(zip(axes, combo))
            out = np.asarray(op.run(op.clamp(dict(point), *args, **kwargs),
                                    *args, **kwargs), np.float32)
            outs[combo] = out
            if op.tol == 0.0:
                assert np.array_equal(out, ref), (name, point)
            else:
                assert np.max(np.abs(out - ref)) <= op.tol, (name, point)
        # exact axes: varying ONLY that axis never changes a bit
        names = list(axes)
        for axis in op.exact_axes:
            i = names.index(axis)
            groups = {}
            for combo, out in outs.items():
                groups.setdefault(combo[:i] + combo[i + 1:], []).append(out)
            for rest, group in groups.items():
                for other in group[1:]:
                    assert np.array_equal(group[0], other), (name, axis, rest)


class TestTunedCache:
    def test_round_trip(self, tuned_dir):
        tuned.store("flash_attn", "s256", {"block_q": 128, "block_k": 256},
                    objective_us=123.4, evaluations=7)
        assert tuned.lookup("flash_attn", "s256") \
            == {"block_q": 128, "block_k": 256}
        rec = tuned.entry("flash_attn", "s256")
        assert rec["objective_us"] == pytest.approx(123.4)
        assert rec["evaluations"] == 7
        assert tuned.lookup("flash_attn", "s999") is None

    def test_stale_device_kind_is_clean_miss(self, tuned_dir):
        """A cache written on another device kind must not serve its
        blocks here — lookup misses, dispatch falls back to the default;
        the raw entry stays readable for reporting."""
        tuned.store("rmsnorm", "r512", {"block_rows": 64},
                    objective_us=1.0, evaluations=4)
        path = tuned.cache_path()
        payload = json.loads(path.read_text())
        payload["points"]["rmsnorm|r512"]["device_kind"] = "tpu-v9999"
        path.write_text(json.dumps(payload))
        tuned.invalidate_memo()
        assert tuned.lookup("rmsnorm", "r512") is None
        assert tuned.entry("rmsnorm", "r512")["point"] == {"block_rows": 64}
        op = api.get_op("rmsnorm")
        x = jnp.ones((512, 128), jnp.float32)
        sc = jnp.ones((128,), jnp.float32)
        assert api.resolve_point(op, x, sc) == api.default_point(op)

    def test_corrupt_cache_file_is_miss(self, tuned_dir):
        tuned.cache_path().parent.mkdir(parents=True, exist_ok=True)
        tuned.cache_path().write_text("{not json")
        tuned.invalidate_memo()
        assert tuned.lookup("flash_attn", "anything") is None

    def test_oversized_cached_point_clamps_on_serve(self, tuned_dir):
        """A tuned point with blocks larger than the operand (schema
        drift, hand-edited cache) is clamped at call time, not trusted."""
        x = jnp.linspace(-2, 2, 300 * 128, dtype=jnp.float32
                         ).reshape(300, 128)
        sc = jnp.ones((128,), jnp.float32)
        op = api.get_op("rmsnorm")
        skey = op.shape_key(x, sc)
        tuned.store("rmsnorm", skey, {"block_rows": 1024},
                    objective_us=1.0, evaluations=1)
        out = rmsnorm(x, sc)                 # 300 rows, 1024 clamps to 300
        ref = rmsnorm(x, sc, use_ref=True)
        assert np.array_equal(np.asarray(out), np.asarray(ref))

    def test_explicit_oversized_blocks_clamp(self):
        """The pre-registry wrappers asserted on non-dividing blocks;
        every wrapper now fits them to the operand extent."""
        import jax
        key = jax.random.PRNGKey(0)
        q = jax.random.normal(key, (1, 2, 128, 64), jnp.float32)
        k = jax.random.normal(key, (1, 1, 128, 64), jnp.float32)
        v = jax.random.normal(key, (1, 1, 128, 64), jnp.float32)
        out = flash_attention(q, k, v, block_q=1024, block_k=1024)
        ref = flash_attention(q, k, v, use_ref=True)
        assert np.max(np.abs(np.asarray(out) - np.asarray(ref))) < 5e-2


class TestTuneHarness:
    def test_sweep_finds_nondefault_point_then_serves_from_cache(
            self, tuned_dir):
        """The tentpole acceptance path: the exhaustive sweep finds a
        non-default best point for compact_pack on this host (coarser DMA
        blocks beat the chunk-at-a-time default), persists it, and the
        second run is a cache hit with ZERO re-evaluations."""
        first = tune.tune_op("compact_pack", quick=True, iters=1)
        assert not first.cache_hit
        assert first.evaluations >= len(
            api.clamped_axes(api.get_op("compact_pack"),
                             *api.get_op("compact_pack").example(True)[0])
            ["block_chunks"])
        assert first.point["block_chunks"] > 1      # non-default winner
        second = tune.tune_op("compact_pack", quick=True, iters=1)
        assert second.cache_hit
        assert second.evaluations == 0
        assert second.point == first.point

    def test_tuned_point_serves_deterministically(self, tuned_dir):
        """Once a point is cached, api.call resolves it on every call and
        the op output is bit-stable across calls."""
        tune.tune_op("compact_pack", quick=True, iters=1)
        op = api.get_op("compact_pack")
        args, kwargs = op.example(True)
        assert api.resolve_point(op, *args, **kwargs)["block_chunks"] > 1
        a = np.asarray(api.call("compact_pack", *args, **kwargs))
        b = np.asarray(api.call("compact_pack", *args, **kwargs))
        assert np.array_equal(a, b)

    def test_expert_a2a_sweep_then_cache_hit(self, tuned_dir):
        """The expert all-to-all inherits the sweep harness like every
        registered op: first sweep evaluates the (clamped, deduped) block
        grid and persists, the second is a pure cache hit."""
        first = tune.tune_op("expert_a2a", quick=True, iters=1)
        assert not first.cache_hit
        op = api.get_op("expert_a2a")
        args, kwargs = op.example(True)
        assert first.evaluations >= len(
            api.clamped_axes(op, *args, **kwargs)["block"])
        second = tune.tune_op("expert_a2a", quick=True, iters=1)
        assert second.cache_hit
        assert second.evaluations == 0
        assert second.point == first.point
        assert api.resolve_point(op, *args, **kwargs) == first.point


class TestFusedFilterPack:
    """The fused filter+pack kernel vs the filter-then-pack reference:
    bit-identical across plan shapes, keep fractions, and DMA
    granularities (the whole point of exact_axes for compact_pack)."""

    @pytest.mark.parametrize("counts,order", [
        ([4, 4, 4, 4], [3, 1, 2, 0]),
        ([2, 6, 8], None),
        ([3, 1, 2], [2, 0, 1]),
    ])
    @pytest.mark.parametrize("frac", [0.0, 0.3, 1.0])
    def test_fused_matches_reference(self, counts, order, frac):
        from repro.kernels.compact_pack import (compact_chunks,
                                                plan_compaction)
        from repro.kernels.compact_pack.compact_pack import (CHUNK_ROWS,
                                                             CHUNK_TOKENS)
        n_src = sum(counts)
        rng = np.random.RandomState(hash((tuple(counts), frac)) % (1 << 31))
        src = jnp.asarray(rng.randint(0, 1 << 30,
                                      n_src * CHUNK_TOKENS, np.int64)
                          .astype(np.int32))
        cm = plan_compaction(counts, fragment_order=order)
        keep = rng.rand(len(cm) * CHUNK_ROWS) >= frac
        fused = np.asarray(compact_chunks(src, cm, keep_mask=keep))
        ref = np.asarray(compact_chunks(src, cm, use_ref=True,
                                        keep_mask=keep))
        assert np.array_equal(fused, ref)
        assert fused.shape[0] == \
            -(-int(keep.sum()) // CHUNK_ROWS) * CHUNK_TOKENS
