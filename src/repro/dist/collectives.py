"""Blockwise-int8 compressed collectives with error feedback.

Cross-pod gradient all-reduce is the bandwidth floor of multi-pod training
(the DCI link is ~an order of magnitude slower than ICI). Following the
DRAGONN/ATOMO line of gradient compression, payloads are quantized to
symmetric int8 per ``block`` elements (4x smaller than bf16 on the wire,
scales amortized over the block) and the quantization residual is carried
into the next step — error feedback — so the *long-run* contribution of
every element is unbiased even though each step rounds.

The serve path uses the same quantizer for its *activation* all-gathers
(:func:`act_gather` under an :class:`act_transport_scope`): no error
feedback there — activations are stateless across steps, so each gather
quantizes fresh and the error never compounds.

All functions are jit-compatible: shapes are static, no host sync.
"""

from __future__ import annotations

import threading
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dist import sharding as _shd


def quantize_int8(x: jnp.ndarray, block: int = 256
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-block int8 quantization.

    Flattens ``x``, zero-pads to a multiple of ``block``, and scales each
    block by its abs-max so values land in [-127, 127]. Per-element error is
    at most ``block_max / 254`` (half a quantization step). Returns
    ``(q, scales)`` with ``q: int8 (n_blocks, block)`` and
    ``scales: float32 (n_blocks,)``.
    """
    flat = jnp.ravel(x).astype(jnp.float32)
    pad = (-flat.shape[0]) % block
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.float32)])
    return _quantize_blocks(flat.reshape(-1, block))


def dequantize_int8(q: jnp.ndarray, scales: jnp.ndarray, n: int
                    ) -> jnp.ndarray:
    """Inverse of :func:`quantize_int8`; returns the first ``n`` elements."""
    return _dequantize_blocks(q, scales).reshape(-1)[:n]


def _quantize_blocks(blocks: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric int8 over the trailing ``block`` axis of ``(..., block)``."""
    scales = jnp.max(jnp.abs(blocks), axis=-1) / 127.0
    safe = jnp.where(scales > 0, scales, 1.0)   # all-zero block -> q = 0
    q = jnp.clip(jnp.round(blocks / safe[..., None]), -127, 127).astype(jnp.int8)
    return q, scales


def _dequantize_blocks(q: jnp.ndarray, scales: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scales[..., None]


def _two_stage_int8_psum(flat: jnp.ndarray, axis_name, block: int
                         ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """All-reduce ``flat`` across ``axis_name`` moving int8 on the wire.

    DRAGONN-style two-stage scheme (the op mix a real int8 all-reduce uses):

    1. split the payload into one chunk per peer, quantize each chunk
       blockwise, and ``all_to_all`` the int8 chunks + f32 scales — every
       device receives each peer's compressed contribution to *its* chunk;
    2. dequantize + sum locally (the owned chunk is now fully reduced),
       re-quantize it, and ``all_gather`` the int8 result chunks.

    Wire traffic is ~(2 + 8/block) bytes/element vs 4 bytes/element for a
    ring bf16 all-reduce. Both quantization errors feed the returned
    residual: stage 1 over the full local payload, stage 2 only on the
    owned chunk (each chunk has exactly one owner, so the residual *sum*
    across devices captures the stage-2 error exactly once).

    Returns ``(summed_flat, residual_flat)`` of the same length as ``flat``.
    """
    w = jax.lax.psum(1, axis_name)   # statically-known axis size
    n = flat.shape[0]
    pad = (-n) % (w * block)
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.float32)])
    npad = flat.shape[0]
    chunk = npad // w
    # stage 1: my contribution to every peer's chunk, int8 on the wire
    q1, s1 = _quantize_blocks(flat.reshape(w, chunk // block, block))
    err1 = flat - _dequantize_blocks(q1, s1).reshape(npad)
    q1x = jax.lax.all_to_all(q1, axis_name, split_axis=0, concat_axis=0)
    s1x = jax.lax.all_to_all(s1, axis_name, split_axis=0, concat_axis=0)
    mine = jnp.sum(_dequantize_blocks(q1x, s1x), axis=0)   # (chunk//block, block)
    # stage 2: broadcast the reduced chunk, int8 on the wire again
    q2, s2 = _quantize_blocks(mine)
    err2 = (mine - _dequantize_blocks(q2, s2)).reshape(chunk)
    q2g = jax.lax.all_gather(q2, axis_name)
    s2g = jax.lax.all_gather(s2, axis_name)
    out = _dequantize_blocks(q2g, s2g).reshape(npad)
    ofs = jax.lax.axis_index(axis_name) * chunk
    new_err = jax.lax.dynamic_update_slice(
        err1, jax.lax.dynamic_slice(err1, (ofs,), (chunk,)) + err2, (ofs,))
    return out[:n], new_err[:n]


def compressed_psum(x: jnp.ndarray, axis_name: Optional[str] = None,
                    err: Optional[jnp.ndarray] = None, *, block: int = 256
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """psum of an int8-compressed payload with error-feedback accumulation.

    The carried residual ``err`` (same shape as ``x``, float32; pass zeros or
    ``None`` on the first step) is added *before* quantization and the new
    residual ``(x + err) - dequantized`` is returned for the next step, so
    the accumulated sum over steps converges to the uncompressed sum.

    ``axis_name=None`` degenerates to the single-device identity (no psum) —
    the form the SPMD train step and the CPU container exercise: the
    quantization error and residual carry are real, only the wire is not.
    With an ``axis_name`` (inside ``shard_map``/``pmap``) the reduction runs
    the two-stage int8 exchange, so the compiled HLO moves int8 — this is
    the path the forced-8-device tests compile and measure.

    Returns ``(summed, new_err)``.
    """
    xf = x.astype(jnp.float32)
    carry = xf if err is None else xf + err.astype(jnp.float32)
    if axis_name is None:
        q, scales = quantize_int8(carry, block)
        deq = dequantize_int8(q, scales, carry.size).reshape(carry.shape)
        return deq.astype(x.dtype), carry - deq
    out, new_err = _two_stage_int8_psum(jnp.ravel(carry), axis_name, block)
    return (out.reshape(carry.shape).astype(x.dtype),
            new_err.reshape(carry.shape))


# ---------------------------------------------------------------------------
# serve activation transport: quantized all-gathers, no error feedback
# ---------------------------------------------------------------------------

ACT_TRANSPORTS = ("bf16", "int8")
ACT_BLOCK = 256

# Disaggregated serving knobs (see "Disaggregated serving" in dist/README.md):
# the prefill->decode cache handoff wire format, and the decode-resident
# cache storage dtype. Orthogonal axes — transfer x storage combinations.
CACHE_TRANSFERS = ("bf16", "int8")
KV_STORAGES = ("bf16", "int8", "f8")

# f8 (e4m3) resident-cache storage: unlike int8, e4m3 carries its own
# per-element exponent, so the cast is *scale-free* — no `<leaf>_scale`
# companions, exactly half the bf16 bytes. e4m3fn has no inf encoding
# (overflow becomes nan), so the cast clips to the finite range first.
F8_DTYPE = jnp.float8_e4m3fn
F8_MAX = 448.0


def cast_f8(x: jnp.ndarray) -> jnp.ndarray:
    """Scale-free blockwise-safe cast to e4m3: values are clipped to the
    f8 finite range (e4m3fn saturates to nan, not inf) and cast. Blocks
    never interact — every element rounds independently — so the cast is
    local under any sharding and a slot-row write touches only its own
    bytes. Pair with :func:`uncast_f8` at read time."""
    return jnp.clip(x.astype(jnp.float32), -F8_MAX, F8_MAX).astype(F8_DTYPE)


def uncast_f8(q: jnp.ndarray, dtype=jnp.float32) -> jnp.ndarray:
    """Inverse of :func:`cast_f8` (exact: every f8 value is representable
    in f32/bf16). ``decode_attention`` calls this per read — the XLA path
    upcasts the whole operand; the Pallas kernel upcasts per K/V tile."""
    return q.astype(dtype)


def lastdim_blocks(d: int, block: int = ACT_BLOCK) -> Tuple[int, int]:
    """(block_size, n_blocks) the lastdim quantizer uses for a trailing dim
    of ``d``: ``block`` when it divides ``d``, else one block spanning the
    whole dim. Cache-layout code needs this to size scale leaves."""
    b = block if d % block == 0 else d
    return b, d // b


def quantize_int8_lastdim(x: jnp.ndarray, block: int = ACT_BLOCK
                          ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric int8 quantization blocked along the *trailing* axis only.

    Unlike :func:`quantize_int8` (which flattens the whole array), blocks
    never cross the trailing-axis boundary, so the op stays local under any
    sharding of the leading axes — the form the serve activation all-gather
    needs: quantize on the sequence shard, gather the int8 payload, then
    dequantize on the far side. A trailing dim not divisible by ``block``
    falls back to one block spanning the whole dim (always valid, coarser
    scales). Returns ``(q, scales)`` with ``q: int8`` of ``x.shape`` and
    ``scales: float32`` of ``x.shape[:-1] + (n_blocks,)``.
    """
    d = x.shape[-1]
    b, nb = lastdim_blocks(d, block)
    blocks = x.astype(jnp.float32).reshape(x.shape[:-1] + (nb, b))
    q, scales = _quantize_blocks(blocks)
    return q.reshape(x.shape), scales


def dequantize_int8_lastdim(q: jnp.ndarray, scales: jnp.ndarray
                            ) -> jnp.ndarray:
    """Inverse of :func:`quantize_int8_lastdim` (float32 out)."""
    nb = scales.shape[-1]
    d = q.shape[-1]
    blocks = q.reshape(q.shape[:-1] + (nb, d // nb))
    return _dequantize_blocks(blocks, scales).reshape(q.shape)


# ---------------------------------------------------------------------------
# disaggregated serving: prefill->decode cache stream + storage quantization
# ---------------------------------------------------------------------------

def quantize_int8_seqaxis(x: jnp.ndarray, seq_axis: int,
                          block: int = ACT_BLOCK
                          ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Blockwise int8 along the *sequence* axis of a cache leaf.

    The cache-stream wire format: the leaf is viewed with its sequence axis
    trailing and quantized with :func:`quantize_int8_lastdim`, so each block
    groups ``block`` consecutive positions of one feature channel — the
    natural chunking for a cache handed off as a stream, and (when ``block``
    divides the per-shard sequence length) local under the prefill side's
    sequence sharding. Returns ``(q, scales)`` in the seq-last layout; pair
    with :func:`dequantize_int8_seqaxis` on the receiving mesh.
    """
    return quantize_int8_lastdim(jnp.moveaxis(x, seq_axis, -1), block)


def dequantize_int8_seqaxis(q: jnp.ndarray, scales: jnp.ndarray,
                            seq_axis: int) -> jnp.ndarray:
    """Inverse of :func:`quantize_int8_seqaxis`: dequantize and move the
    sequence axis back to its cache position (float32 out)."""
    return jnp.moveaxis(dequantize_int8_lastdim(q, scales), -1, seq_axis)


def stream_int8(x: jnp.ndarray, *logical_axes: Optional[str],
                seq_axis: int, block: int = ACT_BLOCK) -> jnp.ndarray:
    """Reshard a cache leaf to the layout named by ``logical_axes`` moving
    seq-blockwise int8 chunks + f32 scales on the wire — the single-mesh
    form of the prefill->decode cache stream (the dryrun compiles this to
    measure transfer wire bytes; the two-mesh launcher runs the same
    quantize/dequantize pair around a ``device_put``).

    ``logical_axes`` names the *target* (decode-side) layout in the leaf's
    own axis order; ``seq_axis`` is the sequence axis index. The quantized
    arrays are constrained to the target layout so XLA's resharding
    collective carries s8 instead of the raw payload.
    """
    axes = list(logical_axes)
    axes.append(axes.pop(seq_axis))          # seq-last, matching q's layout
    q, scales = quantize_int8_seqaxis(x, seq_axis, block)
    q = _shd.constrain(q, *axes)
    scales = _shd.constrain(scales, *axes[:-1], None)
    return dequantize_int8_seqaxis(q, scales, seq_axis).astype(x.dtype)


def stream_slot_int8(cache_leaf: jnp.ndarray, new_slice: jnp.ndarray, slot,
                     *logical_axes: Optional[str], seq_axis: int,
                     batch_axis: int = 1, block: int = ACT_BLOCK
                     ) -> jnp.ndarray:
    """Per-slot variant of :func:`stream_int8` — the continuous-streaming
    admission primitive: quantize ONE request's ``[..., 1, ..., seq, ...]``
    cache slice seq-blockwise, ship the s8 chunks + f32 scales (constrained
    to the slot-row target layout so a cross-layout reshard carries s8,
    not the raw slice), dequantize, and write the arrived slice into row
    ``slot`` along ``batch_axis`` of the *running* decode cache leaf.

    ``logical_axes`` names the slice's target layout (its batch dim is 1,
    so the batch rule never actually shards it — the slot row's home
    device set receives the whole slice); ``slot`` may be a traced scalar,
    so one compiled admission program serves every slot."""
    arrived = stream_int8(new_slice, *logical_axes, seq_axis=seq_axis,
                          block=block).astype(cache_leaf.dtype)
    start = [jnp.zeros((), jnp.int32)] * cache_leaf.ndim
    start[batch_axis] = jnp.asarray(slot, jnp.int32)
    return jax.lax.dynamic_update_slice(cache_leaf, arrived, tuple(start))


def stream_row_int8(cache_leaf: jnp.ndarray, new_row: jnp.ndarray, slot,
                    *logical_axes: Optional[str], batch_axis: int = 0,
                    block: int = ACT_BLOCK) -> jnp.ndarray:
    """Per-row variant of :func:`stream_slot_int8` for state leaves with
    no sequence axis — the recurrent-family admission primitive (SSM conv
    and ssm states, mLSTM C/n/m, sLSTM h/c/n/m): quantize ONE request's
    O(1) state row blockwise along its trailing feature axis, ship the s8
    chunks + f32 scales (constrained to the slot-row target layout so a
    cross-layout reshard carries s8, not the raw row), dequantize, and
    overwrite row ``slot`` along ``batch_axis`` of the running state
    store. ``slot`` may be a traced scalar, so one compiled admission
    program serves every slot."""
    q, scales = quantize_int8_lastdim(new_row, block)
    q = _shd.constrain(q, *logical_axes)
    scales = _shd.constrain(scales, *logical_axes[:-1], None)
    arrived = dequantize_int8_lastdim(q, scales).astype(cache_leaf.dtype)
    start = [jnp.zeros((), jnp.int32)] * cache_leaf.ndim
    start[batch_axis] = jnp.asarray(slot, jnp.int32)
    return jax.lax.dynamic_update_slice(cache_leaf, arrived, tuple(start))


class _TraceScope(threading.local):
    """Thread-local trace-time value stack — the shared machinery behind
    the serve-path knobs (activation transport, KV storage). ``None``
    pushed into a scope normalizes to the stack's default; reading an
    empty stack returns the default too. Like ``sharding.axis_rules``
    these scopes only affect tracing, so a jitted step keeps the values
    it was traced with."""

    def __init__(self, name: str, allowed: Tuple[str, ...],
                 default: Optional[str] = None):
        self.name = name
        self.allowed = allowed
        self.default = default
        self.items: list = []

    def current(self) -> Optional[str]:
        return self.items[-1] if self.items else self.default


class _trace_scope_ctx:
    def __init__(self, stack: _TraceScope, mode: Optional[str]):
        if mode is not None and mode not in stack.allowed:
            raise ValueError(f"unknown {stack.name} {mode!r}; "
                             f"expected one of {stack.allowed}")
        self.stack = stack
        self.mode = stack.default if mode is None else mode

    def __enter__(self) -> "_trace_scope_ctx":
        self.stack.items.append(self.mode)
        return self

    def __exit__(self, *exc) -> bool:
        self.stack.items.pop()
        return False


_act_ctx = _TraceScope("act_transport", ACT_TRANSPORTS, None)


def current_act_transport() -> Optional[str]:
    """Active serve activation transport, or None outside any scope."""
    return _act_ctx.current()


def act_transport_scope(mode: Optional[str]) -> _trace_scope_ctx:
    """Trace-time scope selecting how serve activation all-gathers cross
    the wire (``"bf16"`` — plain constrained reshard — or ``"int8"`` —
    blockwise int8 chunks + scales; ``None`` disables the boundary).
    Entered by the prefill/decode step factories; model code reads it
    through :func:`act_gather`."""
    return _trace_scope_ctx(_act_ctx, mode)


def all_gather_int8(x: jnp.ndarray, *logical_axes: Optional[str],
                    block: int = ACT_BLOCK) -> jnp.ndarray:
    """Reshard ``x`` to the layout named by ``logical_axes`` moving
    blockwise int8 + per-block f32 scales on the wire instead of the raw
    payload: quantize locally (blocks along the trailing axis never cross a
    shard of the leading axes), constrain the *quantized* arrays to the
    target layout so XLA's resharding all-gather carries s8, dequantize on
    the gathered side. ~(1 + 4/block)/2 of the bf16 wire bytes.

    An already-compressed payload — an int8- or f8-resident KV cache under
    ``kv_storage={"int8","f8"}`` — passes through as a plain constrained
    reshard: it is as small as this transport could make it, and rounding
    s8/e4m3 values through a fresh abs-max int8 scale would only add
    error."""
    if x.dtype in (jnp.int8, F8_DTYPE):
        return _shd.constrain(x, *logical_axes)
    q, scales = quantize_int8_lastdim(x, block)
    q = _shd.constrain(q, *logical_axes)
    scales = _shd.constrain(scales, *logical_axes[:-1], None)
    return dequantize_int8_lastdim(q, scales).astype(x.dtype)


_kv_ctx = _TraceScope("kv_storage", KV_STORAGES, "bf16")


def current_kv_storage() -> str:
    """Active decode-cache storage dtype ("bf16" outside any scope)."""
    return _kv_ctx.current()


def kv_storage_scope(mode: Optional[str]) -> _trace_scope_ctx:
    """Trace-time scope selecting the decode KV cache's *resident* dtype:
    ``"bf16"`` (the default, full-precision leaves), ``"int8"`` (each
    leaf stored as blockwise-int8 values + f32 scales along the trailing
    feature axis; written tokens quantize per-position on the way in and
    attention dequantizes per-block at read time), or ``"f8"`` (scale-free
    e4m3 leaves via :func:`cast_f8`; exactly half the bf16 bytes, upcast
    per block at read time). Entered by
    ``make_decode_step``; attention layers read it through
    :func:`current_kv_storage`. Orthogonal to :func:`act_transport_scope`
    (the storage dtype is what the cache *is*; the transport is how a
    reshard crosses the wire)."""
    return _trace_scope_ctx(_kv_ctx, mode)


def act_gather(x: jnp.ndarray, *logical_axes: Optional[str]) -> jnp.ndarray:
    """The serve activation all-gather boundary.

    Moves ``x`` to the (gathered) layout named by ``logical_axes`` under
    the active :class:`act_transport_scope`: ``"bf16"`` pins a plain
    ``constrain`` (XLA reshards the raw payload), ``"int8"`` routes the
    reshard through :func:`all_gather_int8`. Outside any scope (training,
    legacy callers) this is the identity, so model code is unchanged
    everywhere the serve transport is not explicitly enabled."""
    mode = current_act_transport()
    if mode is None:
        return x
    if mode == "int8":
        return all_gather_int8(x, *logical_axes)
    return _shd.constrain(x, *logical_axes)
