"""Optional filters between OODA phases (§3.3/§4.1): refine the candidate
pool using statistics and table usage. Platform-specific policies (recently
created tables, write-conflict risk, trivial tables) are expressed here.
"""

from __future__ import annotations

from typing import Callable, Iterable, List, Optional

from repro.core.model import Candidate


class MinAgeFilter:
    """OpenHouse policy: don't compact tables created within a window —
    avoids spending budget on tables that won't affect long-term health."""

    def __init__(self, min_age_hours: float, now_fn: Callable[[], float]):
        self.min_age = min_age_hours
        self.now_fn = now_fn

    def __call__(self, c: Candidate) -> bool:
        return (self.now_fn() - c.stats.created_at) >= self.min_age


class RecentWriteFilter:
    """Skip candidates with very recent writes (conflict risk, §4.4)."""

    def __init__(self, quiet_hours: float, now_fn: Callable[[], float]):
        self.quiet = quiet_hours
        self.now_fn = now_fn

    def __call__(self, c: Candidate) -> bool:
        return (self.now_fn() - c.stats.last_write_at) >= self.quiet


class MinSmallFilesFilter:
    """Compaction is pointless below a handful of small files."""

    def __init__(self, min_small_files: int = 2):
        self.min_small = min_small_files

    def __call__(self, c: Candidate) -> bool:
        return c.stats.small_file_count >= self.min_small


class MaxCostFilter:
    """Discard candidates whose estimated cost exceeds a hard cap (§4.2:
    'candidates with a compute cost that exceeds the allocated budget can be
    automatically discarded')."""

    def __init__(self, max_gbhr: float):
        self.max_gbhr = max_gbhr

    def __call__(self, c: Candidate) -> bool:
        return c.traits.get("compute_cost", 0.0) <= self.max_gbhr


def apply_filters(cands: Iterable[Candidate], filters) -> List[Candidate]:
    out = []
    for c in cands:
        if all(f(c) for f in filters):
            out.append(c)
    return out
