"""Step factories: train_step (grad-accum microbatching + AdamW) and
serve steps (prefill / decode). These are the functions the launcher jits
with explicit in/out shardings and the dry-run lowers on the production mesh.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig
from repro.configs.shapes import ShapeSpec
from repro.models import transformer
from repro.train import optimizer as opt_lib


def make_loss_fn(cfg: ModelConfig):
    def loss_fn(params, batch):
        loss, metrics = transformer.forward(cfg, params, batch, "train")
        return loss, metrics
    return loss_fn


def _split_microbatches(batch: Dict[str, Any], n_mb: int) -> Dict[str, Any]:
    def split(x):
        b = x.shape[0]
        assert b % n_mb == 0, (b, n_mb)
        return x.reshape(n_mb, b // n_mb, *x.shape[1:])
    return jax.tree.map(split, batch)


def make_train_step(cfg: ModelConfig, adamw: opt_lib.AdamWConfig,
                    microbatches: int = 1):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    Gradient accumulation runs as a ``lax.scan`` over microbatches; gradients
    are accumulated in fp32 and averaged. With FSDP/ZeRO rules the gradient
    reduction crosses the network in bf16 (network dtype), while the AdamW
    math is fp32 on the local shard.
    """
    loss_fn = make_loss_fn(cfg)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, batch):
        if microbatches > 1:
            mb = _split_microbatches(batch, microbatches)

            def accum(carry, mb_batch):
                gacc, lacc = carry
                (loss, metrics), grads = grad_fn(params, mb_batch)
                gacc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), gacc, grads)
                return (gacc, lacc + loss), metrics

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), metrics_stack = jax.lax.scan(accum, (g0, 0.0), mb)
            grads = jax.tree.map(lambda g: (g / microbatches).astype(jnp.bfloat16),
                                 gsum)
            metrics = jax.tree.map(lambda m: m[-1], metrics_stack)
            metrics["loss"] = lsum / microbatches
        else:
            (loss, metrics), grads = grad_fn(params, batch)
        new_params, new_opt, opt_metrics = opt_lib.apply_updates(
            adamw, params, grads, opt_state)
        metrics.update(opt_metrics)
        return new_params, new_opt, metrics

    return train_step


def make_encode_step(cfg: ModelConfig):
    """Encoder-only serving: full-sequence unit logits (HuBERT-style)."""
    def encode_step(params, batch):
        logits, _ = transformer.forward(cfg, params, batch, "encode")
        return logits
    return encode_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        logits, cache = transformer.forward(cfg, params, batch, "prefill")
        return logits, cache
    return prefill_step


def make_decode_step(cfg: ModelConfig, cache_len_total: int):
    def decode_step(params, cache, batch):
        logits, new_cache = transformer.forward(
            cfg, params, batch, "decode", cache=cache,
            cache_len_total=cache_len_total)
        return logits, new_cache
    return decode_step


def step_for_shape(cfg: ModelConfig, shape: ShapeSpec,
                   adamw: Optional[opt_lib.AdamWConfig] = None):
    """The function the dry-run lowers for a given cell, plus its kind."""
    if shape.kind == "train":
        return make_train_step(cfg, adamw or opt_lib.AdamWConfig(),
                               microbatches=shape.microbatches), "train"
    if shape.kind == "prefill":
        if not cfg.supports_decode:      # encoder: no cache semantics
            return make_encode_step(cfg), "encode"
        return make_prefill_step(cfg), "prefill"
    return make_decode_step(cfg, shape.seq_len), "decode"
