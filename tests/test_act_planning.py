"""Scheduler planning cost: one bin-pack per table per ``execute`` call.

``Scheduler.plan`` used to run ``comp.plan_table`` over the WHOLE table
for every partition-scope candidate and then filter to the candidate's
partition — O(P^2) bins planned for P partition candidates of one table.
``execute`` now plans each table once and dispatches bins by partition
(execution never crosses partitions, so compacting one partition leaves
the other partitions' bins valid)."""

import pytest

from repro.core import act
from repro.core.model import Candidate, Scope
from repro.lst import Catalog, InMemoryStore
from repro.lst import compaction as comp
from repro.lst.files import DataFile
from repro.lst.workload import SimClock

MB = 1 << 20


def make_table(n_parts=6, files_per_part=3):
    clock = SimClock()
    store = InMemoryStore()
    cat = Catalog(store, now_fn=clock.now)
    t = cat.create_table("ns", "t", "p")
    t.now_fn = clock.now
    files = []
    for p in range(n_parts):
        for i in range(files_per_part):
            path = f"{t.table_id}/data/p{p}-f{i}.bin"
            t.store.put(path, b"x" * 64)
            files.append(DataFile(path, 4 * MB, 10, f"part{p}"))
    t.append(files)
    return t


@pytest.fixture
def plan_counter(monkeypatch):
    """Count comp.plan_table calls made through the act module."""
    calls = {"n": 0}
    real = comp.plan_table

    def counting(*args, **kwargs):
        calls["n"] += 1
        return real(*args, **kwargs)

    monkeypatch.setattr(act.comp, "plan_table", counting)
    return calls


class TestLinearPlanning:
    def test_one_plan_per_table_for_partition_candidates(self, plan_counter):
        n_parts = 6
        t = make_table(n_parts=n_parts)
        cands = [Candidate(table=t, scope=Scope.PARTITION,
                           partition=f"part{p}") for p in range(n_parts)]
        sched = act.Scheduler(target_file_bytes=64 * MB)
        report = sched.execute(cands)
        # the counter-based linearity claim: P partition candidates of one
        # table cost ONE whole-table bin-pack, not P
        assert plan_counter["n"] == 1
        # and every partition actually got compacted
        assert len(report.results) == n_parts
        assert all(r.success for r in report.results)
        assert t.file_count() == n_parts

    def test_dispatch_matches_per_candidate_replanning(self):
        """The cached-plan dispatch compacts exactly what per-candidate
        replanning compacted: one output file per partition, same bytes."""
        t1, t2 = make_table(), make_table()
        cands = lambda t: [Candidate(table=t, scope=Scope.PARTITION,
                                     partition=f"part{p}") for p in range(6)]
        fast = act.Scheduler(target_file_bytes=64 * MB).execute(cands(t1))
        # reference: plan each candidate independently (the old behavior)
        slow_removed = 0
        for cand in cands(t2):
            tasks = act.Scheduler(target_file_bytes=64 * MB).plan(cand)
            for task in tasks:
                res = comp.execute_task(t2, task)
                assert res.success
                slow_removed += res.files_removed
        assert fast.files_removed == slow_removed
        assert sorted(f.partition for f in t1.current_files()) \
            == sorted(f.partition for f in t2.current_files())

    def test_table_scope_execution_invalidates_cached_plan(self,
                                                           plan_counter):
        t = make_table(n_parts=2)
        cands = [Candidate(table=t, scope=Scope.TABLE),
                 Candidate(table=t, scope=Scope.TABLE)]
        sched = act.Scheduler(target_file_bytes=64 * MB)
        report = sched.execute(cands)
        # an atomic table rewrite changes every partition's files: the
        # second table-scope candidate must replan, not reuse stale bins
        assert plan_counter["n"] == 2
        assert report.results[0].success

    def test_public_plan_api_unchanged(self):
        t = make_table(n_parts=3)
        sched = act.Scheduler(target_file_bytes=64 * MB)
        tasks = sched.plan(Candidate(table=t, scope=Scope.PARTITION,
                                     partition="part1"))
        assert tasks and all(task.scope == "part1" for task in tasks)
        all_tasks = sched.plan(Candidate(table=t, scope=Scope.TABLE))
        assert {task.scope for task in all_tasks} \
            == {f"part{p}" for p in range(3)}


class TestStalePlanInvalidation:
    """A cached bin that references a no-longer-live file — consumed by an
    earlier candidate, or deleted by a concurrent writer — must trigger a
    replan, never execute (a stale bin would merge a logically-deleted
    file's rows into the compacted output)."""

    def test_concurrent_delete_between_candidates_replans(self,
                                                          plan_counter):
        """A writer deletes a part1 file while part0's candidate runs;
        part1's candidate must not execute the bin planned before the
        delete (which still references the deleted file)."""
        t = make_table(n_parts=2)
        victim = next(f for f in t.current_files()
                      if f.partition == "part1")
        state = {"done": False}

        def delete_part1_file(table, _task):
            if not state["done"]:
                state["done"] = True
                table.delete_files([victim])

        cands = [Candidate(table=t, scope=Scope.PARTITION, partition="part0"),
                 Candidate(table=t, scope=Scope.PARTITION, partition="part1")]
        report = act.Scheduler(target_file_bytes=64 * MB,
                               interleave_fn=delete_part1_file,
                               ).execute(cands)
        assert plan_counter["n"] == 2    # staleness forced the replan
        assert all(r.success for r in report.results)
        # the deleted file's rows were NOT resurrected: no committed
        # compacted file in part1 counts it among its inputs
        for r in report.results:
            assert all(f.path != victim.path for f in r.task.inputs)

    def test_table_scope_after_partition_scope_replans(self, plan_counter):
        t = make_table(n_parts=3)
        cands = [Candidate(table=t, scope=Scope.PARTITION, partition="part0"),
                 Candidate(table=t, scope=Scope.TABLE)]
        report = act.Scheduler(target_file_bytes=64 * MB).execute(cands)
        assert all(r.success for r in report.results), \
            [r.error for r in report.results]
        assert plan_counter["n"] == 2    # dirtied part0 forces the replan
        assert t.file_count() == 3       # every partition compacted once

    def test_repeated_partition_candidate_replans(self, plan_counter):
        t = make_table(n_parts=2)
        cands = [Candidate(table=t, scope=Scope.PARTITION, partition="part0"),
                 Candidate(table=t, scope=Scope.PARTITION, partition="part0")]
        report = act.Scheduler(target_file_bytes=64 * MB).execute(cands)
        assert plan_counter["n"] == 2
        # first run compacts part0; rerun finds a single well-sized file
        # there and correctly plans nothing for it
        assert report.results and report.results[0].success
        assert len(report.results) == 1

    def test_distinct_partitions_untouched_by_dirtying(self, plan_counter):
        t = make_table(n_parts=4)
        cands = [Candidate(table=t, scope=Scope.PARTITION,
                           partition=f"part{p}") for p in range(4)]
        report = act.Scheduler(target_file_bytes=64 * MB).execute(cands)
        assert plan_counter["n"] == 1    # still one plan for the clean case
        assert all(r.success for r in report.results)
