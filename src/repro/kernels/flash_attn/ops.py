"""Public flash-attention wrapper (auto interpret on non-TPU backends)."""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels.flash_attn.flash_attn import flash_attention_kernel
from repro.kernels.flash_attn.ref import flash_attention_ref


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k",
                                   "use_ref"))
def flash_attention(q, k, v, *, causal=True, window=0,
                    block_q=512, block_k=512, use_ref=False):
    if use_ref:
        return flash_attention_ref(q, k, v, causal=causal, window=window)
    return flash_attention_kernel(
        q, k, v, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=_use_interpret())
