"""Production mesh factory.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state. The dry-run entry point sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import so 512 placeholder host devices exist; everything else (tests,
benches, examples) sees the real single CPU device.
"""

from __future__ import annotations

import jax


def _auto(n):
    return (jax.sharding.AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))


def make_local_mesh(model_parallel: int = 1):
    """Mesh over whatever devices actually exist (tests / examples)."""
    n = jax.device_count()
    assert n % model_parallel == 0
    return jax.make_mesh((n // model_parallel, model_parallel),
                         ("data", "model"), axis_types=_auto(2))
