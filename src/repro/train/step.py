"""Step factories: train_step (grad-accum microbatching + AdamW) and
serve steps (prefill / decode). These are the functions the launcher jits
with explicit in/out shardings and the dry-run lowers on the production mesh.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig
from repro.configs.shapes import ShapeSpec
from repro.dist import collectives
from repro.models import registry as model_registry
from repro.models import transformer
from repro.train import optimizer as opt_lib

GRAD_TRANSPORTS = ("bf16", "int8_ef")
ACT_TRANSPORTS = collectives.ACT_TRANSPORTS   # serve steps: ("bf16", "int8")
KV_STORAGES = collectives.KV_STORAGES         # decode cache residency
CACHE_TRANSFERS = collectives.CACHE_TRANSFERS # prefill->decode handoff wire


def make_loss_fn(cfg: ModelConfig):
    def loss_fn(params, batch):
        loss, metrics = transformer.forward(cfg, params, batch, "train")
        return loss, metrics
    return loss_fn


def _split_microbatches(batch: Dict[str, Any], n_mb: int) -> Dict[str, Any]:
    def split(x):
        b = x.shape[0]
        assert b % n_mb == 0, (b, n_mb)
        return x.reshape(n_mb, b // n_mb, *x.shape[1:])
    return jax.tree.map(split, batch)


def _int8_ef_transport(grads, opt_state, axis_name, block):
    """Per-leaf int8+error-feedback reduction; residual lives in opt_state."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(opt_state["ef"])
    out = [collectives.compressed_psum(g, axis_name, e, block=block)
           for g, e in zip(flat_g, flat_e)]
    new_grads = treedef.unflatten([o[0] for o in out])
    new_ef = treedef.unflatten([o[1] for o in out])
    return new_grads, {**opt_state, "ef": new_ef}


def make_train_step(cfg: ModelConfig, adamw: opt_lib.AdamWConfig,
                    microbatches: int = 1, grad_transport: str = "bf16",
                    mesh=None, data_axis: str = "data", ef_block: int = 256):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    Gradient accumulation runs as a ``lax.scan`` over microbatches; gradients
    are accumulated in fp32 and averaged.

    ``grad_transport`` picks how the gradient crosses the network:

    * ``"bf16"`` — the baseline. With FSDP/ZeRO rules the reduction crosses
      in bf16 (network dtype) while the AdamW math is fp32 on the shard.
    * ``"int8_ef"`` — blockwise int8 quantization with error feedback
      (``repro.dist.collectives.compressed_psum``); the per-leaf residual is
      carried in optimizer state under ``opt_state["ef"]``, so build the
      state with ``opt_lib.init_state(params, error_feedback=True)``.

    Two execution modes:

    * ``mesh=None`` (default) — the SPMD step the dry-run lowers: XLA owns
      the collectives, so int8_ef applies quantize→dequantize+EF to the
      already-reduced gradient (compression *error* and residual carry are
      exact; the wire stays XLA's).
    * ``mesh=<jax Mesh>`` — an explicit data-parallel step wrapped in
      ``shard_map`` over ``data_axis`` (the cross-pod role): params and
      moments replicated, the batch split, and the gradient reduction done
      manually — bf16 ``psum`` vs the two-stage int8 exchange — so the
      compiled HLO moves exactly the transport's bytes. This is the path
      the forced-8-device mesh tests compile, execute, and measure.
      ``opt_state["ef"]`` is per-device here: build it with
      ``init_state(params, error_feedback=True, ef_devices=W)``.
    """
    if grad_transport not in GRAD_TRANSPORTS:
        raise ValueError(f"unknown grad_transport {grad_transport!r}; "
                         f"expected one of {GRAD_TRANSPORTS}")
    loss_fn = make_loss_fn(cfg)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def grads_and_metrics(params, batch):
        if microbatches > 1:
            mb = _split_microbatches(batch, microbatches)

            def accum(carry, mb_batch):
                gacc, lacc = carry
                (loss, metrics), grads = grad_fn(params, mb_batch)
                gacc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), gacc, grads)
                return (gacc, lacc + loss), metrics

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), metrics_stack = jax.lax.scan(accum, (g0, 0.0), mb)
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            if grad_transport == "bf16":
                grads = jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)
            metrics = jax.tree.map(lambda m: m[-1], metrics_stack)
            metrics["loss"] = lsum / microbatches
        else:
            (loss, metrics), grads = grad_fn(params, batch)
        return grads, metrics

    def train_step(params, opt_state, batch):
        grads, metrics = grads_and_metrics(params, batch)
        if grad_transport == "int8_ef":
            grads, opt_state = _int8_ef_transport(grads, opt_state, None,
                                                  ef_block)
        new_params, new_opt, opt_metrics = opt_lib.apply_updates(
            adamw, params, grads, opt_state)
        metrics.update(opt_metrics)
        return new_params, new_opt, metrics

    if mesh is None:
        return train_step
    return _data_parallel_step(grads_and_metrics, adamw, mesh, data_axis,
                               grad_transport, ef_block)


def _data_parallel_step(grads_and_metrics, adamw, mesh, data_axis,
                        grad_transport, ef_block):
    """shard_map DDP wrapper: batch split over ``data_axis``, params/moments
    replicated, the gradient reduction explicit (and therefore measurable)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    w = mesh.shape[data_axis]

    def device_step(params, opt_state, batch):
        grads, metrics = grads_and_metrics(params, batch)
        # each device holds d(mean local loss); global grad = psum(local)/W
        grads = jax.tree.map(lambda g: g.astype(jnp.float32) / w, grads)
        if grad_transport == "bf16":
            grads = jax.tree.map(
                lambda g: jax.lax.psum(g.astype(jnp.bfloat16), data_axis),
                grads)
        else:
            local = {**opt_state,
                     "ef": jax.tree.map(lambda e: e[0], opt_state["ef"])}
            grads, local = _int8_ef_transport(grads, local, data_axis,
                                              ef_block)
            opt_state = {**opt_state,
                         "ef": jax.tree.map(lambda e: e[None], local["ef"])}
        metrics = jax.tree.map(lambda m: jax.lax.pmean(m, data_axis), metrics)
        new_params, new_opt, opt_metrics = opt_lib.apply_updates(
            adamw, params, grads, opt_state)
        metrics.update(opt_metrics)
        return new_params, new_opt, metrics

    def opt_spec(with_ef):
        spec = {"mu": P(), "nu": P(), "step": P()}
        if with_ef:
            spec["ef"] = P(data_axis)   # per-device residual, leading axis
        return spec

    ospec = opt_spec(grad_transport == "int8_ef")
    return shard_map(device_step, mesh=mesh,
                     in_specs=(P(), ospec, P(data_axis)),
                     out_specs=(P(), ospec, P()),
                     check_rep=False)


def _check_act_transport(act_transport: Optional[str]) -> None:
    if act_transport is not None and act_transport not in ACT_TRANSPORTS:
        raise ValueError(f"unknown act_transport {act_transport!r}; "
                         f"expected one of {ACT_TRANSPORTS}")


def make_encode_step(cfg: ModelConfig, act_transport: Optional[str] = "bf16"):
    """Encoder-only serving: full-sequence unit logits (HuBERT-style)."""
    _check_act_transport(act_transport)

    def encode_step(params, batch):
        with collectives.act_transport_scope(act_transport):
            logits, _ = transformer.forward(cfg, params, batch, "encode")
        return logits
    return encode_step


def make_prefill_step(cfg: ModelConfig, act_transport: Optional[str] = "bf16"):
    """Returns prefill_step(params, batch) -> (last-position logits, cache).

    ``batch`` may carry ``"last_pos"`` (per-row index of the final prompt
    token) for ragged continuous batching; without it the logits come from
    the last sequence position of every row.

    ``act_transport`` picks how the sequence-parallel activation all-gather
    (the ``sp``/``serve_sp`` residual-stream gather before attention and
    the MLP) crosses the wire: ``"bf16"`` reshards the raw payload,
    ``"int8"`` moves blockwise-int8 chunks + scales
    (``collectives.all_gather_int8``). No error feedback: activations are
    stateless across steps, so per-step quantization error never compounds.
    ``None`` disables the serve gather boundary entirely (legacy layout).
    """
    _check_act_transport(act_transport)

    def prefill_step(params, batch):
        with collectives.act_transport_scope(act_transport):
            logits, cache = transformer.forward(cfg, params, batch, "prefill")
        return logits, cache
    return prefill_step


def make_decode_step(cfg: ModelConfig, cache_len_total: int,
                     act_transport: Optional[str] = "bf16",
                     kv_storage: str = "bf16"):
    """Returns decode_step(params, cache, batch) -> (logits, new_cache).

    ``batch["pos"]`` is a scalar position or a per-row ``(B,)`` vector
    (ragged continuous batching). Under the ``serve_sp`` preset the KV
    cache is sharded over data (batch) x model (sequence); decode's
    activation all-gather is the cache gather feeding single-token
    attention, and ``act_transport="int8"`` runs it as blockwise-int8
    chunks + scales (see :func:`make_prefill_step`).

    ``kv_storage="int8"`` makes the cache int8-*resident*: the step
    expects (and emits) the storage layout from
    ``transformer.abstract_cache(..., kv_storage="int8")`` — s8 value
    leaves plus f32 ``<leaf>_scale`` leaves — writes each new token
    quantized per position, and attention dequantizes per block at read
    time. ``"f8"`` stores scale-free e4m3 leaves instead (same shapes as
    bf16, half the bytes, upcast per block at read time). Orthogonal to
    ``act_transport`` (storage is what HBM holds; the transport is how a
    reshard crosses the wire).
    """
    _check_act_transport(act_transport)
    if kv_storage not in KV_STORAGES:
        raise ValueError(f"unknown kv_storage {kv_storage!r}; "
                         f"expected one of {KV_STORAGES}")
    if kv_storage != "bf16":
        model_registry.require(cfg, "quantized_storage",
                               f"kv_storage={kv_storage!r}")

    def decode_step(params, cache, batch):
        with collectives.act_transport_scope(act_transport), \
                collectives.kv_storage_scope(kv_storage):
            logits, new_cache = transformer.forward(
                cfg, params, batch, "decode", cache=cache,
                cache_len_total=cache_len_total)
        return logits, new_cache
    return decode_step


def step_for_shape(cfg: ModelConfig, shape: ShapeSpec,
                   adamw: Optional[opt_lib.AdamWConfig] = None,
                   grad_transport: str = "bf16",
                   act_transport: str = "bf16",
                   kv_storage: str = "bf16"):
    """The function the dry-run lowers for a given cell, plus its kind."""
    if shape.kind == "train":
        return make_train_step(cfg, adamw or opt_lib.AdamWConfig(),
                               microbatches=shape.microbatches,
                               grad_transport=grad_transport), "train"
    if shape.kind == "prefill":
        if not cfg.supports_decode:      # encoder: no cache semantics
            return make_encode_step(cfg, act_transport), "encode"
        return make_prefill_step(cfg, act_transport), "prefill"
    return make_decode_step(cfg, shape.seq_len, act_transport,
                            kv_storage), "decode"
