"""Causal GQA flash attention (training/prefill), Pallas TPU.

Grid: (B*H, num_q_blocks, num_kv_blocks) with the kv dimension sequential
("arbitrary") so the online-softmax state lives in VMEM scratch across kv
steps. GQA is expressed in the K/V BlockSpec index maps (query head h reads
kv head h // group) — no KV replication materializes, unlike the XLA path.

VMEM working set per step: q (bq, D) + k,v (bk, D) + acc (bq, D) f32 +
m/l (bq, 128) f32; with bq = bk = 512 and D <= 192 this is ~1.5 MB, well
under the ~16 MB v5e VMEM budget, and all matmul dims are multiples of 128
(MXU-aligned).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import CompilerParams as _CompilerParams

NEG_INF = -1e30
DEFAULT_BLOCK_Q = 512
DEFAULT_BLOCK_K = 512


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: int,
                  block_q: int, block_k: int, num_kv_blocks: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                     # (bq, D)
    k = k_ref[0, 0].astype(jnp.float32)                     # (bk, D)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        mask &= q_pos >= k_pos
    if window:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[:, :1]                                   # (bq, 1)
    l_prev = l_ref[:, :1]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_new = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ik == num_kv_blocks - 1)
    def _finish():
        l = l_ref[:, :1]
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l, 1e-30)
                       ).astype(o_ref.dtype)


def flash_attention_kernel(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                           *, causal: bool = True, window: int = 0,
                           block_q: int = DEFAULT_BLOCK_Q,
                           block_k: int = DEFAULT_BLOCK_K,
                           interpret: bool = False) -> jnp.ndarray:
    """q: (B, H, S, D); k, v: (B, Hkv, S, D) -> (B, H, S, D)."""
    b, h, s, d = q.shape
    hkv = k.shape[1]
    group = h // hkv
    bq = min(block_q, s)
    bk = min(block_k, s)
    assert s % bq == 0 and s % bk == 0
    nq, nk = s // bq, s // bk
    scale = 1.0 / (d ** 0.5)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=bq, block_k=bk, num_kv_blocks=nk)

    return pl.pallas_call(
        kernel,
        grid=(b * h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d),
                         lambda bh, iq, ik: (bh // h, bh % h, iq, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bh, iq, ik: (bh // h, (bh % h) // group, ik, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bh, iq, ik: (bh // h, (bh % h) // group, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d),
                               lambda bh, iq, ik: (bh // h, bh % h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),   # m
            pltpu.VMEM((bq, 128), jnp.float32),   # l
            pltpu.VMEM((bq, d), jnp.float32),     # acc
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
