"""Kernel microbenchmarks (interpret-mode correctness + host timing) and the
RewriteBytesPerHour calibration for the GBHr cost trait (§4.2): measured
throughput of the compact_pack merge path on this host feeds the cost model
the simulations use.

``--json`` additionally runs the tunable-kernel sweep harness
(repro.kernels.tune) over every registered op and writes a
BENCH_roofline-shaped artifact ({"records": [...]}) that
``scripts/bench_diff.py`` gates:

  * one record per op with ``kernel_<op>_default_s`` vs
    ``kernel_<op>_tuned_s`` (the tuned point is persisted to
    experiments/tuned/ and served from cache on re-runs), and
  * a compact_pack filter-fraction sweep: the fused filter+pack kernel vs
    the filter-then-pack reference at several delete fractions, with
    ``kernel_compact_filter_s``, the analytic HBM traffic of each path
    (``kernel_compact_filter_hbm_bytes`` — the fused gather reads only
    touched chunks and writes only kept rows; the reference reads and
    writes everything twice), and a bit-match check (record status flips
    to "mismatch" if fused != reference).

CI: bench-smoke runs ``--quick --json BENCH_kernels.json`` per PR;
nightly bench-sweep runs the full shapes with ``--sweep`` (force
re-tune) into its own BENCH_kernels_sweep lineage.
"""

from __future__ import annotations

import json
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _time_us(fn, *args, iters=3) -> float:
    fn(*args)  # compile/warm
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def main(quick: bool = False) -> List[str]:
    """``quick=True`` is the CI smoke mode: every workload shrinks so the
    whole suite exercises each kernel path in seconds — timings are then
    smoke numbers, not calibration data."""
    rows = []
    key = jax.random.PRNGKey(0)

    # compact_pack: oracle timing at realistic size (kernel timing in
    # interpret mode is not meaningful for throughput; oracle == same math)
    from repro.kernels.compact_pack import compact_chunks, plan_compaction
    from repro.kernels.compact_pack.compact_pack import CHUNK_TOKENS
    n_chunks = 256 if quick else 2048
    src = jax.random.randint(key, (n_chunks * CHUNK_TOKENS,), 0, 1 << 30,
                             dtype=jnp.int32)
    cm = plan_compaction([64] * (n_chunks // 64),
                         fragment_order=list(reversed(range(n_chunks // 64))))
    us = _time_us(lambda s: compact_chunks(s, cm, use_ref=True), src)
    byts = n_chunks * CHUNK_TOKENS * 4
    bph = byts / (us / 1e6) * 3600
    rows.append(f"kernel_compact_pack_ref,{us:.0f},"
                f"bytes={byts};rewrite_bytes_per_hour={bph:.3e}")
    usk = _time_us(lambda s: compact_chunks(s, cm), src)
    rows.append(f"kernel_compact_pack_interpret,{usk:.0f},correctness_path")

    # flash attention: kernel-vs-ref correctness scale + host us
    from repro.kernels.flash_attn import flash_attention
    seq = 128 if quick else 512
    q = jax.random.normal(key, (1, 4, seq, 64), jnp.float32).astype(jnp.bfloat16)
    k = jax.random.normal(key, (1, 2, seq, 64), jnp.float32).astype(jnp.bfloat16)
    v = jax.random.normal(key, (1, 2, seq, 64), jnp.float32).astype(jnp.bfloat16)
    us_ref = _time_us(lambda a, b, c: flash_attention(a, b, c, use_ref=True),
                      q, k, v)
    us_k = _time_us(lambda a, b, c: flash_attention(a, b, c, block_q=128,
                                                    block_k=128), q, k, v)
    rows.append(f"kernel_flash_attn_ref,{us_ref:.0f},B1H4S{seq}D64")
    rows.append(f"kernel_flash_attn_interpret,{us_k:.0f},B1H4S{seq}D64")

    # decode attention
    from repro.kernels.decode_attn import decode_attention
    clen = 512 if quick else 2048
    qd = jax.random.normal(key, (4, 8, 64), jnp.float32).astype(jnp.bfloat16)
    kc = jax.random.normal(key, (4, clen, 2, 64), jnp.float32).astype(jnp.bfloat16)
    vc = jax.random.normal(key, (4, clen, 2, 64), jnp.float32).astype(jnp.bfloat16)
    lens = jnp.array([clen, clen // 2, clen // 4, 100], jnp.int32)
    us_ref = _time_us(lambda a, b, c, l: decode_attention(a, b, c, l,
                                                          use_ref=True),
                      qd, kc, vc, lens)
    us_k = _time_us(lambda a, b, c, l: decode_attention(a, b, c, l,
                                                        block_k=512),
                    qd, kc, vc, lens)
    rows.append(f"kernel_decode_attn_ref,{us_ref:.0f},B4S{clen}")
    rows.append(f"kernel_decode_attn_interpret,{us_k:.0f},B4S{clen}")

    # rmsnorm
    from repro.kernels.rmsnorm import rmsnorm
    rows_n = 512 if quick else 4096
    x = jax.random.normal(key, (rows_n, 1024), jnp.float32).astype(jnp.bfloat16)
    sc = jnp.ones((1024,), jnp.bfloat16)
    us_ref = _time_us(lambda a, b: rmsnorm(a, b, use_ref=True), x, sc)
    us_k = _time_us(lambda a, b: rmsnorm(a, b), x, sc)
    rows.append(f"kernel_rmsnorm_ref,{us_ref:.0f},R{rows_n}D1024")
    rows.append(f"kernel_rmsnorm_interpret,{us_k:.0f},R{rows_n}D1024")
    return rows


def _record(shape: str, preset: str, roofline: Dict[str, float],
            status: str = "ok", **extra: Any) -> Dict[str, Any]:
    """One BENCH_roofline-shaped record (same cell-key fields the other
    artifacts use, so bench_diff matches cells across runs)."""
    rec = {
        "arch": "kernel",
        "shape": shape,
        "mesh": None, "preset": preset,
        "grad_transport": None, "act_transport": None,
        "microbatches": None, "remat_block": None, "capacity_factor": None,
        "status": status,
        "roofline": {k: float(v) for k, v in roofline.items()},
    }
    rec.update(extra)
    return rec


def tuned_records(quick: bool, iters: int = 3,
                  force: bool = False) -> List[Dict[str, Any]]:
    """Sweep every registered op (cache-first unless ``force``), then time
    the clamped default point against the tuned winner on the same
    operands — the gated ``kernel_<op>_tuned_s`` trajectory."""
    from repro.kernels import api, tune

    preset = "kernel-quick" if quick else "kernel-full"
    records = []
    for name, op in api.ops().items():
        outcome = tune.tune_op(name, quick=quick, iters=iters, force=force)
        args, kwargs = op.example(quick)
        default = op.clamp(api.default_point(op), *args, **kwargs)
        default_us = tune.time_point(op, default, args, kwargs, iters=iters)
        tuned_us = tune.time_point(op, outcome.point, args, kwargs,
                                   iters=iters)
        records.append(_record(
            f"{name}:{outcome.shape_key}", preset,
            {f"kernel_{name}_default_s": default_us / 1e6,
             f"kernel_{name}_tuned_s": tuned_us / 1e6},
            point=dict(outcome.point), default_point=dict(default),
            cache_hit=outcome.cache_hit,
            sweep_evaluations=outcome.evaluations))
    return records


FILTER_FRACTIONS = (0.1, 0.5, 0.9)


def filter_records(quick: bool, iters: int = 3) -> List[Dict[str, Any]]:
    """compact_pack filter-fraction sweep: fused filter+pack vs the
    two-pass filter-then-pack reference at several delete fractions.

    The HBM model comes from the plan, not the stopwatch: the fused gather
    reads only touched source chunks (+1 flush re-read at most) and writes
    only ceil(kept/8) chunks; the reference reads every planned chunk,
    writes the full packed stream, re-reads it, and writes the kept rows.
    Bit-equality of the two outputs is checked on every cell — a mismatch
    flips the record status, which drops it from the gate (bench_diff only
    matches "ok" cells) and fails the lost-metric check loudly.
    """
    from repro.kernels.compact_pack import compact_chunks, plan_compaction
    from repro.kernels.compact_pack.ops import plan_filter
    from repro.kernels.compact_pack.compact_pack import (
        CHUNK_ROWS, CHUNK_TOKENS)

    preset = "kernel-quick" if quick else "kernel-full"
    n_chunks = 128 if quick else 1024
    frag = 16 if quick else 64
    key = jax.random.PRNGKey(0)
    src = jax.random.randint(key, (n_chunks * CHUNK_TOKENS,), 0, 1 << 30,
                             dtype=jnp.int32)
    cm = plan_compaction([frag] * (n_chunks // frag),
                         fragment_order=list(reversed(range(n_chunks // frag))))
    rng = np.random.RandomState(0)
    itemsize = 4
    records = []
    for frac in FILTER_FRACTIONS:
        keep = rng.rand(n_chunks * CHUNK_ROWS) >= frac   # frac = drop rate
        fused = np.asarray(compact_chunks(src, cm, keep_mask=keep))
        ref = np.asarray(compact_chunks(src, cm, use_ref=True,
                                        keep_mask=keep))
        bit_match = bool(np.array_equal(fused, ref))
        us_fused = _time_us(
            lambda s: compact_chunks(s, cm, keep_mask=keep), src,
            iters=iters)
        us_ref = _time_us(
            lambda s: compact_chunks(s, cm, use_ref=True, keep_mask=keep),
            src, iters=iters)
        chunk_sel, _, _, _, n_out = plan_filter(cm, keep)
        fused_bytes = (len(chunk_sel) + n_out) * CHUNK_TOKENS * itemsize
        ref_bytes = (3 * len(cm) + n_out) * CHUNK_TOKENS * itemsize
        records.append(_record(
            f"compact_filter:n{n_chunks}_drop{int(frac * 100)}", preset,
            {"kernel_compact_filter_s": us_fused / 1e6,
             "kernel_compact_filter_ref_s": us_ref / 1e6,
             "kernel_compact_filter_hbm_bytes": fused_bytes,
             "kernel_compact_filter_ref_hbm_bytes": ref_bytes},
            status="ok" if bit_match else "mismatch",
            bit_match=bit_match,
            touched_chunks=int(len(chunk_sel)), out_chunks=int(n_out)))
    return records


def cli(argv: Optional[List[str]] = None) -> int:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke mode: tiny shapes, seconds not minutes")
    ap.add_argument("--json", default=None,
                    help="run the tunable-kernel sweep and write a "
                         "BENCH_roofline-shaped artifact here")
    ap.add_argument("--sweep", action="store_true",
                    help="force a fresh block sweep even on a tuned-cache "
                         "hit (the nightly refresh path)")
    ap.add_argument("--iters", type=int, default=3)
    args = ap.parse_args(argv)

    for r in main(quick=args.quick):
        print(r)
    if args.json:
        records = tuned_records(args.quick, iters=args.iters,
                                force=args.sweep)
        records += filter_records(args.quick, iters=args.iters)
        from repro.kernels import tuned
        payload = {"cells": len(records), "records": records,
                   "config": {"quick": args.quick, "sweep": args.sweep,
                              "iters": args.iters,
                              "device_kind": tuned.device_kind(),
                              "tuned_cache": str(tuned.cache_path())}}
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"wrote {args.json} ({len(records)} records)")
        bad = [r["shape"] for r in records if r["status"] != "ok"]
        if bad:
            print(f"BIT-MATCH FAILURE in cells: {bad}")
            return 1
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(cli())
