"""Observe phase (§3.3/§4.1): extract statistics for each candidate.

The standardized stats layout supports generic metrics (file counts/sizes)
plus platform-specific custom metrics injected through ``custom_fns`` —
e.g. access frequency from the data-pipeline reader, or checkpoint age from
the training runner.

Fleet-scale note: the generic statistics of a candidate are a pure function
of its snapshot, so the collector memoizes them per (table, scope,
partition, snapshot). A 2k-table fleet cycle re-scans only the tables whose
snapshot actually moved since the last cycle; everything else is a dict
hit. Activity-derived metrics (query frequency, write rates from
``lst.workload.ActivityTracker``) move *without* a new snapshot, so they
are re-evaluated on every observe and never cached.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, Iterable, List, Optional, Tuple

from repro.core.model import Candidate, CandidateStats

BUCKETS = 16  # power-of-two size buckets starting at 1 MiB


def size_bucket(size_bytes: int) -> int:
    mb = max(size_bytes / (1 << 20), 1e-6)
    b = int(math.floor(math.log2(mb))) + 1 if mb >= 1 else 0
    return min(max(b, 0), BUCKETS - 1)


class StatsCollector:
    def __init__(self, target_file_bytes: int,
                 custom_fns: Optional[Dict[str, Callable]] = None,
                 activity=None) -> None:
        self.target = target_file_bytes
        self.custom_fns = custom_fns or {}
        # activity: lst.workload.ActivityTracker (or anything with
        # read_rate/write_file_rate/burstiness) feeding query-frequency
        # stats into the candidate pool
        self.activity = activity
        # (table_id, scope, partition) -> (snapshot_id, stats sans custom);
        # one slot per candidate identity, so memory is bounded by the
        # candidate pool, not by history
        self._memo: Dict[Tuple[str, str, str],
                         Tuple[Optional[int], CandidateStats]] = {}
        self.memo_hits = 0
        self.memo_misses = 0

    def _scan(self, cand: Candidate) -> CandidateStats:
        files = cand.files()
        hist = [0] * BUCKETS
        small = 0
        small_bytes = 0
        total = 0
        for f in files:
            hist[size_bucket(f.size_bytes)] += 1
            total += f.size_bytes
            if f.size_bytes < self.target:
                small += 1
                small_bytes += f.size_bytes
        return CandidateStats(
            file_count=len(files),
            total_bytes=total,
            small_file_count=small,
            small_bytes=small_bytes,
            size_histogram=tuple(hist),
            partition_count=len({f.partition for f in files}),
            created_at=cand.table.meta.created_at,
            last_write_at=cand.table.meta.last_write_at,
        )

    def observe(self, cand: Candidate) -> CandidateStats:
        key = (cand.table.table_id, cand.scope.value, cand.partition or "")
        sid = cand.snapshot_id if cand.snapshot_id is not None \
            else cand.table.meta.current_snapshot_id
        hit = self._memo.get(key)
        if hit is not None and hit[0] == sid:
            self.memo_hits += 1
            stats = dataclasses.replace(hit[1], custom={})
        else:
            self.memo_misses += 1
            stats = self._scan(cand)
            self._memo[key] = (sid, dataclasses.replace(stats, custom={}))
        if self.activity is not None:
            tid = cand.table.table_id
            stats.custom["query_freq"] = self.activity.read_rate(tid)
            stats.custom["write_rate"] = self.activity.write_rate(tid)
            stats.custom["write_file_rate"] = \
                self.activity.write_file_rate(tid)
            stats.custom["burstiness"] = self.activity.burstiness(tid)
        for name, fn in self.custom_fns.items():
            stats.custom[name] = fn(cand)
        cand.stats = stats
        return stats

    def observe_all(self, cands: Iterable[Candidate]) -> List[Candidate]:
        out = []
        for c in cands:
            self.observe(c)
            out.append(c)
        return out
