"""Fault-tolerant training runner.

Production behaviors implemented (and exercised by tests/examples):
  * checkpoint/restart: periodic async saves; ``run_with_recovery`` restores
    from the latest checkpoint after a (simulated) preemption and continues
    — loss trajectory is continuous across the restart;
  * elastic scaling: restore works under a different data-parallel degree
    (the global batch is re-microbatched; shardings recomputed for the new
    mesh);
  * straggler mitigation: per-step host timing with a rolling median; steps
    slower than ``straggler_factor`` x median are flagged, and a pluggable
    policy reacts (on a real fleet: evict/replace the slow host; here the
    hook records and the simulated straggler is removed);
  * storage healing: an AutoComp service tick runs between steps (the
    "separate compaction cluster" of §4.4 — host threads, never blocking
    the device step).
"""

from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Any, Callable, Dict, Iterator, List, Optional

import jax
import numpy as np

from repro.train import optimizer as opt_lib
from repro.train.checkpoints import CheckpointManager


class SimulatedPreemption(Exception):
    """Raised by fault-injection hooks to model a node preemption."""


@dataclasses.dataclass
class RunnerConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    async_ckpt: bool = True
    straggler_factor: float = 3.0
    straggler_window: int = 16


class Trainer:
    def __init__(self, cfg: RunnerConfig, train_step: Callable,
                 params: Any, opt_state: Any,
                 batches: Callable[[], Iterator[Dict[str, np.ndarray]]],
                 ckpt: Optional[CheckpointManager] = None,
                 autocomp_tick: Optional[Callable[[], Any]] = None,
                 fault_hook: Optional[Callable[[int], None]] = None,
                 straggler_hook: Optional[Callable[[int, float], float]] = None,
                 on_straggler: Optional[Callable[[int, float, float], None]] = None
                 ) -> None:
        self.cfg = cfg
        self.train_step = train_step
        self.params = params
        self.opt_state = opt_state
        self.batches = batches
        self.ckpt = ckpt
        self.autocomp_tick = autocomp_tick
        self.fault_hook = fault_hook
        self.straggler_hook = straggler_hook
        self.on_straggler = on_straggler
        self.history: List[Dict[str, float]] = []
        self.step = 0
        self.restarts = 0
        self.stragglers_detected: List[int] = []

    # ------------------------------------------------------------------ run
    def _maybe_restore(self) -> None:
        if self.ckpt is None:
            return
        try:
            (self.params, self.opt_state, step), s = self.ckpt.restore(
                (self.params, self.opt_state, 0))
            self.step = int(np.asarray(step))
        except FileNotFoundError:
            pass

    def _save(self, blocking: bool = False) -> None:
        if self.ckpt is None:
            return
        self.ckpt.save(self.step, (self.params, self.opt_state, self.step),
                       blocking=blocking or not self.cfg.async_ckpt)

    def run(self) -> Dict[str, Any]:
        it = self.batches()
        step_times: List[float] = []
        while self.step < self.cfg.total_steps:
            try:
                batch = next(it)
            except StopIteration:
                it = self.batches()
                batch = next(it)
            if self.fault_hook is not None:
                self.fault_hook(self.step)          # may raise preemption
            t0 = time.perf_counter()
            self.params, self.opt_state, metrics = self.train_step(
                self.params, self.opt_state, batch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            if self.straggler_hook is not None:
                dt += self.straggler_hook(self.step, dt)  # injected delay
            step_times.append(dt)
            if len(step_times) >= self.cfg.straggler_window:
                med = statistics.median(step_times[-self.cfg.straggler_window:])
                if dt > self.cfg.straggler_factor * med:
                    self.stragglers_detected.append(self.step)
                    if self.on_straggler is not None:
                        self.on_straggler(self.step, dt, med)
            self.history.append({"step": self.step, "loss": loss,
                                 "time_s": dt})
            self.step += 1
            if self.ckpt is not None and self.step % self.cfg.ckpt_every == 0:
                self._save()
            if self.autocomp_tick is not None:
                self.autocomp_tick()
        if self.ckpt is not None:
            self._save(blocking=True)
            self.ckpt.wait()
        return {"final_step": self.step, "history": self.history,
                "stragglers": self.stragglers_detected}

    def run_with_recovery(self, max_restarts: int = 3) -> Dict[str, Any]:
        """Preemption-tolerant outer loop: restore + continue on failure."""
        while True:
            try:
                return self.run()
            except SimulatedPreemption:
                self.restarts += 1
                if self.restarts > max_restarts:
                    raise
                if self.ckpt is not None:
                    self.ckpt.wait()
                self._maybe_restore()
