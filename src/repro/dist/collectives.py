"""Blockwise-int8 compressed collectives with error feedback.

Cross-pod gradient all-reduce is the bandwidth floor of multi-pod training
(the DCI link is ~an order of magnitude slower than ICI). Following the
DRAGONN/ATOMO line of gradient compression, payloads are quantized to
symmetric int8 per ``block`` elements (4x smaller than bf16 on the wire,
scales amortized over the block) and the quantization residual is carried
into the next step — error feedback — so the *long-run* contribution of
every element is unbiased even though each step rounds.

The serve path uses the same quantizer for its *activation* all-gathers
(:func:`act_gather` under an :class:`act_transport_scope`): no error
feedback there — activations are stateless across steps, so each gather
quantizes fresh and the error never compounds.

All functions are jit-compatible: shapes are static, no host sync.
"""

from __future__ import annotations

import threading
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dist import sharding as _shd


def quantize_int8(x: jnp.ndarray, block: int = 256
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-block int8 quantization.

    Flattens ``x``, zero-pads to a multiple of ``block``, and scales each
    block by its abs-max so values land in [-127, 127]. Per-element error is
    at most ``block_max / 254`` (half a quantization step). Returns
    ``(q, scales)`` with ``q: int8 (n_blocks, block)`` and
    ``scales: float32 (n_blocks,)``.
    """
    flat = jnp.ravel(x).astype(jnp.float32)
    pad = (-flat.shape[0]) % block
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.float32)])
    return _quantize_blocks(flat.reshape(-1, block))


def dequantize_int8(q: jnp.ndarray, scales: jnp.ndarray, n: int
                    ) -> jnp.ndarray:
    """Inverse of :func:`quantize_int8`; returns the first ``n`` elements."""
    return _dequantize_blocks(q, scales).reshape(-1)[:n]


def _quantize_blocks(blocks: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric int8 over the trailing ``block`` axis of ``(..., block)``."""
    scales = jnp.max(jnp.abs(blocks), axis=-1) / 127.0
    safe = jnp.where(scales > 0, scales, 1.0)   # all-zero block -> q = 0
    q = jnp.clip(jnp.round(blocks / safe[..., None]), -127, 127).astype(jnp.int8)
    return q, scales


def _dequantize_blocks(q: jnp.ndarray, scales: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scales[..., None]


def _two_stage_int8_psum(flat: jnp.ndarray, axis_name, block: int
                         ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """All-reduce ``flat`` across ``axis_name`` moving int8 on the wire.

    DRAGONN-style two-stage scheme (the op mix a real int8 all-reduce uses):

    1. split the payload into one chunk per peer, quantize each chunk
       blockwise, and ``all_to_all`` the int8 chunks + f32 scales — every
       device receives each peer's compressed contribution to *its* chunk;
    2. dequantize + sum locally (the owned chunk is now fully reduced),
       re-quantize it, and ``all_gather`` the int8 result chunks.

    Wire traffic is ~(2 + 8/block) bytes/element vs 4 bytes/element for a
    ring bf16 all-reduce. Both quantization errors feed the returned
    residual: stage 1 over the full local payload, stage 2 only on the
    owned chunk (each chunk has exactly one owner, so the residual *sum*
    across devices captures the stage-2 error exactly once).

    Returns ``(summed_flat, residual_flat)`` of the same length as ``flat``.
    """
    w = jax.lax.psum(1, axis_name)   # statically-known axis size
    n = flat.shape[0]
    pad = (-n) % (w * block)
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.float32)])
    npad = flat.shape[0]
    chunk = npad // w
    # stage 1: my contribution to every peer's chunk, int8 on the wire
    q1, s1 = _quantize_blocks(flat.reshape(w, chunk // block, block))
    err1 = flat - _dequantize_blocks(q1, s1).reshape(npad)
    q1x = jax.lax.all_to_all(q1, axis_name, split_axis=0, concat_axis=0)
    s1x = jax.lax.all_to_all(s1, axis_name, split_axis=0, concat_axis=0)
    mine = jnp.sum(_dequantize_blocks(q1x, s1x), axis=0)   # (chunk//block, block)
    # stage 2: broadcast the reduced chunk, int8 on the wire again
    q2, s2 = _quantize_blocks(mine)
    err2 = (mine - _dequantize_blocks(q2, s2)).reshape(chunk)
    q2g = jax.lax.all_gather(q2, axis_name)
    s2g = jax.lax.all_gather(s2, axis_name)
    out = _dequantize_blocks(q2g, s2g).reshape(npad)
    ofs = jax.lax.axis_index(axis_name) * chunk
    new_err = jax.lax.dynamic_update_slice(
        err1, jax.lax.dynamic_slice(err1, (ofs,), (chunk,)) + err2, (ofs,))
    return out[:n], new_err[:n]


def compressed_psum(x: jnp.ndarray, axis_name: Optional[str] = None,
                    err: Optional[jnp.ndarray] = None, *, block: int = 256
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """psum of an int8-compressed payload with error-feedback accumulation.

    The carried residual ``err`` (same shape as ``x``, float32; pass zeros or
    ``None`` on the first step) is added *before* quantization and the new
    residual ``(x + err) - dequantized`` is returned for the next step, so
    the accumulated sum over steps converges to the uncompressed sum.

    ``axis_name=None`` degenerates to the single-device identity (no psum) —
    the form the SPMD train step and the CPU container exercise: the
    quantization error and residual carry are real, only the wire is not.
    With an ``axis_name`` (inside ``shard_map``/``pmap``) the reduction runs
    the two-stage int8 exchange, so the compiled HLO moves int8 — this is
    the path the forced-8-device tests compile and measure.

    Returns ``(summed, new_err)``.
    """
    xf = x.astype(jnp.float32)
    carry = xf if err is None else xf + err.astype(jnp.float32)
    if axis_name is None:
        q, scales = quantize_int8(carry, block)
        deq = dequantize_int8(q, scales, carry.size).reshape(carry.shape)
        return deq.astype(x.dtype), carry - deq
    out, new_err = _two_stage_int8_psum(jnp.ravel(carry), axis_name, block)
    return (out.reshape(carry.shape).astype(x.dtype),
            new_err.reshape(carry.shape))


# ---------------------------------------------------------------------------
# serve activation transport: quantized all-gathers, no error feedback
# ---------------------------------------------------------------------------

ACT_TRANSPORTS = ("bf16", "int8")
ACT_BLOCK = 256


def quantize_int8_lastdim(x: jnp.ndarray, block: int = ACT_BLOCK
                          ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric int8 quantization blocked along the *trailing* axis only.

    Unlike :func:`quantize_int8` (which flattens the whole array), blocks
    never cross the trailing-axis boundary, so the op stays local under any
    sharding of the leading axes — the form the serve activation all-gather
    needs: quantize on the sequence shard, gather the int8 payload, then
    dequantize on the far side. A trailing dim not divisible by ``block``
    falls back to one block spanning the whole dim (always valid, coarser
    scales). Returns ``(q, scales)`` with ``q: int8`` of ``x.shape`` and
    ``scales: float32`` of ``x.shape[:-1] + (n_blocks,)``.
    """
    d = x.shape[-1]
    b = block if d % block == 0 else d
    blocks = x.astype(jnp.float32).reshape(x.shape[:-1] + (d // b, b))
    q, scales = _quantize_blocks(blocks)
    return q.reshape(x.shape), scales


def dequantize_int8_lastdim(q: jnp.ndarray, scales: jnp.ndarray
                            ) -> jnp.ndarray:
    """Inverse of :func:`quantize_int8_lastdim` (float32 out)."""
    nb = scales.shape[-1]
    d = q.shape[-1]
    blocks = q.reshape(q.shape[:-1] + (nb, d // nb))
    return _dequantize_blocks(blocks, scales).reshape(q.shape)


class _ActStack(threading.local):
    def __init__(self):
        self.items: list = []


_act_ctx = _ActStack()


def current_act_transport() -> Optional[str]:
    """Active serve activation transport, or None outside any scope."""
    return _act_ctx.items[-1] if _act_ctx.items else None


class act_transport_scope:
    """Trace-time scope selecting how serve activation all-gathers cross
    the wire (``"bf16"`` — plain constrained reshard — or ``"int8"`` —
    blockwise int8 chunks + scales). Entered by the prefill/decode step
    factories; model code reads it through :func:`act_gather`. Like
    ``sharding.axis_rules`` this only affects tracing, so a jitted step
    keeps the transport it was traced with."""

    def __init__(self, mode: Optional[str]):
        if mode is not None and mode not in ACT_TRANSPORTS:
            raise ValueError(f"unknown act_transport {mode!r}; "
                             f"expected one of {ACT_TRANSPORTS}")
        self.mode = mode

    def __enter__(self) -> "act_transport_scope":
        _act_ctx.items.append(self.mode)
        return self

    def __exit__(self, *exc) -> bool:
        _act_ctx.items.pop()
        return False


def all_gather_int8(x: jnp.ndarray, *logical_axes: Optional[str],
                    block: int = ACT_BLOCK) -> jnp.ndarray:
    """Reshard ``x`` to the layout named by ``logical_axes`` moving
    blockwise int8 + per-block f32 scales on the wire instead of the raw
    payload: quantize locally (blocks along the trailing axis never cross a
    shard of the leading axes), constrain the *quantized* arrays to the
    target layout so XLA's resharding all-gather carries s8, dequantize on
    the gathered side. ~(1 + 4/block)/2 of the bf16 wire bytes."""
    q, scales = quantize_int8_lastdim(x, block)
    q = _shd.constrain(q, *logical_axes)
    scales = _shd.constrain(scales, *logical_axes[:-1], None)
    return dequantize_int8_lastdim(q, scales).astype(x.dtype)


def act_gather(x: jnp.ndarray, *logical_axes: Optional[str]) -> jnp.ndarray:
    """The serve activation all-gather boundary.

    Moves ``x`` to the (gathered) layout named by ``logical_axes`` under
    the active :class:`act_transport_scope`: ``"bf16"`` pins a plain
    ``constrain`` (XLA reshards the raw payload), ``"int8"`` routes the
    reshard through :func:`all_gather_int8`. Outside any scope (training,
    legacy callers) this is the identity, so model code is unchanged
    everywhere the serve transport is not explicitly enabled."""
    mode = current_act_transport()
    if mode is None:
        return x
    if mode == "int8":
        return all_gather_int8(x, *logical_axes)
    return _shd.constrain(x, *logical_axes)
