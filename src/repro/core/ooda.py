"""The OODA pipeline (Fig. 4): one configurable object wiring candidates ->
observe -> filters -> orient -> filters -> decide -> act -> feedback.

``run_cycle`` is deterministic given the catalog state (NFR2) and returns a
CycleReport with everything the benchmarks plot.

Fleet refactor: the pipeline is now a per-table/per-namespace *policy
object*. Its front half, :meth:`AutoCompPipeline.propose`, produces the
ranked candidate pool (observe -> orient -> filters -> rank); the decide and
act tails are injectable strategies (``decide=`` anything with
``select(ranked)``, ``act=`` anything with ``execute(selected)`` — by
default the legacy top-k/budget selection and the ``Scheduler``).
``run_cycle`` composes the two halves for standalone single-pool use;
``core.fleet.FleetScheduler`` instead pools ``propose`` output from many
pipelines and owns cross-table decide/act under a shared budget.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence

from repro.core import filters as filt
from repro.core.act import ActReport, Scheduler
from repro.core.decide import (BudgetSelection, MoopRanker, TopKSelection,
                               minmax_normalize)
from repro.core.model import Candidate, Scope, generate_candidates
from repro.core.observe import StatsCollector
from repro.core.orient import TraitContext, compute_traits
from repro.lst.catalog import Catalog


@dataclasses.dataclass
class CycleReport:
    n_candidates: int = 0
    n_after_filters: int = 0
    n_selected: int = 0
    n_unpriced: int = 0          # conservative-skipped: no compute_cost trait
    selected_keys: List = dataclasses.field(default_factory=list)
    deferred_keys: List = dataclasses.field(default_factory=list)
    act: Optional[ActReport] = None
    wall_s: float = 0.0

    @property
    def files_removed(self) -> int:
        return self.act.files_removed if self.act else 0

    @property
    def gbhr(self) -> float:
        return self.act.gbhr if self.act else 0.0


class AutoCompPipeline:
    def __init__(self,
                 stats: StatsCollector,
                 traits: Sequence,
                 trait_ctx: TraitContext,
                 ranker: MoopRanker,
                 scheduler: Optional[Scheduler] = None,
                 scope: Scope = Scope.TABLE,
                 hybrid: bool = False,
                 pre_filters: Sequence = (),
                 post_filters: Sequence = (),
                 top_k: Optional[int] = 10,
                 budget_gbhr: Optional[float] = None,
                 weights_fn: Optional[Callable[[Candidate], Dict[str, float]]] = None,
                 feedback_fn: Optional[Callable] = None,
                 decide=None,
                 act=None) -> None:
        self.stats = stats
        self.traits = traits
        self.trait_ctx = trait_ctx
        self.ranker = ranker
        self.scheduler = scheduler
        self.scope = scope
        self.hybrid = hybrid
        self.pre_filters = list(pre_filters)
        self.post_filters = list(post_filters)
        self.top_k = top_k
        self.budget_gbhr = budget_gbhr
        self.weights_fn = weights_fn
        self.feedback_fn = feedback_fn
        # injectable decide/act tails; defaults reproduce the legacy
        # top_k/budget_gbhr behavior on top of the passed scheduler
        if decide is None:
            decide = (BudgetSelection(budget_gbhr, max_k=top_k)
                      if budget_gbhr is not None else TopKSelection(top_k))
        self.decide = decide
        self.act = act if act is not None else scheduler

    # -- observe -> orient -> rank (the per-pool policy half) ----------------
    def propose(self, catalog: Catalog,
                tables: Optional[Sequence] = None,
                report: Optional[CycleReport] = None) -> List[Candidate]:
        """Produce this pool's ranked candidates. This is the surface the
        fleet scheduler consumes: everything up to (but excluding) the
        decide/act tail."""
        cands = generate_candidates(tables if tables is not None
                                    else catalog.tables(),
                                    self.scope, hybrid=self.hybrid)
        if report is not None:
            report.n_candidates = len(cands)
        self.stats.observe_all(cands)
        cands = filt.apply_filters(cands, self.pre_filters)

        # orient
        compute_traits(cands, self.traits, self.trait_ctx)
        cands = filt.apply_filters(cands, self.post_filters)
        if report is not None:
            report.n_after_filters = len(cands)

        # rank (per-candidate quota-adaptive weights if configured)
        if self.weights_fn is not None:
            # re-rank with per-candidate weights: score candidates under
            # their own namespace weights, then order globally
            names = list(self.ranker.weights)
            minmax_normalize(cands, names)
            for c in cands:
                w = self.weights_fn(c)
                c.score = sum(
                    (-wv if n in self.ranker.costs else wv)
                    * c.normalized.get(n, 0.0) for n, wv in w.items())
            return sorted(cands, key=lambda c: (-c.score,) + c.key)
        return self.ranker.rank(cands)

    # -- the four phases ------------------------------------------------------
    def run_cycle(self, catalog: Catalog,
                  tables: Optional[Sequence] = None) -> CycleReport:
        t0 = time.perf_counter()
        rep = CycleReport()

        ranked = self.propose(catalog, tables=tables, report=rep)

        # decide
        selected = self.decide.select(ranked)
        rep.n_selected = len(selected)
        rep.n_unpriced = len(getattr(self.decide, "last_unpriced", ()))
        rep.selected_keys = [c.key for c in selected]

        # act
        if self.act is not None:
            rep.act = self.act.execute(selected)
            rep.deferred_keys = [c.key for c in rep.act.deferred]

        # feedback loop -> observe (updated file counts / layout changes)
        if self.feedback_fn is not None and rep.act is not None:
            self.feedback_fn(rep)
        rep.wall_s = time.perf_counter() - t0
        return rep
