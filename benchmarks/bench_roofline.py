"""§Roofline table emitter: reads the dry-run JSON records (experiments/
dryrun/) and prints one row per (arch x shape x mesh) cell with the three
terms, the dominant bottleneck, MODEL_FLOPS/HLO_FLOPS, and the int8-vs-bf16
collective comparison (modeled gradient transport for train cells, measured
activation transport for serve cells).

``--json PATH`` additionally writes the full record set as a trajectory
artifact (the CI bench-smoke job uploads it as ``BENCH_roofline.json``) so
regressions can later be diffed across commits."""

from __future__ import annotations

import glob
import json
import os
from typing import List, Optional


def load(outdir: str = "experiments/dryrun"):
    recs = []
    for p in sorted(glob.glob(os.path.join(outdir, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def main(outdir: str = "experiments/dryrun") -> List[str]:
    rows = []
    ok = skip = 0
    for r in load(outdir):
        tag = f"{r['arch']};{r['shape']};{r['mesh']}"
        variant = [v for v in (r.get("preset"), r.get("grad_transport"),
                               r.get("act_transport"))
                   if v and v not in ("baseline", "bf16")]
        if variant:
            tag += ";" + "-".join(variant)
        if r.get("status") == "skip":
            skip += 1
            rows.append(f"roofline[{tag}],skip,{r['skip_reason']}")
            continue
        if r.get("status") != "ok":
            rows.append(f"roofline[{tag}],ERROR,{r.get('error','')[:80]}")
            continue
        ok += 1
        rf = r["roofline"]
        coll_cmp = ""
        if rf.get("collective_s_int8") is not None:
            # train: modeled int8_ef grad transport; serve: *measured*
            # act_transport comparison (both programs compiled)
            coll_cmp = (f";coll_bf16={rf['collective_s_bf16']:.4f}"
                        f";coll_int8={rf['collective_s_int8']:.4f}")
        rows.append(
            f"roofline[{tag}],{rf['roofline_fraction']:.4f},"
            f"dom={rf['dominant'].replace('_s','')};"
            f"compute={rf['compute_s']:.4f};mem={rf['memory_s']:.4f};"
            f"coll={rf['collective_s']:.4f};"
            f"useful_ratio={rf['useful_flops_ratio']:.3f}" + coll_cmp)
    rows.append(f"roofline_cells,{ok},skips={skip}")
    return rows


def write_trajectory(path: str, outdir: str = "experiments/dryrun") -> None:
    """Dump rows + raw records as one JSON artifact for CI upload/diffing."""
    recs = load(outdir)
    payload = {"cells": len(recs), "rows": main(outdir), "records": recs}
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the trajectory artifact JSON here")
    args = ap.parse_args()
    for r in main():
        print(r)
    if args.json:
        write_trajectory(args.json)
        print(f"wrote {args.json}")
