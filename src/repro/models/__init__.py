from repro.models.registry import (  # noqa: F401
    abstract_params,
    init_params,
    param_axes,
    forward,
)
