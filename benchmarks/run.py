"""Benchmark aggregator: one suite per paper table/figure plus the roofline
table. Prints ``name,value,derived`` CSV rows.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig6]
"""

from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer simulated hours")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    hours = 3 if args.quick else 5

    from benchmarks import (bench_autotune, bench_compaction_cost,
                            bench_conflicts, bench_file_count, bench_fleet,
                            bench_hist, bench_kernels,
                            bench_pipeline_latency, bench_query_latency,
                            bench_roofline)

    suites = [
        ("fig1_fig2_size_distribution", lambda: bench_hist.main()),
        ("fig3_query_vs_maintenance", lambda: bench_pipeline_latency.main()),
        ("fig6_file_count", lambda: bench_file_count.main(hours)),
        ("fig7_compaction_cost", lambda: bench_compaction_cost.main(hours)),
        ("fig8_query_latency", lambda: bench_query_latency.main(hours)),
        ("table1_conflicts", lambda: bench_conflicts.main(hours)),
        ("fig9_autotune", lambda: bench_autotune.main(max(2, hours - 2))),
        ("fig10_fleet", lambda: bench_fleet.main()),
        ("kernels", lambda: bench_kernels.main()),
        ("roofline", lambda: bench_roofline.main()),
    ]
    print("name,value,derived")
    failures = 0
    for name, fn in suites:
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        try:
            for row in fn():
                print(row)
            print(f"suite[{name}],{time.time()-t0:.1f}s,ok")
        except Exception as e:  # pragma: no cover
            failures += 1
            import traceback
            traceback.print_exc()
            print(f"suite[{name}],FAILED,{e!r}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
