"""Property-based tests (hypothesis) on AutoComp's decision invariants."""

import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.decide import (FLEET_NORM_TRAITS, MoopRanker,
                               minmax_normalize, pooled_benefit,
                               quota_adaptive_weights, select_budget,
                               select_topk)
from repro.core.model import Candidate, CandidateStats, Scope
from repro.core.orient import (ComputeCostTrait, FileCountReductionTrait,
                               FileEntropyTrait, TraitContext)
from repro.lst import InMemoryStore
from repro.lst.files import DataFile
from repro.lst.table import LogStructuredTable

MB = 1 << 20


def mk_candidate(sizes, table_id="ns/t", partition=None):
    store = InMemoryStore()
    t = LogStructuredTable(store, table_id)
    files = []
    for i, s in enumerate(sizes):
        path = f"{table_id}/data/f{i}.bin"
        store.put(path, b"x")
        files.append(DataFile(path, int(s), 1, partition))
    t.append(files)
    c = Candidate(t, Scope.TABLE)
    from repro.core.observe import StatsCollector
    StatsCollector(512 * MB).observe(c)
    return c


sizes_strategy = st.lists(st.integers(min_value=1, max_value=2 << 30),
                          min_size=1, max_size=40)


class TestTraits:
    @given(sizes_strategy)
    @settings(max_examples=25, deadline=None)
    def test_file_count_reduction_formula(self, sizes):
        """Paper §4.2: ΔF_c counts files below the target size."""
        c = mk_candidate(sizes)
        ctx = TraitContext(target_file_bytes=512 * MB)
        v = FileCountReductionTrait().compute(c, ctx)
        assert v == sum(1 for s in sizes if s < 512 * MB)

    @given(sizes_strategy)
    @settings(max_examples=25, deadline=None)
    def test_entropy_nonnegative(self, sizes):
        c = mk_candidate(sizes)
        ctx = TraitContext(target_file_bytes=512 * MB)
        assert FileEntropyTrait().compute(c, ctx) >= 0.0

    def test_entropy_drops_after_packing(self):
        """Many small files have higher excess entropy than the same bytes
        packed at target size."""
        ctx = TraitContext(target_file_bytes=512 * MB)
        frag = mk_candidate([4 * MB] * 256)
        packed = mk_candidate([512 * MB] * 2)
        e = FileEntropyTrait()
        assert e.compute(frag, ctx) > e.compute(packed, ctx)

    @given(sizes_strategy, st.floats(min_value=1.0, max_value=64.0))
    @settings(max_examples=25, deadline=None)
    def test_gbhr_linear_in_bytes(self, sizes, mem_gb):
        """GBHr = mem * small_bytes / rate, exactly (§4.2)."""
        c = mk_candidate(sizes)
        ctx = TraitContext(target_file_bytes=512 * MB,
                           executor_memory_gb=mem_gb,
                           rewrite_bytes_per_hour=1e9)
        v = ComputeCostTrait().compute(c, ctx)
        small = sum(s for s in sizes if s < 512 * MB)
        assert v == pytest.approx(mem_gb * small / 1e9)


class TestRanking:
    def _cands(self, vals):
        out = []
        for i, (b, c) in enumerate(vals):
            cand = mk_candidate([MB], table_id=f"ns/t{i:03d}")
            cand.traits = {"file_count_reduction": float(b),
                           "compute_cost": float(c)}
            out.append(cand)
        return out

    @given(st.lists(st.tuples(st.floats(0, 1e6), st.floats(0, 1e6)),
                    min_size=1, max_size=30))
    @settings(max_examples=30, deadline=None)
    def test_minmax_in_unit_interval(self, vals):
        cands = self._cands(vals)
        minmax_normalize(cands, ["file_count_reduction", "compute_cost"])
        for c in cands:
            for v in c.normalized.values():
                assert 0.0 <= v <= 1.0

    @given(st.lists(st.tuples(st.floats(0, 1e6), st.floats(0, 1e6)),
                    min_size=2, max_size=20), st.randoms())
    @settings(max_examples=20, deadline=None)
    def test_rank_deterministic_and_permutation_invariant(self, vals, rnd):
        """NFR2: identical inputs -> identical decisions, regardless of
        candidate enumeration order."""
        ranker = MoopRanker({"file_count_reduction": 0.7,
                             "compute_cost": 0.3})
        a = ranker.rank(self._cands(vals))
        shuffled = self._cands(vals)
        rnd.shuffle(shuffled)
        b = ranker.rank(shuffled)
        assert [c.key for c in a] == [c.key for c in b]

    def test_weights_must_sum_to_one(self):
        with pytest.raises(ValueError):
            MoopRanker({"file_count_reduction": 0.7, "compute_cost": 0.7})

    @given(st.lists(st.tuples(st.floats(0, 100), st.floats(0.01, 10)),
                    min_size=1, max_size=30),
           st.floats(min_value=0.0, max_value=20.0))
    @settings(max_examples=30, deadline=None)
    def test_budget_never_exceeded(self, vals, budget):
        ranker = MoopRanker({"file_count_reduction": 0.7,
                             "compute_cost": 0.3})
        ranked = ranker.rank(self._cands(vals))
        sel = select_budget(ranked, budget)
        assert sum(c.traits["compute_cost"] for c in sel) <= budget + 1e-9

    @given(st.lists(st.tuples(st.floats(0, 100), st.floats(0.0, 10),
                              st.booleans()),
                    min_size=1, max_size=30),
           st.floats(min_value=0.5, max_value=20.0))
    @settings(max_examples=30, deadline=None)
    def test_budget_skips_unpriced_conservatively(self, vals, budget):
        """A candidate with NO compute_cost trait must never be admitted
        (missing cost is unknown, not free) and must be counted; an
        explicit cost of 0.0 is priced and admissible."""
        cands = self._cands([(b, c) for b, c, _ in vals])
        for (b, c, unpriced), cand in zip(vals, cands):
            if unpriced:
                del cand.traits["compute_cost"]
        unpriced_out = []
        sel = select_budget(cands, budget, unpriced=unpriced_out)
        assert all("compute_cost" in c.traits for c in sel)
        assert len(unpriced_out) == sum(1 for _, _, u in vals if u)
        assert sum(c.traits["compute_cost"] for c in sel) <= budget + 1e-9
        # explicitly-free candidates are all admitted
        free = [c for c in cands if c.traits.get("compute_cost") == 0.0]
        assert all(c in sel for c in free)

    def test_higher_benefit_same_cost_ranks_first(self):
        """Paper §4.2: 200-file reduction beats 100 at equal cost."""
        cands = self._cands([(100, 5), (200, 5)])
        ranker = MoopRanker({"file_count_reduction": 0.7,
                             "compute_cost": 0.3})
        ranked = ranker.rank(cands)
        assert ranked[0].traits["file_count_reduction"] == 200

    @given(st.floats(min_value=0, max_value=1))
    @settings(max_examples=30, deadline=None)
    def test_quota_adaptive_weights(self, util):
        w = quota_adaptive_weights(util * 100, 100)
        assert w["file_count_reduction"] == pytest.approx(
            min(1.0, 0.5 * (1 + util)))
        assert sum(w.values()) == pytest.approx(1.0)


class TestPooledBenefit:
    """The fleet pool's benefit term (PR 8 pricing fix): reclaimed bytes
    count alongside file-count reduction, so a drop-heavy delete candidate
    can win the shared budget; pools with no delete candidates are
    unchanged."""

    def _pool(self, vals):
        out = []
        for i, (fcr, reclaim, cost) in enumerate(vals):
            cand = mk_candidate([MB], table_id=f"ns/t{i:03d}")
            cand.traits = {"file_count_reduction": float(fcr),
                           "compute_cost": float(cost)}
            if reclaim is not None:
                cand.traits["reclaim_bytes"] = float(reclaim)
            out.append(cand)
        minmax_normalize(out, list(FLEET_NORM_TRAITS))
        return out

    @given(st.lists(st.tuples(st.floats(0, 1e4), st.floats(0, 1e12),
                              st.floats(0, 10)),
                    min_size=1, max_size=25))
    @settings(max_examples=30, deadline=None)
    def test_benefit_bounded_and_monotone_in_reclaim(self, vals):
        pool = self._pool(vals)
        for c in pool:
            assert 0.0 <= pooled_benefit(c) <= 2.0
        top = max(v[1] for v in vals)
        for c, (fcr, reclaim, _) in zip(pool, vals):
            if reclaim == top and all(v[0] == fcr for v in vals):
                # equal file-count reduction: max reclaim is max benefit
                assert pooled_benefit(c) == pytest.approx(
                    max(pooled_benefit(x) for x in pool))

    @given(st.lists(st.tuples(st.floats(0, 1e4), st.floats(0, 10)),
                    min_size=1, max_size=25))
    @settings(max_examples=30, deadline=None)
    def test_pool_without_reclaim_trait_unchanged(self, vals):
        """An all-absent trait normalizes to 0 for everyone: benefit
        degenerates to normalized file-count reduction exactly."""
        pool = self._pool([(fcr, None, cost) for fcr, cost in vals])
        for c in pool:
            assert pooled_benefit(c) == pytest.approx(
                c.normalized.get("file_count_reduction", 0.0))

    def test_drop_heavy_delete_wins_budget_over_compaction(self):
        """A GDPR rewrite over two large files barely reduces file count;
        under file-count-only benefit it lost the budget to ANY ordinary
        compaction. With the reclaim term it outranks the mid-tier
        compaction, and a two-slot budget picks it over that compaction."""
        pool = self._pool([
            (40.0, 0.0, 2.0),            # big compaction, no bytes deleted
            (30.0, 0.0, 2.0),            # mid compaction: used to beat...
            (2.0, 5e10, 2.0),            # ...this drop-heavy delete
        ])
        for c in pool:
            c.score = pooled_benefit(c)
        big, mid, delete = pool
        assert pooled_benefit(delete) > pooled_benefit(mid)
        ranked = sorted(pool, key=lambda c: (-c.score,) + c.key)
        sel = select_budget(ranked, budget_gbhr=4.0)   # room for two
        assert delete in sel and mid not in sel
        # old pricing (file count only) inverted that choice
        old = sorted(pool, key=lambda c: (
            -c.normalized["file_count_reduction"],) + c.key)
        assert delete not in select_budget(old, budget_gbhr=4.0)

    def test_file_drop_costs_explicit_zero_not_unpriced(self):
        """A pure file-drop candidate is priced-FREE (0.0), never
        conservative-skipped: it fits any budget, including 0."""
        pool = self._pool([(5.0, 1e9, 0.0), (50.0, 0.0, 3.0)])
        for c in pool:
            c.score = pooled_benefit(c)
        unpriced = []
        sel = select_budget(sorted(pool, key=lambda c: (-c.score,) + c.key),
                            budget_gbhr=0.0, unpriced=unpriced)
        assert unpriced == []
        assert [c.traits["compute_cost"] for c in sel] == [0.0]


class TestBinpack:
    @given(st.lists(st.integers(min_value=1, max_value=600 * MB),
                    min_size=0, max_size=60))
    @settings(max_examples=30, deadline=None)
    def test_bins_respect_target(self, sizes):
        from repro.lst.compaction import plan_binpack
        files = [DataFile(f"f{i}", s, 1) for i, s in enumerate(sizes)]
        tasks = plan_binpack(files, 512 * MB)
        for t in tasks:
            assert t.input_bytes <= 512 * MB
            assert len(t.inputs) >= 2
            for f in t.inputs:
                assert f.size_bytes < 512 * MB
        # no file appears in two bins
        seen = [f.path for t in tasks for f in t.inputs]
        assert len(seen) == len(set(seen))


class TestTuneDesign:
    """Coordinate-descent hillclimb over discrete design spaces (the
    serve transfer x storage x block sweep's tuner)."""

    def test_finds_global_optimum_of_separable_objective(self):
        from repro.core.autotune import tune_design
        axes = {"t": ("bf16", "int8"), "s": ("bf16", "int8", "f8"),
                "b": (128, 256, 512)}
        cost = {"bf16": 2.0, "int8": 1.0, "f8": 0.5}

        def ev(p):
            return cost[p["t"]] + cost[p["s"]] + 256 / p["b"]

        res = tune_design(ev, axes)
        # separable objective: coordinate descent reaches the global min
        assert res.best_point == {"t": "int8", "s": "f8", "b": 512}
        assert res.best_objective == pytest.approx(1.0 + 0.5 + 0.5)

    def test_memoized_and_far_below_exhaustive(self):
        from repro.core.autotune import tune_design
        calls = []

        def ev(p):
            calls.append(tuple(sorted(p.items())))
            return -p["a"] - p["b"]

        res = tune_design(ev, {"a": tuple(range(5)), "b": tuple(range(5))})
        assert res.best_point == {"a": 4, "b": 4}
        assert len(calls) == len(set(calls))        # never re-evaluated
        assert res.evaluations < 25                 # < exhaustive 5x5

    def test_deterministic_and_respects_maximize(self):
        from repro.core.autotune import tune_design

        def ev(p):
            return p["x"] * p["y"]

        axes = {"x": (1, 3, 2), "y": (5, 4, 6)}
        a = tune_design(ev, axes, minimize=False)
        b = tune_design(ev, axes, minimize=False)
        assert a.best_point == b.best_point == {"x": 3, "y": 6}
        assert a.best_objective == 18
        assert [h[0] for h in a.history] == [h[0] for h in b.history]

    def test_single_point_space(self):
        from repro.core.autotune import tune_design
        res = tune_design(lambda p: 7.0, {"only": ("v",)})
        assert res.best_point == {"only": "v"}
        assert res.best_objective == 7.0 and res.evaluations == 1

    def test_warm_start_from_incumbent(self):
        """``start`` seeds the walk at the incumbent point (fleet profile
        re-tuning); values outside the axes are ignored, not an error."""
        from repro.core.autotune import tune_design

        def ev(p):
            return abs(p["a"] - 3) + abs(p["b"] - 30)

        axes = {"a": (0, 1, 2, 3, 4), "b": (10, 20, 30)}
        res = tune_design(ev, axes, start={"a": 3, "b": 30, "junk": 9})
        assert res.best_point == {"a": 3, "b": 30}
        assert res.history[0][0] == {"a": 3, "b": 30}   # evaluated first
        # a start value not in the axis falls back to the axis default
        res2 = tune_design(ev, axes, start={"a": 99})
        assert res2.history[0][0]["a"] == 0
        assert res2.best_point == {"a": 3, "b": 30}
