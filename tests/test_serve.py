"""Serve-path tests that run on any device count: ragged continuous
batching (per-row masking — every row of a mixed-length batch must match a
solo run of its unpadded prompt), cache growth padding, and sampling
determinism. The sharded/transport claims live in
tests/test_serve_multidevice.py (8 forced devices)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.launch.serve import generate, grow_cache
from repro.models import transformer
from repro.train import step as step_lib


@pytest.fixture(scope="module")
def dense():
    cfg = smoke_config("granite-3-8b")
    return cfg, transformer.init_params(cfg, jax.random.PRNGKey(1))


def _prompts(cfg, b, s, seed=0):
    return np.random.RandomState(seed).randint(
        0, cfg.vocab, size=(b, s)).astype(np.int32)


class TestRaggedContinuousBatching:
    def test_mixed_lengths_match_solo_runs(self, dense):
        """Rows at different positions share the decode step; pad slots are
        junk from prefill and must never leak into any row's tokens."""
        cfg, params = dense
        prompts = _prompts(cfg, 3, 12, seed=3)
        lens = np.array([5, 12, 9], np.int32)
        mixed = generate(cfg, params, prompts, max_new=6, prompt_lens=lens)
        for i, n in enumerate(lens):
            solo = generate(cfg, params, prompts[i:i + 1, :n], max_new=6)
            assert (mixed[i] == solo[0]).all(), (i, mixed[i], solo[0])

    def test_pad_contents_never_observed(self, dense):
        """Same ragged batch, different junk in the pad slots => identical
        outputs (the masking claim, tested directly)."""
        cfg, params = dense
        lens = np.array([4, 9, 7], np.int32)
        a = _prompts(cfg, 3, 9, seed=5)
        b = a.copy()
        for i, n in enumerate(lens):
            b[i, n:] = (b[i, n:] + 17) % cfg.vocab   # different junk
        out_a = generate(cfg, params, a, max_new=5, prompt_lens=lens)
        out_b = generate(cfg, params, b, max_new=5, prompt_lens=lens)
        assert (out_a == out_b).all()

    def test_full_lens_equals_uniform_path(self, dense):
        """prompt_lens=[S0]*B must reproduce the scalar-position path."""
        cfg, params = dense
        prompts = _prompts(cfg, 4, 8, seed=7)
        uniform = generate(cfg, params, prompts, max_new=5)
        ragged = generate(cfg, params, prompts, max_new=5,
                          prompt_lens=np.full((4,), 8, np.int32))
        assert (uniform == ragged).all()

    @pytest.mark.parametrize("arch", ["hymba-1.5b", "xlstm-125m"])
    def test_ragged_refused_for_ring_and_recurrent_families(self, arch):
        """Ring buffers alias padded junk slots into the window and
        recurrent states scan pad tokens in — per-row masks can't undo
        either, so ragged serving must refuse loudly, not drift."""
        cfg = smoke_config(arch)
        params = transformer.init_params(cfg, jax.random.PRNGKey(2))
        with pytest.raises(NotImplementedError, match="ragged"):
            generate(cfg, params, _prompts(cfg, 2, 10), max_new=2,
                     prompt_lens=np.array([6, 10], np.int32))

    @pytest.mark.parametrize("arch", ["hymba-1.5b", "xlstm-125m"])
    def test_uniform_decode_families_still_serve(self, arch):
        """Signature changes (per-row pos plumbing) must not break the
        ring-buffer (SWA) and recurrent-state families on the scalar
        position path."""
        cfg = smoke_config(arch)
        params = transformer.init_params(cfg, jax.random.PRNGKey(2))
        out = generate(cfg, params, _prompts(cfg, 2, 10), max_new=4)
        assert out.shape == (2, 4)
        assert ((out >= 0) & (out < cfg.vocab)).all()


class TestCacheGrow:
    def test_grow_pads_end_and_casts(self, dense):
        cfg, params = dense
        b, s0, total = 2, 6, 14
        prefill = jax.jit(step_lib.make_prefill_step(cfg))
        _, cache = prefill(params, {"tokens": jnp.asarray(_prompts(cfg, b, s0))})
        target = transformer.abstract_cache(cfg, b, total)
        grown = grow_cache(cache, target)
        for leaf, tgt in zip(jax.tree.leaves(grown), jax.tree.leaves(target)):
            assert leaf.shape == tgt.shape and leaf.dtype == tgt.dtype
        # prefix slots preserved exactly, padded slots zero
        k0, kg = cache["k"], grown["k"]
        np.testing.assert_array_equal(np.asarray(kg[:, :, :s0]),
                                      np.asarray(k0.astype(kg.dtype)))
        assert not np.asarray(kg[:, :, s0:]).any()

    def test_grow_is_identity_at_target_shape(self, dense):
        cfg, _ = dense
        cache = transformer.init_cache(cfg, 2, 10)
        grown = grow_cache(cache, transformer.abstract_cache(cfg, 2, 10))
        for a, g in zip(jax.tree.leaves(cache), jax.tree.leaves(grown)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(g))


class TestSampling:
    def test_fixed_seed_is_deterministic(self, dense):
        cfg, params = dense
        prompts = _prompts(cfg, 3, 8, seed=11)
        one = generate(cfg, params, prompts, max_new=6, temperature=0.8,
                       seed=42)
        two = generate(cfg, params, prompts, max_new=6, temperature=0.8,
                       seed=42)
        assert (one == two).all()

    def test_seed_changes_samples(self, dense):
        cfg, params = dense
        prompts = _prompts(cfg, 4, 8, seed=11)
        a = generate(cfg, params, prompts, max_new=8, temperature=2.0, seed=0)
        b = generate(cfg, params, prompts, max_new=8, temperature=2.0, seed=1)
        assert (a != b).any()
