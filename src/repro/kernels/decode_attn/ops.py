"""Public flash-decode wrapper, registered on the tunable-op registry.

``block_k`` resolves tuned > default (512) and is clamped to the cache
length (divisor-safe), so a point tuned on a long cache can't mis-grid a
short one. ``block_k`` regroups the online-softmax accumulation, so no
axis is exact — kernel-vs-ref matches within fp tolerance only.
"""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels import api
from repro.kernels.decode_attn.decode_attn import (
    DEFAULT_BLOCK_K, decode_attention_kernel)
from repro.kernels.decode_attn.ref import decode_attention_ref

BLOCK_CANDIDATES = (128, 256, 512, 1024)


@partial(jax.jit, static_argnames=("block_k", "interpret"))
def _run_jit(q, k, v, lengths, *, block_k, interpret):
    return decode_attention_kernel(q, k, v, lengths, block_k=block_k,
                                   interpret=interpret)


def _run(point, q, k, v, lengths):
    return _run_jit(q, k, v, lengths, block_k=point["block_k"],
                    interpret=api.use_interpret())


def _ref(q, k, v, lengths):
    return decode_attention_ref(q, k, v, lengths)


def _clamp(point, q, k, v, lengths, **kw):
    return {"block_k": api.fit_block(point["block_k"], k.shape[1])}


def _shape_key(q, k, v, lengths, **kw):
    b, h, d = q.shape
    return f"b{b}h{h}kv{k.shape[2]}s{k.shape[1]}d{d}:{q.dtype.name}"


def _example(quick: bool):
    import jax.numpy as jnp
    s = 512 if quick else 2048
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (4, 8, 64), jnp.float32).astype(jnp.bfloat16)
    k = jax.random.normal(key, (4, s, 2, 64), jnp.float32).astype(jnp.bfloat16)
    v = jax.random.normal(key, (4, s, 2, 64), jnp.float32).astype(jnp.bfloat16)
    lens = jnp.asarray([s, s // 2, s // 4, 100], jnp.int32)
    return (q, k, v, lens), {}


api.register(api.TunableOp(
    name="decode_attn",
    axes={"block_k": BLOCK_CANDIDATES},
    default={"block_k": DEFAULT_BLOCK_K},
    run=_run,
    ref=_ref,
    clamp=_clamp,
    shape_key=_shape_key,
    example=_example,
    exact_axes=frozenset(),
    tol=5e-2,
))


def decode_attention(q, k, v, lengths, *, block_k=None, use_ref=False):
    point = None if block_k is None else {"block_k": block_k}
    return api.call("decode_attn", q, k, v, lengths, point=point,
                    use_ref=use_ref)
