"""Multi-prefill-worker fan-in: admission arbitration for one decode slot
table fed by N independent prefill workers.

AutoComp's fleet scheduler (``core/fleet.py``) arbitrates many tables
competing for a shared compaction budget; this module is the serving-side
translation — many prefill workers competing for rows of one decode slot
table. The :class:`AdmissionArbiter` owns the queue discipline:

* **FIFO with priority classes** — requests carry a class (0 = most
  urgent); within a class, enqueue order wins.
* **Aging + hard promotion** — a queued request that loses admission
  passes gains an aging boost (the same ``1 + aging_boost *
  min(skips, n) / n`` shape as the fleet scheduler's starvation
  guarantee), and at ``promotion_cycles`` lost passes it is *hard
  promoted*: sorted ahead of the un-starved pool (oldest first) and
  allowed to evict, so no request waits unboundedly.
* **Per-worker in-flight accounting** — each prefill worker holds at most
  ``max_inflight`` dispatched prefill+transfer jobs (the double buffer of
  ``serve.make_cache_mover``); assignment goes to the least-loaded,
  lowest-numbered worker.
* **Deterministic tie-break** — the admission order is a total order over
  (hard-promoted, urgency, enqueue sequence, request id) with NO
  wall-clock input: the engine admits the arbiter's choice and *blocks*
  on its shipment rather than racing on arrival order, so a permuted
  worker completion order replays the same admission sequence (the NFR2
  replayability property ``tests/test_serve_fanin.py`` pins).

Eviction, when the table is full, is policy-driven
(:data:`EVICTION_POLICIES`): ``"oldest"`` preempts the longest-resident
occupant, ``"priority"`` the worst-class (then longest-resident) one.
Either way an eviction must be *justified* — the pending request outranks
the victim's class or has hit the hard promotion bound — so equal-class
pressure ages in the queue instead of thrashing the table. Evicted
requests re-queue with their prompt extended by the tokens already
emitted (recompute-style preemption; the engine re-prefills and the
greedy continuation bit-matches an uncontended run).

Pure host-side stdlib/numpy — no jax import — so ``serve.fanin_report``
can drive the real arbiter in a deterministic roofline simulation.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import List, Optional, Sequence

import numpy as np

EVICTION_POLICIES = ("none", "oldest", "priority")

# Mirrors core/fleet.py's starvation guarantee: the same aging-boost
# factor and the same hard promotion bound, applied to admission passes
# instead of scheduler cycles.
AGING_BOOST = 0.5
PROMOTION_CYCLES = 5


@dataclasses.dataclass
class Request:
    """One generation request moving through the fan-in lifecycle
    (queue -> prefill worker -> arbiter -> slot -> evict/requeue -> free).

    ``rid`` is stable across evictions (the deterministic tie-break of
    last resort); ``prompt`` grows by the emitted tokens on requeue and
    ``max_new`` shrinks by them, so a readmission re-prefills the
    extended prompt and continues exactly where the eviction cut it off.
    """
    rid: int
    prompt: np.ndarray                 # (len,) int32 tokens
    max_new: int
    priority: int = 0                  # class, 0 = most urgent
    # arbiter bookkeeping (owned by AdmissionArbiter)
    seq: int = -1                      # enqueue sequence number
    skips: int = 0                     # admission passes lost while queued
    evictions: int = 0                 # times preempted so far
    worker: int = -1                   # assigned prefill worker, -1 = none


@dataclasses.dataclass(frozen=True)
class Occupant:
    """What the arbiter needs to know about a slot's current resident."""
    rid: int
    priority: int
    admit_seq: int                     # admission sequence number


class AdmissionArbiter:
    """FIFO-with-priority-classes admission queue over N prefill workers.

    The engine drives it in passes: ``assign()`` hands queued requests to
    workers (dispatching their prefill+ship), ``next_admission()`` names
    the one request the pass may admit (the engine blocks on its
    shipment), ``admit()``/``age()`` record the outcome, and
    ``pick_victim()`` arbitrates eviction when the table is full.
    """

    def __init__(self, workers: int = 1, classes: int = 1,
                 aging_boost: float = AGING_BOOST,
                 promotion_cycles: int = PROMOTION_CYCLES,
                 max_inflight: int = 1):
        if workers < 1:
            raise ValueError(f"need at least one prefill worker, got {workers}")
        if classes < 1:
            raise ValueError(f"need at least one priority class, got {classes}")
        if max_inflight < 1:
            raise ValueError(f"max_inflight must be >= 1, got {max_inflight}")
        self.workers = workers
        self.classes = classes
        self.aging_boost = aging_boost
        self.promotion_cycles = promotion_cycles
        self.max_inflight = max_inflight
        self.queue: List[Request] = []
        self.inflight = [0] * workers      # per-worker in-flight transfers
        self._enqueue_seq = itertools.count()
        self._admit_seq = itertools.count()
        self.stats = {"submitted": 0, "admissions": 0, "evictions": 0,
                      "requeues": 0, "wait_sum": 0, "max_wait": 0}

    # --- queue discipline --------------------------------------------------
    def submit(self, req: Request, requeue: bool = False) -> Request:
        if not 0 <= req.priority < self.classes:
            raise ValueError(
                f"request {req.rid}: priority {req.priority} outside the "
                f"{self.classes} configured classes")
        req.seq = next(self._enqueue_seq)
        req.skips = 0                      # aging restarts per occupancy
        req.worker = -1
        self.queue.append(req)
        self.stats["requeues" if requeue else "submitted"] += 1
        return req

    def promoted(self, req: Request) -> bool:
        return req.skips >= self.promotion_cycles

    def urgency(self, req: Request) -> float:
        """Class urgency times the fleet-style aging boost, capped at the
        promotion bound."""
        n = self.promotion_cycles
        boost = 1.0 + self.aging_boost * min(req.skips, n) / n
        return (self.classes - req.priority) * boost

    def _key(self, req: Request):
        # hard-promoted first, oldest-first among them; then urgency
        # (descending), enqueue order, rid — a total order with no
        # wall-clock input
        hard = self.promoted(req)
        return (0 if hard else 1, req.seq if hard else 0,
                -self.urgency(req), req.seq, req.rid)

    def ordered(self) -> List[Request]:
        return sorted(self.queue, key=self._key)

    # --- worker assignment -------------------------------------------------
    def assign(self) -> List[Request]:
        """Assign unassigned queued requests to prefill workers in arbiter
        order; each worker carries at most ``max_inflight`` dispatched
        jobs. Returns the newly assigned requests (the engine dispatches
        their prefill+ship on the named worker)."""
        out = []
        for req in self.ordered():
            if req.worker >= 0:
                continue
            w = min(range(self.workers), key=lambda i: (self.inflight[i], i))
            if self.inflight[w] >= self.max_inflight:
                break                      # keep order: never skip ahead
            req.worker = w
            self.inflight[w] += 1
            out.append(req)
        return out

    # --- admission ---------------------------------------------------------
    def next_admission(self) -> Optional[Request]:
        """The best-ordered request with a dispatched shipment. Admission
        order is the arbiter's total order, never shipment-arrival order:
        the engine blocks on the chosen shipment, so a permuted worker
        completion order cannot permute admissions."""
        for req in self.ordered():
            if req.worker >= 0:
                return req
        return None

    def admit(self, req: Request) -> Occupant:
        self.queue.remove(req)
        self.inflight[req.worker] -= 1
        self.stats["admissions"] += 1
        self.stats["wait_sum"] += req.skips
        self.stats["max_wait"] = max(self.stats["max_wait"], req.skips)
        return Occupant(rid=req.rid, priority=req.priority,
                        admit_seq=next(self._admit_seq))

    def age(self) -> None:
        """One admission pass ended with these requests still queued."""
        for req in self.queue:
            req.skips += 1

    # --- eviction ----------------------------------------------------------
    def pick_victim(self, occupants: Sequence[Optional[Occupant]],
                    policy: str, pending: Request) -> Optional[int]:
        """Slot to evict for ``pending`` when the table is full, or None.

        ``"oldest"`` targets the longest-resident occupant, ``"priority"``
        the worst class (longest-resident within it). The eviction only
        happens when ``pending`` outranks the victim's class or has hit
        the hard promotion bound — equal-rank pressure keeps aging in the
        queue, so the table never thrashes, while the promotion bound
        still guarantees every request a slot eventually.
        """
        if policy not in EVICTION_POLICIES:
            raise ValueError(f"unknown eviction policy {policy!r}; "
                             f"expected one of {EVICTION_POLICIES}")
        if policy == "none":
            return None
        cands = [(s, o) for s, o in enumerate(occupants) if o is not None]
        if not cands:
            return None
        if policy == "oldest":
            slot, occ = min(cands, key=lambda so: (so[1].admit_seq, so[0]))
        else:  # "priority"
            slot, occ = min(cands,
                            key=lambda so: (-so[1].priority,
                                            so[1].admit_seq, so[0]))
        if self.promoted(pending) or pending.priority < occ.priority:
            return slot
        return None

    def evicted(self, req: Request) -> None:
        """Record a preemption (the engine re-submits via ``submit(...,
        requeue=True)`` with the extended prompt)."""
        req.evictions += 1
        self.stats["evictions"] += 1
