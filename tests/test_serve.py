"""Serve-path tests that run on any device count: ragged continuous
batching (per-row masking — every row of a mixed-length batch must match a
solo run of its unpadded prompt), cache growth padding, and sampling
determinism. The sharded/transport claims live in
tests/test_serve_multidevice.py (8 forced devices)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.launch.serve import generate, grow_cache
from repro.models import transformer
from repro.train import step as step_lib


@pytest.fixture(scope="module")
def dense():
    cfg = smoke_config("granite-3-8b")
    return cfg, transformer.init_params(cfg, jax.random.PRNGKey(1))


def _prompts(cfg, b, s, seed=0):
    return np.random.RandomState(seed).randint(
        0, cfg.vocab, size=(b, s)).astype(np.int32)


class TestRaggedContinuousBatching:
    def test_mixed_lengths_match_solo_runs(self, dense):
        """Rows at different positions share the decode step; pad slots are
        junk from prefill and must never leak into any row's tokens."""
        cfg, params = dense
        prompts = _prompts(cfg, 3, 12, seed=3)
        lens = np.array([5, 12, 9], np.int32)
        mixed = generate(cfg, params, prompts, max_new=6, prompt_lens=lens)
        for i, n in enumerate(lens):
            solo = generate(cfg, params, prompts[i:i + 1, :n], max_new=6)
            assert (mixed[i] == solo[0]).all(), (i, mixed[i], solo[0])

    def test_pad_contents_never_observed(self, dense):
        """Same ragged batch, different junk in the pad slots => identical
        outputs (the masking claim, tested directly)."""
        cfg, params = dense
        lens = np.array([4, 9, 7], np.int32)
        a = _prompts(cfg, 3, 9, seed=5)
        b = a.copy()
        for i, n in enumerate(lens):
            b[i, n:] = (b[i, n:] + 17) % cfg.vocab   # different junk
        out_a = generate(cfg, params, a, max_new=5, prompt_lens=lens)
        out_b = generate(cfg, params, b, max_new=5, prompt_lens=lens)
        assert (out_a == out_b).all()

    def test_full_lens_equals_uniform_path(self, dense):
        """prompt_lens=[S0]*B must reproduce the scalar-position path."""
        cfg, params = dense
        prompts = _prompts(cfg, 4, 8, seed=7)
        uniform = generate(cfg, params, prompts, max_new=5)
        ragged = generate(cfg, params, prompts, max_new=5,
                          prompt_lens=np.full((4,), 8, np.int32))
        assert (uniform == ragged).all()

    @pytest.mark.parametrize("arch", ["hymba-1.5b", "xlstm-125m"])
    def test_ragged_refused_for_ring_and_recurrent_families(self, arch):
        """Ring buffers alias padded junk slots into the window and
        recurrent states scan pad tokens in — per-row masks can't undo
        either, so ragged serving must refuse loudly, not drift."""
        cfg = smoke_config(arch)
        params = transformer.init_params(cfg, jax.random.PRNGKey(2))
        with pytest.raises(NotImplementedError, match="ragged"):
            generate(cfg, params, _prompts(cfg, 2, 10), max_new=2,
                     prompt_lens=np.array([6, 10], np.int32))

    @pytest.mark.parametrize("arch", ["hymba-1.5b", "xlstm-125m"])
    def test_uniform_decode_families_still_serve(self, arch):
        """Signature changes (per-row pos plumbing) must not break the
        ring-buffer (SWA) and recurrent-state families on the scalar
        position path."""
        cfg = smoke_config(arch)
        params = transformer.init_params(cfg, jax.random.PRNGKey(2))
        out = generate(cfg, params, _prompts(cfg, 2, 10), max_new=4)
        assert out.shape == (2, 4)
        assert ((out >= 0) & (out < cfg.vocab)).all()


class TestCacheGrow:
    def test_grow_pads_end_and_casts(self, dense):
        cfg, params = dense
        b, s0, total = 2, 6, 14
        prefill = jax.jit(step_lib.make_prefill_step(cfg))
        _, cache = prefill(params, {"tokens": jnp.asarray(_prompts(cfg, b, s0))})
        target = transformer.abstract_cache(cfg, b, total)
        grown = grow_cache(cache, target)
        for leaf, tgt in zip(jax.tree.leaves(grown), jax.tree.leaves(target)):
            assert leaf.shape == tgt.shape and leaf.dtype == tgt.dtype
        # prefix slots preserved exactly, padded slots zero
        k0, kg = cache["k"], grown["k"]
        np.testing.assert_array_equal(np.asarray(kg[:, :, :s0]),
                                      np.asarray(k0.astype(kg.dtype)))
        assert not np.asarray(kg[:, :, s0:]).any()

    def test_grow_is_identity_at_target_shape(self, dense):
        cfg, _ = dense
        cache = transformer.init_cache(cfg, 2, 10)
        grown = grow_cache(cache, transformer.abstract_cache(cfg, 2, 10))
        for a, g in zip(jax.tree.leaves(cache), jax.tree.leaves(grown)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(g))


class TestSampling:
    def test_fixed_seed_is_deterministic(self, dense):
        cfg, params = dense
        prompts = _prompts(cfg, 3, 8, seed=11)
        one = generate(cfg, params, prompts, max_new=6, temperature=0.8,
                       seed=42)
        two = generate(cfg, params, prompts, max_new=6, temperature=0.8,
                       seed=42)
        assert (one == two).all()

    def test_seed_changes_samples(self, dense):
        cfg, params = dense
        prompts = _prompts(cfg, 4, 8, seed=11)
        a = generate(cfg, params, prompts, max_new=8, temperature=2.0, seed=0)
        b = generate(cfg, params, prompts, max_new=8, temperature=2.0, seed=1)
        assert (a != b).any()


class TestServeArgs:
    """--smoke was action="store_true" with default=True — impossible to
    disable, so the full-config branch was dead code. It is now --full."""

    def test_default_serves_smoke_config(self):
        from repro.launch.serve import build_parser, resolve_config
        args = build_parser().parse_args(["--arch", "granite-3-8b"])
        assert args.full is False
        cfg = resolve_config(args)
        assert cfg.name.endswith("-smoke")

    def test_full_flag_serves_published_config(self):
        from repro.configs import get_config
        from repro.launch.serve import build_parser, resolve_config
        args = build_parser().parse_args(["--arch", "granite-3-8b", "--full"])
        assert args.full is True
        cfg = resolve_config(args)
        assert cfg == get_config("granite-3-8b")
        assert not cfg.name.endswith("-smoke")

    def test_disagg_flags_parse(self):
        from repro.launch.serve import build_parser
        args = build_parser().parse_args(
            ["--disagg", "--cache-transfer", "int8", "--kv-storage", "int8"])
        assert args.disagg and args.cache_transfer == "int8" \
            and args.kv_storage == "int8"

    def test_stream_and_f8_flags_parse(self):
        from repro.launch.serve import build_parser
        args = build_parser().parse_args(
            ["--disagg", "--stream", "slots", "--slots", "3",
             "--cache-transfer", "int8", "--kv-storage", "f8"])
        assert args.stream == "slots" and args.slots == 3 \
            and args.kv_storage == "f8"
        assert build_parser().parse_args([]).stream == "batch"


class TestKVStorageInt8:
    """int8-resident decode cache, single-device (the sharded/transfer
    claims live in tests/test_serve_disagg.py)."""

    @pytest.mark.parametrize("arch", ["paper-lm-100m", "minicpm3-4b"])
    def test_int8_storage_logits_match_bf16(self, arch):
        cfg = smoke_config(arch)
        params = transformer.init_params(cfg, jax.random.PRNGKey(0))
        b, s0, total = 2, 8, 16
        prompts = _prompts(cfg, b, s0, seed=13)
        prefill = jax.jit(step_lib.make_prefill_step(cfg))
        logits0, cache = prefill(params, {"tokens": jnp.asarray(prompts)})
        cache = grow_cache(cache, transformer.abstract_cache(cfg, b, total))
        tok = jnp.argmax(logits0, -1).astype(jnp.int32)[:, None]
        batch = {"tokens": tok, "pos": jnp.asarray(s0, jnp.int32)}
        out = {}
        for storage in ("bf16", "int8"):
            c = cache
            if storage == "int8":
                c = transformer.quantize_cache_int8(cache)
            fn = jax.jit(step_lib.make_decode_step(cfg, total, "bf16",
                                                   storage))
            lg, new_c = fn(params, c, batch)
            # the step emits the same storage layout it consumed
            assert jax.tree.structure(new_c) == jax.tree.structure(c)
            out[storage] = np.asarray(lg, np.float32)
        scale = max(np.abs(out["bf16"]).max(), 1.0)
        assert np.abs(out["bf16"] - out["int8"]).max() / scale < 0.05

    def test_int8_storage_generate_tracks_bf16_tokens(self, dense):
        cfg, params = dense
        prompts = _prompts(cfg, 3, 10, seed=17)
        base = generate(cfg, params, prompts, max_new=8)
        quant = generate(cfg, params, prompts, max_new=8, kv_storage="int8")
        rows_equal = (base == quant).all(axis=1)
        assert rows_equal.mean() >= 0.5, (base, quant)

    def test_int8_storage_cache_layout(self):
        cfg = smoke_config("paper-lm-100m")
        struct = transformer.cache_struct(cfg, 2, 16, kv_storage="int8")
        assert "k_scale" in struct and "v_scale" in struct
        abs_c = transformer.abstract_cache(cfg, 2, 16, kv_storage="int8")
        assert abs_c["k"].dtype == jnp.int8
        assert abs_c["k_scale"].dtype == jnp.float32
        assert abs_c["k_scale"].shape[:-1] == abs_c["k"].shape[:-1]

    @pytest.mark.parametrize("arch", ["hymba-1.5b", "xlstm-125m"])
    @pytest.mark.parametrize("storage", ["int8", "f8"])
    def test_recurrent_families_refuse_quantized_storage(self, arch,
                                                         storage):
        cfg = smoke_config(arch)
        with pytest.raises(NotImplementedError, match="kv_storage"):
            step_lib.make_decode_step(cfg, 16, "bf16", storage)


class TestKVStorageF8:
    """f8 (e4m3) resident decode cache: scale-free cast, same shapes as
    bf16 at half the bytes. The sharded/report claims live in
    tests/test_serve_disagg.py."""

    @pytest.mark.parametrize("arch", ["paper-lm-100m", "minicpm3-4b"])
    def test_f8_storage_logits_match_bf16(self, arch):
        cfg = smoke_config(arch)
        params = transformer.init_params(cfg, jax.random.PRNGKey(0))
        b, s0, total = 2, 8, 16
        prompts = _prompts(cfg, b, s0, seed=13)
        prefill = jax.jit(step_lib.make_prefill_step(cfg))
        logits0, cache = prefill(params, {"tokens": jnp.asarray(prompts)})
        cache = grow_cache(cache, transformer.abstract_cache(cfg, b, total))
        tok = jnp.argmax(logits0, -1).astype(jnp.int32)[:, None]
        batch = {"tokens": tok, "pos": jnp.asarray(s0, jnp.int32)}
        out = {}
        for storage in ("bf16", "f8"):
            c = transformer.quantize_cache(cache, storage)
            fn = jax.jit(step_lib.make_decode_step(cfg, total, "bf16",
                                                   storage))
            lg, new_c = fn(params, c, batch)
            # the step emits the same storage layout it consumed
            assert jax.tree.structure(new_c) == jax.tree.structure(c)
            if storage == "f8":
                from repro.dist.collectives import F8_DTYPE
                quant_keys = [k for k in new_c
                              if k in transformer.QUANTIZABLE_CACHE_KEYS]
                assert quant_keys
                for k in quant_keys:
                    assert new_c[k].dtype == F8_DTYPE
            out[storage] = np.asarray(lg, np.float32)
        scale = max(np.abs(out["bf16"]).max(), 1.0)
        assert np.abs(out["bf16"] - out["f8"]).max() / scale < 0.08

    def test_f8_storage_generate_tracks_bf16_tokens(self, dense):
        cfg, params = dense
        prompts = _prompts(cfg, 3, 10, seed=17)
        base = generate(cfg, params, prompts, max_new=8)
        quant = generate(cfg, params, prompts, max_new=8, kv_storage="f8")
        rows_equal = (base == quant).all(axis=1)
        assert rows_equal.mean() >= 0.5, (base, quant)

    def test_f8_storage_cache_layout_scale_free_half_bytes(self):
        from repro.dist.collectives import F8_DTYPE
        cfg = smoke_config("paper-lm-100m")
        bf = transformer.abstract_cache(cfg, 2, 16)
        f8 = transformer.abstract_cache(cfg, 2, 16, kv_storage="f8")
        assert set(f8) == set(bf)                  # no _scale companions
        assert f8["k"].dtype == F8_DTYPE and f8["k"].shape == bf["k"].shape

        def nbytes(tree):
            return sum(np.prod(l.shape) * l.dtype.itemsize
                       for l in jax.tree.leaves(tree))
        assert nbytes(f8) == nbytes(bf) / 2


class TestSlotStreaming:
    """Continuous slot-level streaming, single device (the disagg mesh
    claims live in tests/test_serve_disagg.py): admission into a running
    decode batch must reproduce the whole-batch path token-for-token,
    including when a small slot table forces slots to be freed and
    reused across admissions."""

    def test_slot_stream_matches_batch_ragged(self, dense):
        cfg, params = dense
        prompts = _prompts(cfg, 3, 12, seed=3)
        lens = np.array([5, 12, 9], np.int32)
        batch = generate(cfg, params, prompts, max_new=6, prompt_lens=lens)
        slot = generate(cfg, params, prompts, max_new=6, prompt_lens=lens,
                        stream="slots")
        assert (batch == slot).all(), (batch, slot)

    def test_slot_reuse_no_cross_request_bleed(self, dense):
        """slots=1 serializes every request through ONE slot row — each
        admission must fully overwrite the previous occupant."""
        cfg, params = dense
        prompts = _prompts(cfg, 4, 10, seed=23)
        lens = np.array([4, 10, 7, 9], np.int32)
        batch = generate(cfg, params, prompts, max_new=5, prompt_lens=lens)
        for n_slots in (1, 2):
            slot = generate(cfg, params, prompts, max_new=5,
                            prompt_lens=lens, stream="slots", slots=n_slots)
            assert (batch == slot).all(), (n_slots, batch, slot)

    def test_slot_stream_uniform_and_quantized_pipeline(self, dense):
        cfg, params = dense
        prompts = _prompts(cfg, 3, 8, seed=29)
        batch = generate(cfg, params, prompts, max_new=5)
        slot = generate(cfg, params, prompts, max_new=5, stream="slots")
        assert (batch == slot).all()
        # the fully quantized continuous pipeline still produces sane,
        # mostly-agreeing tokens (lossy: s8 wire + f8-resident cache)
        q = generate(cfg, params, prompts, max_new=5, stream="slots",
                     cache_transfer="int8", kv_storage="f8")
        assert q.shape == batch.shape
        assert ((q >= 0) & (q < cfg.vocab)).all()
        assert (batch == q).all(axis=1).mean() >= 0.5

    def test_single_token_requests_all_served(self, dense):
        """max_new=1: each request IS its prefill token, so every slot
        frees at admission — the loop must keep refilling the table
        instead of breaking with requests unserved."""
        cfg, params = dense
        prompts = _prompts(cfg, 5, 8, seed=37)
        batch = generate(cfg, params, prompts, max_new=1)
        slot = generate(cfg, params, prompts, max_new=1, stream="slots",
                        slots=2)
        assert slot.shape == (5, 1)
        assert (batch == slot).all(), (batch, slot)

    def test_slot_stream_sampling_is_deterministic(self, dense):
        cfg, params = dense
        prompts = _prompts(cfg, 3, 8, seed=31)
        one = generate(cfg, params, prompts, max_new=5, temperature=0.8,
                       seed=42, stream="slots", slots=2)
        two = generate(cfg, params, prompts, max_new=5, temperature=0.8,
                       seed=42, stream="slots", slots=2)
        assert (one == two).all()

    @pytest.mark.parametrize("arch", ["hymba-1.5b", "xlstm-125m"])
    def test_slot_stream_serves_ring_and_recurrent(self, arch):
        """row_state families (ring-buffer hybrid, recurrent xLSTM) serve
        through slot streaming now that admission is a StateStore
        whole-row overwrite after an exact-length prefill: uniform-length
        slot tokens must match the whole-batch path bit-for-bit, even
        when a one-slot table forces reuse."""
        cfg = smoke_config(arch)
        params = transformer.init_params(cfg, jax.random.PRNGKey(2))
        prompts = _prompts(cfg, 3, 10, seed=41)
        batch = generate(cfg, params, prompts, max_new=4)
        for n_slots in (0, 1):
            slot = generate(cfg, params, prompts, max_new=4,
                            stream="slots", slots=n_slots)
            assert (batch == slot).all(), (n_slots, batch, slot)

    @pytest.mark.parametrize("arch", ["hymba-1.5b", "xlstm-125m"])
    def test_slot_stream_ragged_matches_solo_runs(self, arch):
        """Mixed lengths for row_state families: whole-batch ragged stays
        refused (pads would enter the scan state), but slot streaming
        prefills each request at its exact length — every row must match
        a solo run of its unpadded prompt."""
        cfg = smoke_config(arch)
        params = transformer.init_params(cfg, jax.random.PRNGKey(2))
        prompts = _prompts(cfg, 3, 10, seed=43)
        lens = np.array([6, 10, 8], np.int32)
        slot = generate(cfg, params, prompts, max_new=4, prompt_lens=lens,
                        stream="slots", slots=2)
        for i, ln in enumerate(lens):
            solo = generate(cfg, params, prompts[i:i + 1, :ln], max_new=4)
            assert (slot[i] == solo[0]).all(), (i, slot[i], solo[0])

    def test_unknown_stream_refused(self, dense):
        cfg, params = dense
        with pytest.raises(ValueError, match="stream"):
            generate(cfg, params, _prompts(cfg, 2, 8), max_new=2,
                     stream="rows")


class TestDisaggActTransport:
    def test_serve_decode_half_drops_int8_act_transport(self, monkeypatch):
        """Under the serve_decode preset the decode cache is resident (no
        per-step gather), so an int8 act transport would just round the
        whole cache through s8 every step for zero wire saved — generate
        must build the decode step with bf16 transport instead."""
        from repro.dist import sharding as shd
        from repro.launch import serve
        seen = {}
        real = step_lib.make_decode_step

        def spy(cfg, total, act_transport="bf16", kv_storage="bf16"):
            seen["act"] = act_transport
            return real(cfg, total, act_transport, kv_storage)

        monkeypatch.setattr(serve.step_lib, "make_decode_step", spy)
        cfg = smoke_config("paper-lm-100m")
        params = transformer.init_params(cfg, jax.random.PRNGKey(0))
        pre, dec = serve.make_disagg_meshes(cfg)
        serve.generate(cfg, params, _prompts(cfg, 2, 8), max_new=2,
                       mesh=pre, decode_mesh=dec, act_transport="int8")
        assert seen["act"] == "bf16"
        # custom decode rules keep the caller's transport choice
        serve.generate(cfg, params, _prompts(cfg, 2, 8), max_new=2,
                       mesh=pre, decode_mesh=dec, act_transport="int8",
                       decode_rules=shd.PRESETS["serve_sp"])
        assert seen["act"] == "int8"


class TestStateStoreBleed:
    """Cross-request bleed, at the state level: admitting request B into
    a slot previously held by A must leave the state table bit-identical
    to admitting B into a never-used table — no element of A's recurrent
    state survives, with or without an explicit free_row between."""

    @pytest.mark.parametrize("arch", ["hymba-1.5b", "xlstm-125m"])
    def test_readmission_leaves_no_trace_of_previous_occupant(self, arch):
        from repro.models import registry
        cfg = smoke_config(arch)
        params = transformer.init_params(cfg, jax.random.PRNGKey(2))
        store = registry.state_store(cfg, rows=2, total=16)
        prefill = jax.jit(step_lib.make_prefill_step(cfg))

        def row_state(seed):
            _, c = prefill(params,
                           {"tokens": jnp.asarray(_prompts(cfg, 1, 8,
                                                           seed=seed))})
            return grow_cache(c, store.abstract_row())

        row_a, row_b = row_state(51), row_state(52)

        def leaves_equal(x, y):
            return all(np.array_equal(np.asarray(l1), np.asarray(l2))
                       for l1, l2 in zip(jax.tree.leaves(x),
                                         jax.tree.leaves(y)))

        fresh_b = store.admit_row(store.init_state(), row_b, 0)
        # overwrite-on-admit: A -> B directly
        state = store.admit_row(store.init_state(), row_a, 0)
        assert not leaves_equal(state, fresh_b)       # A is really there
        assert leaves_equal(store.admit_row(state, row_b, 0), fresh_b)
        # explicit eviction: A -> free -> B
        freed = store.free_row(state, 0)
        assert leaves_equal(freed, store.init_state())
        assert leaves_equal(store.admit_row(freed, row_b, 0), fresh_b)

    @pytest.mark.parametrize("arch", ["hymba-1.5b", "xlstm-125m"])
    def test_reused_slot_tokens_match_solo_run(self, arch):
        """End to end: a one-slot table serializes requests through the
        same state row; each request's greedy tokens must still match a
        solo run of its prompt bit-for-bit."""
        cfg = smoke_config(arch)
        params = transformer.init_params(cfg, jax.random.PRNGKey(2))
        prompts = _prompts(cfg, 3, 9, seed=53)
        out = generate(cfg, params, prompts, max_new=3, stream="slots",
                       slots=1)
        for i in range(3):
            solo = generate(cfg, params, prompts[i:i + 1], max_new=3)
            assert (out[i] == solo[0]).all(), (i, out[i], solo[0])


class TestExpertParallelDecode:
    def test_ep_decode_routes_dispatch_through_expert_a2a(self, monkeypatch):
        """Under the ep preset with act_transport="int8", MoE decode must
        dispatch its expert all-to-all payload through the expert_a2a
        tunable op (train/prefill keep the bf16 einsum dispatch)."""
        from repro.dist import sharding as shd
        from repro.launch.mesh import make_local_mesh
        from repro.models import moe as moe_lib

        calls = []
        real = moe_lib.expert_a2a
        monkeypatch.setattr(moe_lib, "expert_a2a",
                            lambda xe, **kw: calls.append(xe.shape)
                            or real(xe, **kw))
        cfg = smoke_config("qwen3-moe-30b-a3b")
        params = transformer.init_params(cfg, jax.random.PRNGKey(3))
        prompts = _prompts(cfg, 2, 8, seed=59)
        mesh = make_local_mesh()
        out = generate(cfg, params, prompts, max_new=3, mesh=mesh,
                       rules=shd.PRESETS["ep"], act_transport="int8")
        assert calls, "decode never dispatched through expert_a2a"
        assert all(len(s) == 4 for s in calls)   # (g, e, c, d) payloads
        assert out.shape == (2, 3)
        assert ((out >= 0) & (out < cfg.vocab)).all()

    def test_bf16_transport_keeps_einsum_dispatch(self, monkeypatch):
        """No int8 transport => no quantized wire: the op must not fire,
        and tokens are bit-identical to the no-mesh path."""
        from repro.models import moe as moe_lib
        calls = []
        monkeypatch.setattr(moe_lib, "expert_a2a",
                            lambda xe, **kw: calls.append(1) or xe)
        cfg = smoke_config("qwen3-moe-30b-a3b")
        params = transformer.init_params(cfg, jax.random.PRNGKey(3))
        prompts = _prompts(cfg, 2, 8, seed=59)
        generate(cfg, params, prompts, max_new=3)
        assert not calls
