"""Auto-tuning of compaction triggers (§6.3): iteratively refine trigger
thresholds against an end-to-end workload objective.

The paper uses MLOS+FLAML; this is a dependency-free deterministic stand-in
with the same interface: propose -> evaluate(threshold) -> observe duration.
Strategy: coarse grid sweep, then successive halving around the incumbent
(golden-section-flavored local refinement). :func:`tune_design` extends the
same propose/evaluate/observe loop to *discrete* design spaces (the serve
path's cache-transfer x kv-storage x stream-block sweep) via memoized
coordinate-descent hillclimbing.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Dict, List, Optional, Sequence, Tuple


@dataclasses.dataclass
class TuneResult:
    history: List[Tuple[float, float]]      # (threshold, objective)
    best_threshold: float
    best_objective: float
    iterations: int


def tune_threshold(evaluate: Callable[[float], float],
                   lo: float, hi: float,
                   coarse: int = 5, refine_rounds: int = 3,
                   minimize: bool = True) -> TuneResult:
    """Tune a single trigger threshold in [lo, hi].

    ``evaluate`` runs the workload under the threshold and returns the
    end-to-end duration (the y-axis of Fig. 9). Deterministic: same
    evaluate -> same result.
    """
    sign = 1.0 if minimize else -1.0
    history: List[Tuple[float, float]] = []

    def ev(x: float) -> float:
        y = evaluate(x)
        history.append((x, y))
        return sign * y

    # coarse grid
    grid = [lo + (hi - lo) * i / (coarse - 1) for i in range(coarse)]
    scores = [(ev(x), x) for x in grid]
    best_s, best_x = min(scores)

    # successive halving around incumbent
    span = (hi - lo) / (coarse - 1)
    for _ in range(refine_rounds):
        span /= 2
        for cand in (best_x - span, best_x + span):
            if lo <= cand <= hi:
                s = ev(cand)
                if s < best_s:
                    best_s, best_x = s, cand
    return TuneResult(history=history, best_threshold=best_x,
                      best_objective=sign * best_s, iterations=len(history))


@dataclasses.dataclass
class DesignResult:
    history: List[Tuple[Dict[str, object], float]]   # (point, objective)
    best_point: Dict[str, object]
    best_objective: float
    evaluations: int
    rounds: int


def tune_design(evaluate: Callable[[Dict[str, object]], float],
                axes: Dict[str, Sequence],
                minimize: bool = True,
                max_rounds: int = 8,
                start: Optional[Dict[str, object]] = None,
                exhaustive: bool = False) -> DesignResult:
    """Coordinate-descent hillclimb over a *discrete* design space.

    ``axes`` maps each knob to its ordered candidate values (e.g.
    ``{"cache_transfer": ("bf16", "int8"), "kv_storage": ("bf16", "int8",
    "f8"), "block": (128, 256, 512)}`` — the serve-path transfer x storage
    x block space the dryrun sweeps). Starting from the first value of
    every axis (or from ``start``, e.g. an incumbent fleet class profile
    being re-tuned warm), each round walks the axes in declaration order
    and moves one coordinate at a time to its best value with the others
    held fixed; the climb stops at the first round that moves nothing.
    Deterministic (axis and value order fix the walk) and memoized, so a
    point is never evaluated twice — with N axes of k values each, at most
    1 + rounds * N * (k - 1) evaluations instead of k**N.

    ``exhaustive=True`` evaluates the full cartesian product instead (the
    kernel block sweeps use this: their spaces are a handful of block-size
    candidates, small enough that the guaranteed optimum is worth k**N
    evaluations). Same memoization, history, and result shape.
    """
    sign = 1.0 if minimize else -1.0
    history: List[Tuple[Dict[str, object], float]] = []
    memo: Dict[Tuple, float] = {}

    def ev(point: Dict[str, object]) -> float:
        key = tuple(point[a] for a in axes)
        if key not in memo:
            y = evaluate(dict(point))
            memo[key] = sign * y
            history.append((dict(point), y))
        return memo[key]

    best = {a: vals[0] for a, vals in axes.items()}
    if start is not None:
        for a, vals in axes.items():
            if a in start and start[a] in vals:
                best[a] = start[a]
    best_s = ev(best)
    if exhaustive:
        names = list(axes)
        for combo in itertools.product(*axes.values()):
            point = dict(zip(names, combo))
            s = ev(point)
            if s < best_s:
                best, best_s = point, s
        return DesignResult(history=history, best_point=best,
                            best_objective=sign * best_s,
                            evaluations=len(history), rounds=1)
    rounds = 0
    for _ in range(max_rounds):
        rounds += 1
        moved = False
        for axis, vals in axes.items():
            for cand in vals:
                if cand == best[axis]:
                    continue
                point = {**best, axis: cand}
                s = ev(point)
                if s < best_s:
                    best, best_s = point, s
                    moved = True
        if not moved:
            break
    return DesignResult(history=history, best_point=best,
                        best_objective=sign * best_s,
                        evaluations=len(history), rounds=rounds)


def tune_weights(evaluate: Callable[[Dict[str, float]], float],
                 benefit_trait: str, cost_trait: str,
                 grid: Sequence[float] = (0.3, 0.5, 0.7, 0.9),
                 minimize: bool = True) -> Tuple[Dict[str, float], float]:
    """Sweep the MOOP benefit weight w1 (w2 = 1 - w1)."""
    sign = 1.0 if minimize else -1.0
    best = None
    for w1 in grid:
        w = {benefit_trait: w1, cost_trait: 1.0 - w1}
        y = sign * evaluate(w)
        if best is None or y < best[1]:
            best = (w, y)
    return best[0], sign * best[1]
