"""Unit coverage for repro.dist.collectives beyond the hypothesis bounds in
test_dist.py: zero blocks, ragged tails, and the compressed_psum carry API."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.collectives import (compressed_psum, dequantize_int8,
                                    quantize_int8)


class TestQuantize:
    def test_zero_vector_roundtrips_exactly(self):
        x = jnp.zeros((300,), jnp.float32)
        q, s = quantize_int8(x, block=128)
        assert q.dtype == jnp.int8
        out = dequantize_int8(q, s, 300)
        np.testing.assert_array_equal(np.asarray(out), 0.0)

    def test_ragged_tail_padding(self):
        rng = np.random.RandomState(0)
        x = jnp.asarray(rng.randn(1000), jnp.float32)   # 1000 % 256 != 0
        q, s = quantize_int8(x, block=256)
        assert q.shape == (4, 256) and s.shape == (4,)
        out = dequantize_int8(q, s, 1000)
        assert out.shape == (1000,)
        bound = float(jnp.max(jnp.abs(x))) / 127.0 + 1e-6
        assert float(jnp.max(jnp.abs(out - x))) <= bound

    def test_jit_compatible(self):
        x = jnp.linspace(-3.0, 3.0, 512)

        @jax.jit
        def roundtrip(v):
            q, s = quantize_int8(v, block=64)
            return dequantize_int8(q, s, v.shape[0])

        out = roundtrip(x)
        assert float(jnp.max(jnp.abs(out - x))) <= 3.0 / 127.0 + 1e-6


class TestCompressedPsum:
    def test_single_device_identity_with_error_feedback(self):
        """axis_name=None degenerates to quantize->dequantize; carrying the
        residual keeps the accumulated sum unbiased (DRAGONN-style EF)."""
        rng = np.random.RandomState(3)
        x = jnp.asarray(rng.randn(512), jnp.float32)
        err = jnp.zeros_like(x)
        acc = jnp.zeros_like(x)
        steps = 16
        for _ in range(steps):
            out, err = compressed_psum(x, None, err, block=64)
            acc = acc + out
        rel = float(jnp.linalg.norm(acc - steps * x)
                    / jnp.linalg.norm(steps * x))
        assert rel < 0.02

    def test_first_step_accepts_none_err(self):
        x = jnp.ones((64,), jnp.float32)
        out, err = compressed_psum(x, None, None, block=32)
        assert out.shape == x.shape and err.shape == x.shape

    def test_preserves_dtype_and_shape(self):
        x = jnp.ones((4, 32), jnp.bfloat16)
        out, err = compressed_psum(x, None, None, block=16)
        assert out.dtype == jnp.bfloat16 and out.shape == (4, 32)
        assert err.dtype == jnp.float32


class TestCacheStreamQuantizers:
    """Seq-axis blockwise quantization — the disagg cache-stream wire
    format (quantize on the prefill mesh, dequantize on arrival)."""

    def test_seqaxis_roundtrip_error_bound(self):
        from repro.dist.collectives import (dequantize_int8_seqaxis,
                                            quantize_int8_seqaxis)
        rng = np.random.RandomState(7)
        x = jnp.asarray(rng.randn(3, 8, 512, 2, 16), jnp.float32)  # seq=dim2
        q, s = quantize_int8_seqaxis(x, 2, block=256)
        assert q.dtype == jnp.int8 and q.shape == (3, 8, 2, 16, 512)
        assert s.shape == (3, 8, 2, 16, 2)          # 512 / 256 blocks
        out = dequantize_int8_seqaxis(q, s, 2)
        assert out.shape == x.shape
        # error <= half a quantization step of each block's abs-max
        step = jnp.moveaxis(jnp.repeat(s, 256, axis=-1), -1, 2)
        assert float(jnp.max(jnp.abs(out - x) - step / 2)) <= 1e-6

    def test_lastdim_blocks_fallback(self):
        from repro.dist.collectives import lastdim_blocks
        assert lastdim_blocks(512, 256) == (256, 2)
        assert lastdim_blocks(48, 256) == (48, 1)   # non-divisible: one block

    def test_stream_int8_identity_out_of_context(self):
        """Outside axis_rules, stream_int8 is pure quantize->dequantize:
        same values the real two-mesh transfer delivers."""
        from repro.dist.collectives import (dequantize_int8_seqaxis,
                                            quantize_int8_seqaxis,
                                            stream_int8)
        rng = np.random.RandomState(8)
        x = jnp.asarray(rng.randn(2, 64, 4), jnp.bfloat16)
        out = stream_int8(x, "batch", "kv_seq", None, seq_axis=1, block=32)
        assert out.dtype == x.dtype and out.shape == x.shape
        ref = dequantize_int8_seqaxis(
            *quantize_int8_seqaxis(x, 1, block=32), 1).astype(x.dtype)
        assert (out == ref).all()

    def test_all_gather_int8_passes_s8_through(self):
        """An int8-resident cache leaf must not be re-quantized by the
        int8 act transport — it crosses as-is."""
        from repro.dist.collectives import all_gather_int8
        q = jnp.asarray(np.arange(-8, 8, dtype=np.int8).reshape(4, 4))
        out = all_gather_int8(q, "batch", None)
        assert out.dtype == jnp.int8
        assert (out == q).all()
