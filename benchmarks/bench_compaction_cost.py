"""Fig. 7 — mean GBHr_App per compaction strategy.

The paper's observation: table-scope compaction is effective on heavily
fragmented layouts but spiky in resource use; hybrid (partition-scope) gives
a more stable GBHr across operations.
"""

from __future__ import annotations

from typing import List

from benchmarks.workload_sim import run_sim

STRATEGIES = ("table-10", "hybrid-50", "hybrid-500")


def main(hours: int = 5) -> List[str]:
    rows = []
    for strat in STRATEGIES:
        res = run_sim(strategy=strat, hours=hours, seed=0)
        rows.append(
            f"fig7_gbhr[{strat}],{res['mean_cycle_gbhr']:.5f},"
            f"std={res['std_cycle_gbhr']:.5f};removed={res['total_files_removed']}")
    return rows


if __name__ == "__main__":
    for r in main():
        print(r)
