"""Selective SSM (Mamba-style) head used by the Hymba hybrid layer.

Training/prefill uses a chunked associative scan — projections AND the
(B, c, di, N) state tensors are materialized one chunk at a time inside a
``lax.scan``, so peak memory is O(B * CHUNK * di * N) instead of O(B * S *
di * N). Decode is the exact single-step recurrence with O(1) state:
conv tail (B, conv-1, di) + SSM state (B, di, N).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig
from repro.dist.sharding import constrain
from repro.models.common import Spec

DT_RANK = 16
CHUNK = 256


def ssm_specs(cfg: ModelConfig) -> Dict[str, Spec]:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    n = cfg.ssm_state
    return {
        "in_proj": Spec((d, 2, di), ("embed", None, "ssm_inner")),
        "conv_w": Spec((cfg.ssm_conv, di), ("conv", "ssm_inner")),
        "x_proj": Spec((di, DT_RANK + 2 * n), ("ssm_inner", None)),
        "dt_proj": Spec((DT_RANK, di), (None, "ssm_inner")),
        "dt_bias": Spec((di,), ("ssm_inner",), init="zeros"),
        "a_log": Spec((di, n), ("ssm_inner", "ssm_state"), init="small",
                      dtype=jnp.float32),
        "d_skip": Spec((di,), ("ssm_inner",), init="ones", dtype=jnp.float32),
        "out_proj": Spec((di, d), ("ssm_inner", "embed")),
    }


def _ssm_inputs(cfg, p, xz):
    """Gate/state projections. xz: post-conv activations (B, c, di)."""
    n = cfg.ssm_state
    dbc = jnp.einsum("bsi,ir->bsr", xz, p["x_proj"])
    dt_low, bmat, cmat = jnp.split(dbc, [DT_RANK, DT_RANK + n], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,ri->bsi", dt_low, p["dt_proj"]).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32))                     # (B,c,di)
    a = -jnp.exp(p["a_log"])                                    # (di,N)
    da = jnp.exp(dt[..., None] * a)                             # (B,c,di,N)
    dbx = (dt * xz.astype(jnp.float32))[..., None] \
        * bmat.astype(jnp.float32)[:, :, None, :]
    return da, dbx, cmat.astype(jnp.float32)


def _causal_conv(p, x, conv_state=None):
    """Depthwise causal conv. x:(B,S,di); conv_state:(B,K-1,di) or None."""
    k = p["conv_w"].shape[0]
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = conv_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * p["conv_w"][i] for i in range(k))
    new_state = xp[:, -(k - 1):]
    return jax.nn.silu(out), new_state


def ssm_apply(cfg: ModelConfig, p, x: jnp.ndarray, mode: str,
              cache: Optional[dict]) -> Tuple[jnp.ndarray, Optional[dict]]:
    """x: (B,S,d). cache: {"conv": (B,K-1,di), "ssm": (B,di,N)} for decode."""
    b, s, d = x.shape
    proj = constrain(jnp.einsum("bsd,dzi->bszi", x, p["in_proj"]),
                     "batch", None, None, "ssm_inner")
    xin, z = proj[:, :, 0], proj[:, :, 1]

    if mode == "decode":
        xc, conv_state = _causal_conv(p, xin, cache["conv"])
        da, dbx, cmat = _ssm_inputs(cfg, p, xc)
        h = cache["ssm"].astype(jnp.float32) * da[:, 0] + dbx[:, 0]  # (B,di,N)
        y = jnp.einsum("bin,bn->bi", h, cmat[:, 0])[:, None]
        xc_last, h_last = xc, h
        new_cache = {"conv": conv_state.astype(cache["conv"].dtype),
                     "ssm": h.astype(cache["ssm"].dtype)}
    else:
        xc, conv_tail = _causal_conv(p, xin, None)
        y, h_last = _chunked_ssm(cfg, p, xc)
        xc_last = xc
        new_cache = None
        if mode == "prefill":
            new_cache = {"conv": conv_tail.astype(jnp.bfloat16),
                         "ssm": h_last.astype(jnp.bfloat16)}
    y = y + xc_last.astype(jnp.float32) * p["d_skip"]
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return jnp.einsum("bsi,id->bsd", y, p["out_proj"]), new_cache


def _chunked_ssm(cfg, p, xc):
    """Chunked selective scan. xc: (B,S,di) post-conv. -> y (B,S,di) fp32,
    final state (B,di,N) fp32."""
    b, s, di = xc.shape
    n = cfg.ssm_state
    c = min(CHUNK, s)
    assert s % c == 0, (s, c)
    nc = s // c
    xcc = xc.reshape(b, nc, c, di).transpose(1, 0, 2, 3)         # (nc,B,c,di)

    def chunk_step(h0, x_blk):
        da, dbx, cmat = _ssm_inputs(cfg, p, x_blk)               # (B,c,di,N)

        def combine(l, r):
            al, bl = l
            ar, br = r
            return al * ar, bl * ar + br

        a_cum, b_cum = jax.lax.associative_scan(combine, (da, dbx), axis=1)
        h = constrain(a_cum * h0[:, None] + b_cum,
                      "batch", None, "ssm_inner", None)          # (B,c,di,N)
        y = constrain(jnp.einsum("bsin,bsn->bsi", h, cmat),
                      "batch", None, "ssm_inner")                # (B,c,di)
        return h[:, -1], y

    h0 = jnp.zeros((b, di, n), jnp.float32)
    h_last, ys = jax.lax.scan(chunk_step, h0, xcc)
    return ys.transpose(1, 0, 2, 3).reshape(b, s, di), h_last


def ssm_cache_shape(cfg: ModelConfig, batch: int):
    di = cfg.ssm_expand * cfg.d_model
    return {"conv": (batch, cfg.ssm_conv - 1, di),
            "ssm": (batch, di, cfg.ssm_state)}


def ssm_cache_axes():
    """Logical axes of the O(1) recurrent SSM state (StateStore protocol
    contribution; the stack prepends its "layers" axis). No ``kv_seq``
    axis — slot streaming admits these leaves as whole-row overwrites."""
    return {"conv": ("batch", None, "ssm_inner"),
            "ssm": ("batch", "ssm_inner", "ssm_state")}
