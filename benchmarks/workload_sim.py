"""Shared benchmark harness: CAB-like workload + AutoComp strategies.

``run_sim`` drives the synthetic workload for N logical hours under a
compaction strategy and returns everything the paper's figures plot:
hourly file counts, query-latency percentiles, client/cluster conflicts,
GBHr per cycle, and an end-to-end duration objective.

Strategies (§6 "Candidate Selection and Scheduling"):
  none          -- no compaction (baseline)
  table-K       -- table-scope candidates, top-K per cycle
  hybrid-K      -- partition scope for partitioned tables, else table; top-K
Triggers:
  periodic      -- every hour (the §6 setup)
  small_files   -- optimize-after-write threshold on small-file count (§6.3)
  entropy       -- optimize-after-write threshold on file entropy (§6.3)
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core import (AutoCompPipeline, FleetScheduler, MoopRanker,
                        StatsCollector, TraitContext)
from repro.core.act import Scheduler
from repro.core.decide import ThresholdPolicy
from repro.core.model import Scope
from repro.core.orient import (ComputeCostTrait, FileCountReductionTrait,
                               FileEntropyTrait)
from repro.lst import Catalog, InMemoryStore
from repro.lst.workload import (ActivityTracker, CostModel, FleetSpec,
                                SimClock, WorkloadGenerator, WorkloadSpec)

MB = 1 << 20
TARGET = 512 * MB


def make_fleet(fspec: FleetSpec, budget_gbhr: float,
               warmup_hours: int = 1, starvation_cycles: int = 4,
               **fleet_kw):
    """Build a fleet world: storm-mix workload + ActivityTracker wired into
    the scheduler's observe phase. Returns (clock, catalog, gen, tracker,
    fleet) after ``warmup_hours`` of ingestion so classification has
    activity to read."""
    clock = SimClock()
    store = InMemoryStore()
    catalog = Catalog(store, now_fn=clock.now)
    gen = WorkloadGenerator(catalog, WorkloadSpec(seed=fspec.seed), clock)
    gen.setup_fleet(fspec)
    tracker = ActivityTracker(now_fn=clock.now)
    for _ in range(warmup_hours):
        tracker.record(gen.run_hour(substeps=1))
    fleet = FleetScheduler(catalog, budget_gbhr=budget_gbhr,
                           activity=tracker,
                           starvation_cycles=starvation_cycles, **fleet_kw)
    return clock, catalog, gen, tracker, fleet


def make_pipeline(scope: str, k: int, target: int = TARGET,
                  weights=(0.7, 0.3), budget: Optional[float] = None
                  ) -> AutoCompPipeline:
    return AutoCompPipeline(
        stats=StatsCollector(target),
        traits=(FileCountReductionTrait(), FileEntropyTrait(),
                ComputeCostTrait()),
        trait_ctx=TraitContext(target_file_bytes=target),
        ranker=MoopRanker({"file_count_reduction": weights[0],
                           "compute_cost": weights[1]}),
        scheduler=Scheduler(target),
        scope=Scope.TABLE,
        hybrid=(scope == "hybrid"),
        top_k=k,
        budget_gbhr=budget,
    )


def run_sim(strategy: str = "none", hours: int = 5, seed: int = 0,
            profile: str = "balanced", trigger: str = "periodic",
            threshold: float = 0.0, n_databases: int = 3,
            tables_per_db: int = 4, weights=(0.7, 0.3),
            budget: Optional[float] = None,
            interleave: bool = True) -> Dict[str, Any]:
    clock = SimClock()
    store = InMemoryStore()
    catalog = Catalog(store, now_fn=clock.now)
    spec = WorkloadSpec(n_databases=n_databases, tables_per_db=tables_per_db,
                        seed=seed)
    gen = WorkloadGenerator(catalog, spec, clock)
    if profile == "write_heavy":
        gen.rng = np.random.RandomState(seed)
        gen.setup()
        for st in gen.streams:
            st.writes_per_hour *= 4
            st.reads_per_hour *= 0.3
    elif profile == "read_heavy":
        gen.setup()
        for st in gen.streams:
            st.reads_per_hour *= 3
            st.writes_per_hour *= 0.5
    else:
        gen.setup()

    pipeline = None
    scope, k = "none", 0
    if strategy != "none":
        scope, k_str = strategy.split("-")
        k = int(k_str)
        pipeline = make_pipeline(scope, k, weights=weights, budget=budget)

        # concurrent user writes land while a rewrite task is in flight; the
        # collision window scales with the rewrite size (why the paper's
        # table-scope runs conflict while hybrid's small tasks barely do)
        if interleave:
            def interleave_fn(table, task):
                window = min(0.8, task.input_bytes / (64 * MB))
                if gen.rng.rand() < window:
                    gen._append_small_files(table, int(gen.rng.randint(1, 5)))
            pipeline.scheduler.interleave_fn = interleave_fn

    hourly: List[Dict[str, Any]] = []
    cycle_gbhr: List[float] = []
    cluster_conflicts = 0
    compaction_failures = 0
    total_files_removed = 0
    pred_vs_actual: List[Tuple[float, float, float, float]] = []

    for h in range(hours):
        events = gen.run_hour()
        reads = [e for e in events if e.kind == "read"]
        writes = [e for e in events if e.kind == "write"]
        lat = sorted(e.latency for e in reads) or [0.0]

        def pct(p):
            return lat[min(len(lat) - 1, int(p * len(lat)))]

        row = {
            "hour": h + 1,
            "file_count": gen.total_file_count(),
            "small_frac": gen.small_file_fraction(TARGET),
            "reads": len(reads),
            "writes": len(writes),
            "client_conflicts": sum(1 for e in writes if e.conflict),
            "lat_p50": pct(0.5), "lat_p95": pct(0.95),
            "lat_sum": sum(e.latency for e in reads),
        }

        run_compaction = False
        if pipeline is not None:
            if trigger == "periodic":
                run_compaction = True
            else:
                trait = ("file_count_reduction" if trigger == "small_files"
                         else "file_entropy")
                pol = ThresholdPolicy(trait, threshold)
                probe = make_pipeline(scope, k)
                from repro.core.model import generate_candidates
                cands = generate_candidates(catalog.tables(),
                                            hybrid=(scope == "hybrid"))
                probe.stats.observe_all(cands)
                from repro.core.orient import compute_traits
                compute_traits(cands, probe.traits, probe.trait_ctx)
                run_compaction = bool(pol.decide(cands))
        if run_compaction:
            # predicted traits for accuracy accounting (§7)
            rep = pipeline.run_cycle(catalog)
            cycle_gbhr.append(rep.gbhr)
            total_files_removed += rep.files_removed - rep.act.files_added
            cluster_conflicts += rep.act.conflicts
            compaction_failures += rep.act.failures
            row["compaction_gbhr"] = rep.gbhr
            row["cluster_conflicts"] = rep.act.conflicts
            row["files_removed"] = rep.files_removed
        hourly.append(row)

    total_read_latency = sum(r["lat_sum"] for r in hourly)
    retry_penalty = sum(r["client_conflicts"] for r in hourly) * 2.0
    # shared-cluster occupancy: each GBHr of compaction displaces query
    # compute (the paper's TPC-H case, where compaction is a net loss for
    # write-dominated workloads with little read benefit)
    occupancy_penalty = sum(cycle_gbhr) * 120.0
    duration_s = total_read_latency + retry_penalty + occupancy_penalty

    return {
        "strategy": strategy, "hours": hours, "profile": profile,
        "hourly": hourly,
        "duration_s": duration_s,
        "final_file_count": gen.total_file_count(),
        "final_small_frac": gen.small_file_fraction(TARGET),
        "mean_cycle_gbhr": float(np.mean(cycle_gbhr)) if cycle_gbhr else 0.0,
        "std_cycle_gbhr": float(np.std(cycle_gbhr)) if cycle_gbhr else 0.0,
        "total_files_removed": total_files_removed,
        "cluster_conflicts": cluster_conflicts,
        "compaction_failures": compaction_failures,
        "store_metrics": store.metrics.snapshot(),
        "object_count": store.object_count,
    }
