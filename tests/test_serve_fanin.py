"""Multi-prefill-worker fan-in, slot preemption, and the paged slot
cache (launch/serve.py + dist/fanin.py), on any device count.

Arbiter layer (pure host): FIFO-with-priority-classes ordering, aging +
hard promotion (the fleet scheduler's starvation guarantee translated to
admission passes), least-loaded worker assignment that never skips
ahead, justified-only eviction, and NFR2 determinism — the admission
order is a total order with no wall-clock input.

Engine layer (real model, smoke config): evicted-then-readmitted
requests produce greedy tokens bit-identical to an uncontended run
(recompute preemption re-prefills the extended prompt); the paged slot
table bit-matches the unpaged path; requests past the unpaged horizon
are refused loudly (never silently truncated) while ``--paged`` admits
them; pool exhaustion is a loud error. The forced-8-device mesh legs
live in tests/test_serve_disagg.py."""

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import smoke_config
from repro.dist import fanin
from repro.launch import serve
from repro.models import transformer


def _req(rid, priority=0, plen=4, max_new=4):
    return fanin.Request(rid=rid, prompt=np.zeros((plen,), np.int32),
                         max_new=max_new, priority=priority)


class TestArbiterOrdering:
    def test_fifo_within_class(self):
        arb = fanin.AdmissionArbiter(workers=1, classes=1)
        for rid in (7, 3, 5):
            arb.submit(_req(rid))
        assert [r.rid for r in arb.ordered()] == [7, 3, 5]

    def test_higher_class_beats_enqueue_order(self):
        arb = fanin.AdmissionArbiter(workers=1, classes=3)
        arb.submit(_req(0, priority=2))
        arb.submit(_req(1, priority=1))
        arb.submit(_req(2, priority=0))    # most urgent, submitted last
        assert [r.rid for r in arb.ordered()] == [2, 1, 0]

    def test_aging_boosts_urgency_up_to_the_bound(self):
        arb = fanin.AdmissionArbiter(workers=1, classes=2)
        r = arb.submit(_req(0, priority=1))
        u0 = arb.urgency(r)
        r.skips = arb.promotion_cycles - 1
        assert arb.urgency(r) > u0
        r.skips = arb.promotion_cycles
        capped = arb.urgency(r)
        r.skips = arb.promotion_cycles * 10
        assert arb.urgency(r) == capped    # boost is capped, not unbounded

    def test_hard_promoted_sort_first_oldest_first(self):
        arb = fanin.AdmissionArbiter(workers=1, classes=2)
        worst = arb.submit(_req(0, priority=1))
        older = arb.submit(_req(1, priority=1))
        arb.submit(_req(2, priority=0))    # best class, not promoted
        worst.skips = older.skips = arb.promotion_cycles
        assert [r.rid for r in arb.ordered()] == [0, 1, 2]

    def test_order_is_independent_of_internal_queue_permutation(self):
        """NFR2: the admission order is a total order over request state
        — permuting the arrival bookkeeping cannot permute it."""
        arb = fanin.AdmissionArbiter(workers=2, classes=3)
        for rid in range(9):
            arb.submit(_req(rid, priority=rid % 3))
        want = [r.rid for r in arb.ordered()]
        rng = np.random.RandomState(0)
        for _ in range(5):
            arb.queue = [arb.queue[i]
                         for i in rng.permutation(len(arb.queue))]
            assert [r.rid for r in arb.ordered()] == want

    def test_submit_rejects_priority_outside_classes(self):
        arb = fanin.AdmissionArbiter(workers=1, classes=2)
        with pytest.raises(ValueError, match="priority"):
            arb.submit(_req(0, priority=2))


class TestWorkerAssignment:
    def test_least_loaded_lowest_numbered_wins(self):
        arb = fanin.AdmissionArbiter(workers=3, classes=1, max_inflight=2)
        arb.inflight[0] = 1                # worker 0 already busy
        a, b, c = (arb.submit(_req(r)) for r in range(3))
        arb.assign()
        assert (a.worker, b.worker, c.worker) == (1, 2, 0)

    def test_full_workers_never_skip_ahead(self):
        """When the least-loaded worker is full, assignment stops — a
        later request must not jump an earlier one in arbiter order."""
        arb = fanin.AdmissionArbiter(workers=1, classes=1, max_inflight=1)
        first = arb.submit(_req(0))
        second = arb.submit(_req(1))
        assert [r.rid for r in arb.assign()] == [0]
        assert second.worker == -1
        assert arb.next_admission() is first

    def test_admit_releases_the_worker(self):
        arb = fanin.AdmissionArbiter(workers=1, classes=1, max_inflight=1)
        first = arb.submit(_req(0))
        second = arb.submit(_req(1))
        arb.assign()
        arb.admit(first)
        assert arb.inflight == [0]
        assert [r.rid for r in arb.assign()] == [1]
        assert second.worker == 0


class TestEviction:
    def _occ(self, *prio_seq):
        return [fanin.Occupant(rid=i, priority=p, admit_seq=s)
                for i, (p, s) in enumerate(prio_seq)]

    def test_oldest_picks_earliest_admitted(self):
        arb = fanin.AdmissionArbiter(workers=1, classes=2)
        pending = arb.submit(_req(9, priority=0))
        occ = self._occ((1, 5), (1, 2), (1, 8))
        assert arb.pick_victim(occ, "oldest", pending) == 1

    def test_priority_picks_worst_class_then_oldest(self):
        arb = fanin.AdmissionArbiter(workers=1, classes=3)
        pending = arb.submit(_req(9, priority=0))
        occ = self._occ((1, 0), (2, 6), (2, 3))
        assert arb.pick_victim(occ, "priority", pending) == 2

    def test_equal_rank_pressure_is_refused(self):
        """Unjustified eviction would thrash the table: an equal-class
        pending request ages in the queue instead."""
        arb = fanin.AdmissionArbiter(workers=1, classes=2)
        pending = arb.submit(_req(9, priority=1))
        occ = self._occ((1, 0))
        assert arb.pick_victim(occ, "oldest", pending) is None
        assert arb.pick_victim(occ, "priority", pending) is None

    def test_hard_promotion_justifies_equal_class_eviction(self):
        arb = fanin.AdmissionArbiter(workers=1, classes=2)
        pending = arb.submit(_req(9, priority=1))
        pending.skips = arb.promotion_cycles
        assert arb.pick_victim(self._occ((1, 0)), "oldest", pending) == 0

    def test_none_policy_and_unknown_policy(self):
        arb = fanin.AdmissionArbiter(workers=1, classes=2)
        pending = arb.submit(_req(9, priority=0))
        assert arb.pick_victim(self._occ((1, 0)), "none", pending) is None
        with pytest.raises(ValueError, match="eviction policy"):
            arb.pick_victim(self._occ((1, 0)), "bogus", pending)


class TestStarvationBound:
    @given(st.integers(min_value=2, max_value=4),
           st.integers(min_value=6, max_value=20))
    @settings(max_examples=20, deadline=None)
    def test_worst_class_request_waits_at_most_promotion_cycles(
            self, classes, pressure):
        """The fleet scheduler's starvation guarantee, translated: under
        a continuous stream of most-urgent arrivals, a worst-class
        request is hard-promoted after ``promotion_cycles`` lost passes
        and admitted on the next one — its wait is bounded by the
        promotion bound, not by the pressure."""
        arb = fanin.AdmissionArbiter(workers=1, classes=classes,
                                     max_inflight=64)
        victim = arb.submit(_req(999, priority=classes - 1))
        rid = 0
        waited = None
        for _ in range(pressure + arb.promotion_cycles + 2):
            if rid < pressure:             # fresh class-0 pressure
                arb.submit(_req(rid, priority=0))
                rid += 1
            arb.assign()
            req = arb.next_admission()
            assert req is not None
            arb.admit(req)                 # one slot, freed every pass
            if req is victim:
                waited = req.skips
                break
            arb.age()
        assert waited is not None
        assert waited <= arb.promotion_cycles


# --- engine layer: real model, real tokens -------------------------------

@pytest.fixture(scope="module")
def setup():
    cfg = smoke_config("paper-lm-100m")
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    prompts = rng.randint(0, cfg.vocab, size=(4, 12)).astype(np.int32)
    lens = np.array([7, 12, 9, 11], np.int32)
    golden = serve.generate(cfg, params, prompts, max_new=8,
                            prompt_lens=lens)
    return cfg, params, prompts, lens, golden


class TestFanInEngine:
    def test_uncontended_fanin_matches_batch_path(self, setup):
        cfg, params, prompts, lens, golden = setup
        out = serve.generate(cfg, params, prompts, max_new=8,
                             prompt_lens=lens, workers=2)
        assert (out == golden).all(), (out, golden)
        st_ = serve._generate_fanin.last_stats
        assert st_["admissions"] == 4 and st_["evictions"] == 0

    def test_replay_is_deterministic(self, setup):
        """Two identical runs produce identical tokens AND identical
        engine stats — the admission sequence replays exactly."""
        cfg, params, prompts, lens, _ = setup
        a = serve.generate(cfg, params, prompts, max_new=8,
                           prompt_lens=lens, workers=2, slots=2,
                           evict="oldest")
        sa = dict(serve._generate_fanin.last_stats)
        b = serve.generate(cfg, params, prompts, max_new=8,
                           prompt_lens=lens, workers=2, slots=2,
                           evict="oldest")
        sb = dict(serve._generate_fanin.last_stats)
        sa.pop("transfer_wait_s")          # the one wall-clock stat
        sb.pop("transfer_wait_s")
        assert (a == b).all() and sa == sb

    def test_worker_count_does_not_change_tokens(self, setup):
        cfg, params, prompts, lens, golden = setup
        out = serve.generate(cfg, params, prompts, max_new=8,
                             prompt_lens=lens, workers=3)
        assert (out == golden).all()

    def test_evicted_then_readmitted_matches_uncontended(self, setup):
        """The acceptance criterion: priority preemption on a 2-slot
        table — victims requeue with their emitted tokens, re-prefill on
        readmission, and the greedy continuation bit-matches the
        uncontended run."""
        cfg, params, prompts, lens, golden = setup
        out = serve.generate(cfg, params, prompts, max_new=8,
                             prompt_lens=lens, workers=2, slots=2,
                             evict="priority",
                             priorities=np.array([1, 1, 0, 0], np.int32))
        assert (out == golden).all(), (out, golden)
        st_ = serve._generate_fanin.last_stats
        assert st_["evictions"] > 0 and st_["requeues"] > 0

    def test_promotion_driven_oldest_eviction_matches(self, setup):
        """Same-class pressure on a starved table: eviction is justified
        only via hard promotion, and parity still holds."""
        cfg, params, prompts, lens, golden = setup
        out = serve.generate(cfg, params, prompts, max_new=8,
                             prompt_lens=lens, workers=2, slots=2,
                             evict="oldest")
        assert (out == golden).all(), (out, golden)

    def test_sampling_is_refused(self, setup):
        cfg, params, prompts, lens, _ = setup
        with pytest.raises(ValueError, match="greedy"):
            serve.generate(cfg, params, prompts, max_new=8,
                           prompt_lens=lens, workers=2, temperature=0.7)


class TestPagedEngine:
    @pytest.mark.parametrize("page_size", [0, 8])
    def test_paged_matches_unpaged(self, setup, page_size):
        cfg, params, prompts, lens, golden = setup
        out = serve.generate(cfg, params, prompts, max_new=8,
                             prompt_lens=lens, workers=2, paged=True,
                             page_size=page_size)
        assert (out == golden).all(), (out, golden)
        st_ = serve._generate_fanin.last_stats
        assert st_["page"] >= 1 and st_["peak_live_pages"] >= 1
        assert st_["hbm_bytes_per_slot"] \
            < st_["dense_hbm_bytes_per_slot"]

    def test_paged_eviction_quantized_storage_matches(self, setup):
        """Pages + preemption + int8-resident storage compose: the paged
        contended run bit-matches the unpaged uncontended fan-in under
        the same storage arm."""
        cfg, params, prompts, lens, _ = setup
        base = serve.generate(cfg, params, prompts, max_new=8,
                              prompt_lens=lens, workers=2,
                              kv_storage="int8")
        out = serve.generate(cfg, params, prompts, max_new=8,
                             prompt_lens=lens, workers=2, slots=2,
                             evict="priority", paged=True, page_size=8,
                             kv_storage="int8",
                             priorities=np.array([1, 1, 0, 0], np.int32))
        assert (out == base).all(), (out, base)
        assert serve._generate_fanin.last_stats["evictions"] > 0

    def test_long_request_refused_unpaged_admitted_paged(self, setup):
        """The bugfix, both arms: a request past the unpaged horizon is
        refused loudly (never silently truncated); --paged admits it and
        still matches the horizon-free run."""
        cfg, params, prompts, lens, golden = setup
        with pytest.raises(ValueError, match="refusing to truncate"):
            serve.generate(cfg, params, prompts, max_new=8,
                           prompt_lens=lens, workers=2, horizon=12)
        out = serve.generate(cfg, params, prompts, max_new=8,
                             prompt_lens=lens, workers=2, horizon=12,
                             paged=True, page_size=8)
        assert (out == golden).all(), (out, golden)

    def test_batch_path_refuses_silent_truncation_too(self, setup):
        cfg, params, prompts, lens, _ = setup
        with pytest.raises(ValueError, match="refusing"):
            serve.generate(cfg, params, prompts, max_new=8,
                           prompt_lens=lens, horizon=12)

    def test_pool_exhaustion_is_loud(self, setup):
        cfg, params, prompts, lens, _ = setup
        with pytest.raises(RuntimeError, match="paged pool exhausted"):
            serve.generate(cfg, params, prompts, max_new=8,
                           prompt_lens=lens, workers=2, paged=True,
                           page_size=4, pool_pages=6)

    def test_pool_too_small_for_one_row_refused_upfront(self, setup):
        cfg, params, prompts, lens, _ = setup
        with pytest.raises(ValueError, match="pool of 1 pages"):
            serve.generate(cfg, params, prompts, max_new=8,
                           prompt_lens=lens, workers=2, paged=True,
                           page_size=4, pool_pages=1)


class TestFanInReport:
    def test_report_is_deterministic(self):
        cfg = smoke_config("paper-lm-100m")
        r1 = serve.fanin_report(cfg, 8, 64, decode_step_s=0.01,
                                transfer_s=0.05)
        r2 = serve.fanin_report(cfg, 8, 64, decode_step_s=0.01,
                                transfer_s=0.05)
        assert r1 == r2

    def test_gated_keys_present_and_paged_saves_hbm(self):
        """paged_hbm_bytes_per_slot measurably below the dense
        pad-to-horizon rent — the saving the gate defends."""
        cfg = smoke_config("paper-lm-100m")
        rep = serve.fanin_report(cfg, 8, 64, decode_step_s=0.01,
                                 transfer_s=0.05)
        assert rep["fanin_admission_wait_s"] >= 0.0
        assert rep["fanin_evictions"] >= 0
        assert rep["paged_hbm_bytes_per_slot"] \
            < rep["slot_hbm_bytes_per_slot"]
        assert rep["page"] >= 1 and rep["skipped"] == {}

    def test_contention_produces_queue_wait(self):
        """slots = batch//2 is contention by construction: with a real
        transfer cost the mean admission wait is nonzero."""
        cfg = smoke_config("paper-lm-100m")
        rep = serve.fanin_report(cfg, 8, 64, decode_step_s=0.01,
                                 transfer_s=0.05)
        assert rep["slots"] == 4 and rep["fanin_admission_wait_s"] > 0.0

    def test_recurrent_family_skips_paged_leg(self):
        """No paged capability (recurrent state) => the paged keys are
        absent and the refusal lands under skipped, message intact."""
        cfg = smoke_config("xlstm-125m")
        rep = serve.fanin_report(cfg, 8, 64)
        assert "paged_hbm_bytes_per_slot" not in rep
        assert "--paged" in rep["skipped"]
        assert "paged" in rep["skipped"]["--paged"]
