"""Thin public facade over the model zoo."""

from __future__ import annotations

from repro.configs import ModelConfig, get_config, smoke_config  # noqa: F401
from repro.models.transformer import (  # noqa: F401
    abstract_cache,
    abstract_params,
    cache_axes,
    cache_struct,
    forward,
    init_cache,
    init_params,
    param_axes,
    param_specs,
)
