"""The OODA pipeline (Fig. 4): one configurable object wiring candidates ->
observe -> filters -> orient -> filters -> decide -> act -> feedback.

``run_cycle`` is deterministic given the catalog state (NFR2) and returns a
CycleReport with everything the benchmarks plot.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence

from repro.core import filters as filt
from repro.core.act import ActReport, Scheduler
from repro.core.decide import MoopRanker, select_budget, select_topk
from repro.core.model import Candidate, Scope, generate_candidates
from repro.core.observe import StatsCollector
from repro.core.orient import TraitContext, compute_traits
from repro.lst.catalog import Catalog


@dataclasses.dataclass
class CycleReport:
    n_candidates: int = 0
    n_after_filters: int = 0
    n_selected: int = 0
    selected_keys: List = dataclasses.field(default_factory=list)
    act: Optional[ActReport] = None
    wall_s: float = 0.0

    @property
    def files_removed(self) -> int:
        return self.act.files_removed if self.act else 0

    @property
    def gbhr(self) -> float:
        return self.act.gbhr if self.act else 0.0


class AutoCompPipeline:
    def __init__(self,
                 stats: StatsCollector,
                 traits: Sequence,
                 trait_ctx: TraitContext,
                 ranker: MoopRanker,
                 scheduler: Scheduler,
                 scope: Scope = Scope.TABLE,
                 hybrid: bool = False,
                 pre_filters: Sequence = (),
                 post_filters: Sequence = (),
                 top_k: Optional[int] = 10,
                 budget_gbhr: Optional[float] = None,
                 weights_fn: Optional[Callable[[Candidate], Dict[str, float]]] = None,
                 feedback_fn: Optional[Callable] = None) -> None:
        self.stats = stats
        self.traits = traits
        self.trait_ctx = trait_ctx
        self.ranker = ranker
        self.scheduler = scheduler
        self.scope = scope
        self.hybrid = hybrid
        self.pre_filters = list(pre_filters)
        self.post_filters = list(post_filters)
        self.top_k = top_k
        self.budget_gbhr = budget_gbhr
        self.weights_fn = weights_fn
        self.feedback_fn = feedback_fn

    # -- the four phases ------------------------------------------------------
    def run_cycle(self, catalog: Catalog,
                  tables: Optional[Sequence] = None) -> CycleReport:
        t0 = time.perf_counter()
        rep = CycleReport()

        # candidates + observe
        cands = generate_candidates(tables if tables is not None
                                    else catalog.tables(),
                                    self.scope, hybrid=self.hybrid)
        rep.n_candidates = len(cands)
        self.stats.observe_all(cands)
        cands = filt.apply_filters(cands, self.pre_filters)

        # orient
        compute_traits(cands, self.traits, self.trait_ctx)
        cands = filt.apply_filters(cands, self.post_filters)
        rep.n_after_filters = len(cands)

        # decide (per-candidate quota-adaptive weights if configured)
        if self.weights_fn is not None:
            # re-rank with per-candidate weights: score candidates under
            # their own namespace weights, then order globally
            from repro.core.decide import minmax_normalize
            names = list(self.ranker.weights)
            minmax_normalize(cands, names)
            for c in cands:
                w = self.weights_fn(c)
                c.score = sum(
                    (-wv if n in self.ranker.costs else wv)
                    * c.normalized.get(n, 0.0) for n, wv in w.items())
            ranked = sorted(cands, key=lambda c: (-c.score,) + c.key)
        else:
            ranked = self.ranker.rank(cands)

        if self.budget_gbhr is not None:
            selected = select_budget(ranked, self.budget_gbhr,
                                     max_k=self.top_k)
        else:
            selected = select_topk(ranked, self.top_k or len(ranked))
        rep.n_selected = len(selected)
        rep.selected_keys = [c.key for c in selected]

        # act
        rep.act = self.scheduler.execute(selected)

        # feedback loop -> observe (updated file counts / layout changes)
        if self.feedback_fn is not None and rep.act is not None:
            self.feedback_fn(rep)
        rep.wall_s = time.perf_counter() - t0
        return rep
