"""~100M-parameter dense LM used by the end-to-end example driver
(examples/train_e2e.py): real training on CPU for a few hundred steps with
the AutoComp-managed data pipeline.
"""
from repro.configs import ModelConfig

CONFIG = ModelConfig(
    name="paper-lm-100m",
    family="dense",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=4,
    d_ff=2048,
    vocab=32000,
    head_dim=64,
    tie_embeddings=True,
    rope_theta=1e4,
)
