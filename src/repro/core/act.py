"""Act phase (§4.4): schedule and execute selected compaction candidates.

Scheduling policies learned from the paper's deployment:
  * parallel across tables, sequential within a table (concurrent compaction
    of distinct partitions of one table conflicts under Iceberg v1.2);
  * optional off-peak window;
  * per-task retry with fresh snapshot basis on conflict;
  * can run on a dedicated "compaction cluster" (here: a worker pool
    decoupled from the training/query path).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence

from repro.core.model import Candidate, Scope
from repro.lst import compaction as comp
from repro.lst import retention as ret
from repro.lst.compaction import CompactionResult, CompactionTask


@dataclasses.dataclass
class ActReport:
    results: List[CompactionResult] = dataclasses.field(default_factory=list)
    # candidates selected by decide but NOT executed this call (e.g. the
    # off-peak window was closed) — reported so the caller can requeue them
    # next cycle instead of silently losing the selection
    deferred: List[Candidate] = dataclasses.field(default_factory=list)

    @property
    def files_removed(self) -> int:
        return sum(r.files_removed for r in self.results)

    @property
    def files_added(self) -> int:
        return sum(r.files_added for r in self.results)

    @property
    def bytes_rewritten(self) -> int:
        return sum(r.bytes_rewritten for r in self.results)

    @property
    def gbhr(self) -> float:
        return sum(r.gbhr for r in self.results)

    @property
    def rows_dropped(self) -> int:
        return sum(r.rows_dropped for r in self.results)

    @property
    def bytes_reclaimed(self) -> int:
        return sum(r.bytes_reclaimed for r in self.results)

    @property
    def conflicts(self) -> int:
        return sum(1 for r in self.results if r.conflict)

    @property
    def failures(self) -> int:
        return sum(1 for r in self.results if not r.success)


class Scheduler:
    def __init__(self, target_file_bytes: int,
                 merge_fn: Callable = comp.default_merge_fn,
                 executor_memory_gb: float = 8.0,
                 rewrite_bytes_per_hour: float = 256e9,
                 offpeak_window: Optional[Callable[[], bool]] = None,
                 max_retries: int = 2,
                 fail_fn: Optional[Callable] = None,
                 interleave_fn: Optional[Callable] = None,
                 fused_filter: bool = True) -> None:
        self.target = target_file_bytes
        self.merge_fn = merge_fn
        self.executor_memory_gb = executor_memory_gb
        self.rewrite_bytes_per_hour = rewrite_bytes_per_hour
        self.offpeak_window = offpeak_window
        self.max_retries = max_retries
        self.fail_fn = fail_fn
        self.interleave_fn = interleave_fn  # concurrent-writer injection
        self.fused_filter = fused_filter    # rewrite-delete kernel choice

    @staticmethod
    def _tasks_for(cand: Candidate,
                   table_tasks: List[CompactionTask]) -> List[CompactionTask]:
        """Dispatch one table plan's bins to a candidate by partition."""
        if cand.scope == Scope.PARTITION and cand.partition is not None:
            return [t for t in table_tasks
                    if (t.scope or "") == (cand.partition or "")]
        return table_tasks

    def plan(self, cand: Candidate) -> List[CompactionTask]:
        scope = "partition" if cand.scope == Scope.PARTITION else "table"
        tasks = comp.plan_table(cand.table, self.target, scope=scope)
        return self._tasks_for(cand, tasks)

    def _execute_delete(self, cand: Candidate) -> List[CompactionResult]:
        """Delete-candidate dispatch (see ``lst.retention``): tier-1 file
        drops commit one zero-byte metadata snapshot; tier-2 files are
        binned and rewritten through the ordinary single-task commit path
        with the op's keep-mask filter attached (fused filter+pack by
        default, the two-pass reference with ``fused_filter=False``)."""
        route = cand.delete_route
        results: List[CompactionResult] = []
        if route.file_drops:
            results.append(ret.execute_file_drops(
                cand.table, route.file_drops, max_retries=self.max_retries,
                interleave_fn=self.interleave_fn))
        if route.rewrite_files:
            keep = route.op.filter_fn()
            for task in ret.plan_rewrite_delete(cand.table,
                                                route.rewrite_files,
                                                self.target):
                results.append(comp.execute_task(
                    cand.table, task, merge_fn=self.merge_fn,
                    max_retries=self.max_retries,
                    executor_memory_gb=self.executor_memory_gb,
                    rewrite_bytes_per_hour=self.rewrite_bytes_per_hour,
                    fail_fn=self.fail_fn, interleave_fn=self.interleave_fn,
                    filter_fn=keep, fused_filter=self.fused_filter))
        return results

    def execute(self, selected: Sequence[Candidate]) -> ActReport:
        """Tables are independent units (parallelizable); within a table,
        tasks run sequentially to avoid LST conflicts (§4.4/§6).

        Planning is linear in the candidate count: each table is
        bin-packed ONCE per ``execute`` call and the resulting bins are
        dispatched to partition-scope candidates by partition (execution
        never crosses partitions, so compacting one partition leaves every
        other partition's bins valid). The old path re-ran
        ``comp.plan_table`` over the whole table for every partition
        candidate and filtered — O(P^2) bins planned for P partitions.
        Before executing a candidate, its dispatched bins are checked
        against CURRENT file liveness: if any bin references a file no
        longer live — consumed by an earlier candidate in this call, or
        deleted by a concurrent writer since planning — the table is
        replanned instead of executing the stale bin (which would merge a
        logically-deleted file's rows into the compacted output). The
        common case (distinct partitions, no concurrent deletes) still
        plans once: a liveness set-check per candidate, not a bin-pack.
        """
        report = ActReport()
        if self.offpeak_window is not None and not self.offpeak_window():
            report.deferred = list(selected)
            return report
        by_table: Dict[str, List[Candidate]] = {}
        for c in selected:
            by_table.setdefault(c.table.table_id, []).append(c)
        for table_id in sorted(by_table):
            table_tasks: Optional[List[CompactionTask]] = None
            for cand in by_table[table_id]:
                if cand.delete_route is not None:
                    results = self._execute_delete(cand)
                    cand.delete_results = results  # type: ignore[attr-defined]
                    report.results.extend(results)
                    table_tasks = None   # table changed: replan later bins
                    continue
                tasks: List[CompactionTask] = []
                if table_tasks is not None:
                    tasks = self._tasks_for(cand, table_tasks)
                    live = {f.path for f in cand.table.current_files()}
                    if any(f.path not in live
                           for t in tasks for f in t.inputs):
                        table_tasks = None      # stale plan: files died
                if table_tasks is None:
                    table_tasks = comp.plan_table(cand.table, self.target)
                    tasks = self._tasks_for(cand, table_tasks)
                if cand.scope != Scope.PARTITION:
                    # table scope: one commit for the whole rewrite job
                    res = comp.execute_tasks_atomic(
                        cand.table, tasks, merge_fn=self.merge_fn,
                        max_retries=self.max_retries,
                        executor_memory_gb=self.executor_memory_gb,
                        rewrite_bytes_per_hour=self.rewrite_bytes_per_hour,
                        interleave_fn=self.interleave_fn)
                    report.results.append(res)
                    table_tasks = None   # table-scope rewrite: replan
                    continue
                for task in tasks:      # partition scope: per-partition commit
                    res = comp.execute_task(
                        cand.table, task, merge_fn=self.merge_fn,
                        max_retries=self.max_retries,
                        executor_memory_gb=self.executor_memory_gb,
                        rewrite_bytes_per_hour=self.rewrite_bytes_per_hour,
                        fail_fn=self.fail_fn,
                        interleave_fn=self.interleave_fn)
                    report.results.append(res)
        return report
