"""Data layer: shard round-trips (hypothesis), packing, pipeline
determinism, and the central invariant — compaction NEVER changes the token
multiset the training job reads."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.data import (DataPipeline, TokenShardWriter, decode_shard,
                        encode_shard, merge_shards_fn, pack_tokens)
from repro.data.shards import decode_shard_padded
from repro.kernels.compact_pack.compact_pack import CHUNK_TOKENS
from repro.lst import Catalog, InMemoryStore
from repro.lst import compaction as comp
from repro.lst.workload import SimClock


def make_table(seed=0):
    clock = SimClock()
    store = InMemoryStore()
    cat = Catalog(store, now_fn=clock.now)
    t = cat.create_table("train", "corpus",
                         properties={"conflict_granularity": "table"})
    t.now_fn = clock.now
    return cat, t, store


class TestShardFormat:
    @given(st.integers(min_value=0, max_value=5000))
    @settings(max_examples=30, deadline=None)
    def test_encode_decode_roundtrip(self, n):
        rng = np.random.RandomState(1)
        toks = rng.randint(0, 1 << 20, size=n).astype(np.int32)
        raw = encode_shard(toks)
        out = decode_shard(raw)
        assert np.array_equal(out, toks)
        padded = decode_shard_padded(raw)
        assert padded.shape[0] % CHUNK_TOKENS == 0
        assert padded.shape[0] >= n

    def test_pack_tokens_shapes_and_labels(self):
        stream = np.arange(4 * 3 * 9 + 5, dtype=np.int32)
        slabs = pack_tokens(stream, batch=3, seq_len=8)
        assert slabs.shape == (4, 3, 9)
        # labels are next-token shifted views of the same stream
        assert np.array_equal(slabs[0, 0, 1:], stream[1:9])


class TestCompactionPreservesData:
    @pytest.mark.parametrize("tokens_per_file", [100, 1024, 3000])
    def test_token_multiset_preserved(self, tokens_per_file):
        _, table, _ = make_table()
        w = TokenShardWriter(table, vocab=997, seed=3)
        for _ in range(5):
            w.trickle_append(n_files=8, tokens_per_file=tokens_per_file)
        pipe = DataPipeline(table, batch=2, seq_len=64)
        before = np.sort(np.concatenate(
            [b["tokens"].ravel() for b in pipe.batches()]))
        for t in comp.plan_table(table, target_bytes=1 << 20):
            r = comp.execute_task(table, t, merge_fn=merge_shards_fn)
            assert r.success, r.error
        assert table.file_count() < 40
        pipe2 = DataPipeline(table, batch=2, seq_len=64)
        after = np.sort(np.concatenate(
            [b["tokens"].ravel() for b in pipe2.batches()]))
        assert np.array_equal(before, after)

    def test_num_rows_preserved_exactly(self):
        _, table, _ = make_table()
        w = TokenShardWriter(table, vocab=100, seed=4)
        w.trickle_append(n_files=6, tokens_per_file=777)
        rows_before = sum(f.num_rows for f in table.current_files())
        for t in comp.plan_table(table, target_bytes=1 << 22):
            assert comp.execute_task(table, t, merge_fn=merge_shards_fn).success
        rows_after = sum(f.num_rows for f in table.current_files())
        assert rows_before == rows_after


class TestRewriteDeletes:
    """Rewrite-deletes-as-compaction through the real execute path: a
    filter_fn on execute_task routes the merge through the fused
    filter+pack kernel; fused and reference paths must commit identical
    tables and identical rows_dropped accounting."""

    @staticmethod
    def _drop_even(rows, task):
        return (rows[:, 0] % 2).astype(bool)    # keep odd-leading rows

    def _run(self, fused):
        _, table, store = make_table()
        w = TokenShardWriter(table, vocab=997, seed=3)
        for _ in range(3):
            w.trickle_append(n_files=6, tokens_per_file=3000)
        results = [comp.execute_task(table, t, merge_fn=merge_shards_fn,
                                     filter_fn=self._drop_even,
                                     fused_filter=fused)
                   for t in comp.plan_table(table, target_bytes=1 << 20)]
        assert results and all(r.success for r in results)
        toks = sorted((decode_shard(store.get(f.path))
                       for f in table.current_files()),
                      key=lambda a: (a.shape[0], tuple(a[:8])))
        return sum(r.rows_dropped for r in results), toks

    def test_fused_and_reference_commit_identical_tables(self):
        dropped_fused, toks_fused = self._run(fused=True)
        dropped_ref, toks_ref = self._run(fused=False)
        assert dropped_fused == dropped_ref > 0
        assert len(toks_fused) == len(toks_ref)
        assert all(np.array_equal(a, b)
                   for a, b in zip(toks_fused, toks_ref))
        # the filter held: every surviving 128-token row leads odd
        for t in toks_fused:
            assert (t.reshape(-1, 128)[:, 0] % 2 == 1).all()

    def test_unfiltered_rewrite_reports_zero_dropped(self):
        _, table, _ = make_table()
        w = TokenShardWriter(table, vocab=100, seed=4)
        w.trickle_append(n_files=6, tokens_per_file=777)
        for t in comp.plan_table(table, target_bytes=1 << 22):
            r = comp.execute_task(table, t, merge_fn=merge_shards_fn)
            assert r.success and r.rows_dropped == 0

    def test_drop_everything_yields_empty_shard(self):
        _, table, store = make_table()
        w = TokenShardWriter(table, vocab=100, seed=5)
        w.trickle_append(n_files=4, tokens_per_file=900)
        tasks = comp.plan_table(table, target_bytes=1 << 22)
        res = [comp.execute_task(
            table, t, merge_fn=merge_shards_fn,
            filter_fn=lambda rows, task: np.zeros(rows.shape[0], bool))
            for t in tasks]
        assert all(r.success for r in res)
        assert sum(r.rows_dropped for r in res) > 0
        for f in table.current_files():
            assert decode_shard(store.get(f.path)).shape[0] == 0


class TestPipeline:
    def test_batches_deterministic_by_seed(self):
        _, table, _ = make_table()
        w = TokenShardWriter(table, vocab=500, seed=5)
        w.trickle_append(n_files=10, tokens_per_file=2000)
        a = [b["tokens"] for b in DataPipeline(table, 2, 64, seed=1).batches()]
        b = [b["tokens"] for b in DataPipeline(table, 2, 64, seed=1).batches()]
        assert all(np.array_equal(x, y) for x, y in zip(a, b))

    def test_prefetch_yields_same_batches(self):
        _, table, _ = make_table()
        w = TokenShardWriter(table, vocab=500, seed=6)
        w.trickle_append(n_files=6, tokens_per_file=2000)
        plain = [b["tokens"] for b in DataPipeline(table, 2, 64, seed=2).batches()]
        pre = [b["tokens"] for b in
               DataPipeline(table, 2, 64, seed=2).prefetching_batches()]
        assert len(plain) == len(pre)
        assert all(np.array_equal(x, y) for x, y in zip(plain, pre))

    def test_plan_cost_scales_with_file_count(self):
        _, table, store = make_table()
        w = TokenShardWriter(table, vocab=100, seed=7)
        w.trickle_append(n_files=50, tokens_per_file=200)
        pipe = DataPipeline(table, 2, 16)
        open_before = store.metrics.open_calls
        list(pipe.batches())
        opens_fragmented = store.metrics.open_calls - open_before
        for t in comp.plan_table(table, target_bytes=1 << 22):
            comp.execute_task(table, t, merge_fn=merge_shards_fn)
        open_before = store.metrics.open_calls
        list(DataPipeline(table, 2, 16).batches())
        opens_compacted = store.metrics.open_calls - open_before
        assert opens_compacted < opens_fragmented
