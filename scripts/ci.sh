#!/usr/bin/env bash
# Tier-1 gate: install dev deps and run the full suite. A collection error
# anywhere (e.g. a module importing a package that does not exist) fails
# this script, so seed-style breakage can never land again.
#
# SKIP_INSTALL=1 skips the pip step — the CI jobs set it after the shared
# install step so the suite isn't preceded by a redundant re-install on
# every invocation.
set -euo pipefail
cd "$(dirname "$0")/.."

if [[ "${SKIP_INSTALL:-0}" != "1" ]]; then
    python -m pip install -r requirements-dev.txt
fi

# docs lint: every src/repro/* package has a README and every relative
# markdown link in the doc spine resolves
python scripts/check_docs.py

# --durations=15 keeps slow-test creep visible in every CI log
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q --durations=15 "$@"
