from repro.kernels.expert_a2a.ops import expert_a2a  # noqa
