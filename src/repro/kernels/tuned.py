"""Persisted tuned-point cache for the kernel registry.

One JSON file (default ``experiments/tuned/kernel_points.json``, override
via ``REPRO_TUNED_DIR``) maps ``"<op>|<shape_key>"`` to the winning block
point of a ``repro.kernels.tune`` sweep::

    {"version": 1,
     "points": {
       "flash_attn|b1h4kv2s1024d64:bf16": {
         "device_kind": "cpu",
         "point": {"block_q": 256, "block_k": 512},
         "objective_us": 1834.2,
         "evaluations": 16}}}

Lookups happen at op-call time (``api.resolve_point``), so they must be
cheap and never wrong-device: the file is memoized per (path, mtime), and
an entry only hits when its recorded ``device_kind`` matches the running
device — a cache written on a TPU host is a clean miss on CPU (and vice
versa), falling back to the deterministic default point rather than
serving a foreign machine's blocks.
"""

from __future__ import annotations

import json
import os
import pathlib
from typing import Any, Dict, Optional

import jax

CACHE_VERSION = 1
_FILENAME = "kernel_points.json"

# memoized payload keyed by (path, mtime_ns) so per-op-call lookups cost
# one stat(), not a JSON parse
_memo: Dict[str, Any] = {"key": None, "points": {}}


def device_kind() -> str:
    return jax.devices()[0].device_kind


def cache_dir() -> pathlib.Path:
    env = os.environ.get("REPRO_TUNED_DIR")
    if env:
        return pathlib.Path(env)
    # src/repro/kernels/tuned.py -> repo root / experiments / tuned
    return pathlib.Path(__file__).resolve().parents[3] / "experiments" / "tuned"


def cache_path() -> pathlib.Path:
    return cache_dir() / _FILENAME


def invalidate_memo() -> None:
    _memo["key"] = None
    _memo["points"] = {}


def _load_points() -> Dict[str, Any]:
    path = cache_path()
    try:
        mtime = os.stat(path).st_mtime_ns
    except OSError:
        return {}
    key = (str(path), mtime)
    if _memo["key"] == key:
        return _memo["points"]
    try:
        with open(path) as f:
            payload = json.load(f)
        points = payload.get("points") if isinstance(payload, dict) else None
        points = points if isinstance(points, dict) else {}
    except (OSError, ValueError):
        points = {}
    _memo["key"] = key
    _memo["points"] = points
    return points


def entry_key(op_name: str, shape_key: str) -> str:
    return f"{op_name}|{shape_key}"


def lookup(op_name: str, shape_key: str) -> Optional[Dict[str, Any]]:
    """Tuned point for (op, shape) on THIS device kind, else None."""
    entry = _load_points().get(entry_key(op_name, shape_key))
    if not isinstance(entry, dict):
        return None
    if entry.get("device_kind") != device_kind():
        return None                     # stale-device-kind miss
    point = entry.get("point")
    return dict(point) if isinstance(point, dict) else None


def entry(op_name: str, shape_key: str) -> Optional[Dict[str, Any]]:
    """Full cache record (point + objective + evaluations) regardless of
    device kind — for artifact reporting, not dispatch."""
    e = _load_points().get(entry_key(op_name, shape_key))
    return dict(e) if isinstance(e, dict) else None


def store(op_name: str, shape_key: str, point: Dict[str, Any],
          objective_us: float, evaluations: int) -> pathlib.Path:
    """Write-through one tuned point (read-modify-write the JSON)."""
    path = cache_path()
    path.parent.mkdir(parents=True, exist_ok=True)
    points = dict(_load_points())
    points[entry_key(op_name, shape_key)] = {
        "device_kind": device_kind(),
        "point": dict(point),
        "objective_us": float(objective_us),
        "evaluations": int(evaluations),
    }
    with open(path, "w") as f:
        json.dump({"version": CACHE_VERSION, "points": points}, f, indent=1,
                  sort_keys=True)
    invalidate_memo()
    return path
