"""Tunable-op registry: one surface for every Pallas kernel family.

Each kernel subpackage used to carry its own copy of the same plumbing —
a ``_use_interpret()`` backend probe, a ``use_ref=`` escape hatch, and
hard-coded block-size defaults. This module replaces those four divergent
entry points with one registry: an op declares

  * its tunable axes (name -> ordered candidate values) and the
    deterministic default point (the pre-registry hard-coded blocks),
  * its kernel path (``run(point, *args, **kw)``) and pure-jnp ref impl,
  * a ``clamp`` rule that fits any tuned/passed point to the actual
    operand extents (a point cached from a long shape must not fail or
    mis-grid on a shorter one),
  * a ``shape_key`` that names the (shape, dtype) cell a tuned point is
    cached under, and
  * representative ``example`` shapes the sweep harness tunes on.

``call(name, ...)`` is the single dispatch: resolve the point (explicit
override > persisted tuned cache (repro.kernels.tuned) > default), clamp
it, run. ``core.autotune.tune_design`` sweeps any registered op
generically through ``repro.kernels.tune``; new kernels (paged-slot
cache, expert all-to-all) register here instead of re-plumbing.

``exact_axes`` names the axes along which the op's output is provably
invariant bit-for-bit (pure data movement, or tiling that never regroups
a reduction): the property suite pins those, and tolerates only fp
reassociation on the rest (e.g. flash's ``block_k`` splits the online
softmax differently).
"""

from __future__ import annotations

import dataclasses
import importlib
import math
from typing import Any, Callable, Dict, Mapping, Optional, Sequence, Tuple

import jax


def use_interpret() -> bool:
    """Interpret-mode rule shared by every registered op (was copy-pasted
    per kernel subpackage): Pallas interprets on non-TPU backends."""
    return jax.default_backend() != "tpu"


def fit_block(value: int, extent: int) -> int:
    """Clamp a block size to an operand extent, keeping divisibility.

    Every kernel grid requires ``extent % block == 0``. A tuned point
    cached from a long shape (say block 512 from seq 4096) applied to a
    shorter one must degrade deterministically, never assert: clamp to
    the extent, and if the clamped value does not divide it, fall back to
    gcd(value, extent) — always a divisor, always <= value.
    """
    if extent <= 0:
        return max(1, value)
    v = min(int(value), extent)
    if v <= 0:
        v = 1
    if extent % v == 0:
        return v
    return math.gcd(v, extent)


@dataclasses.dataclass(frozen=True)
class TunableOp:
    """One registered kernel family and everything the sweep needs."""
    name: str
    axes: Mapping[str, Tuple]            # axis -> ordered candidate values
    default: Mapping[str, Any]           # the pre-registry hard-coded point
    run: Callable                        # run(point, *args, **kw) -> out
    ref: Callable                        # ref(*args, **kw) -> out
    clamp: Callable                      # clamp(point, *args, **kw) -> point
    shape_key: Callable                  # shape_key(*args, **kw) -> str
    example: Callable                    # example(quick: bool) -> (args, kw)
    exact_axes: frozenset = frozenset()  # axes that provably keep bits
    tol: float = 0.0                     # |kernel - ref| bound (0 = exact)


_REGISTRY: Dict[str, TunableOp] = {}

# ops.py modules that register the built-in kernel families on import;
# imported lazily so `repro.kernels.api` never cycles with the packages
# that import it.
_BUILTIN_OPS = (
    "repro.kernels.compact_pack.ops",
    "repro.kernels.flash_attn.ops",
    "repro.kernels.decode_attn.ops",
    "repro.kernels.paged_attn.ops",
    "repro.kernels.rmsnorm.ops",
    "repro.kernels.expert_a2a.ops",
)


def register(op: TunableOp) -> TunableOp:
    for axis in op.default:
        if axis not in op.axes:
            raise ValueError(f"{op.name}: default names unknown axis {axis!r}")
    for axis, vals in op.axes.items():
        if axis not in op.default:
            raise ValueError(f"{op.name}: axis {axis!r} has no default")
        if op.default[axis] not in vals:
            raise ValueError(f"{op.name}: default {op.default[axis]!r} not "
                             f"among candidates for axis {axis!r}")
    _REGISTRY[op.name] = op
    return op


def ensure_registered() -> None:
    for mod in _BUILTIN_OPS:
        importlib.import_module(mod)


def get_op(name: str) -> TunableOp:
    if name not in _REGISTRY:
        ensure_registered()
    return _REGISTRY[name]


def ops() -> Dict[str, TunableOp]:
    ensure_registered()
    return dict(_REGISTRY)


def default_point(op: TunableOp) -> Dict[str, Any]:
    return dict(op.default)


def resolve_point(op: TunableOp, *args, **kwargs) -> Dict[str, Any]:
    """Tuned-cache lookup at op-call time, deterministic default fallback.

    Cache entries are keyed (op, shape_key, device_kind); a miss — no
    file, unknown shape, stale device kind, corrupt JSON — silently
    yields the default point, so serving never depends on a sweep having
    run. Unknown axes in a cached point (an older/newer schema) are
    dropped rather than trusted.
    """
    from repro.kernels import tuned  # local: keep api import-light

    point = default_point(op)
    cached = tuned.lookup(op.name, op.shape_key(*args, **kwargs))
    if cached:
        for axis in op.axes:
            if axis in cached:
                point[axis] = cached[axis]
    return point


def call(name: str, *args, point: Optional[Mapping[str, Any]] = None,
         use_ref: bool = False, **kwargs):
    """Dispatch one op: explicit point > tuned cache > default, clamped."""
    op = get_op(name)
    if use_ref:
        return op.ref(*args, **kwargs)
    if point is None:
        point = resolve_point(op, *args, **kwargs)
    else:
        merged = default_point(op)
        merged.update({a: v for a, v in point.items() if a in op.axes})
        point = merged
    point = op.clamp(dict(point), *args, **kwargs)
    return op.run(point, *args, **kwargs)


def clamped_axes(op: TunableOp, *args, **kwargs) -> Dict[str, Tuple]:
    """The op's candidate values after clamping to these operands, deduped
    in candidate order — the space ``tune_design`` actually sweeps (a
    short shape collapses oversized candidates onto the extent instead of
    wasting evaluations on aliases)."""
    out: Dict[str, Tuple] = {}
    base = default_point(op)
    for axis, vals in op.axes.items():
        seen = []
        for v in vals:
            c = op.clamp({**base, axis: v}, *args, **kwargs)[axis]
            if c not in seen:
                seen.append(c)
        out[axis] = tuple(seen)
    return out
