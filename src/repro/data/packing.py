"""Sequence packing + the compaction merge_fn for token shards.

``merge_shards_fn`` is what AutoComp's Act phase calls when the candidate is
a token-shard table: it concatenates the chunk-aligned payloads of the input
shards and runs the compact_pack Pallas kernel to produce the merged shard —
the measured RewriteBytesPerHour of this path calibrates the GBHr cost trait.
"""

from __future__ import annotations

from typing import List, Sequence

import jax.numpy as jnp
import numpy as np

from repro.data import shards as sh
from repro.kernels.compact_pack import compact_chunks, plan_compaction
from repro.kernels.compact_pack.compact_pack import CHUNK_TOKENS
from repro.lst.compaction import CompactionTask
from repro.lst.files import DataFile
from repro.lst.table import LogStructuredTable


def pack_tokens(stream: np.ndarray, batch: int, seq_len: int) -> np.ndarray:
    """Pack a flat token stream into (n_batches, batch, seq_len+1) slabs
    (the +1 provides next-token labels)."""
    per = batch * (seq_len + 1)
    n = stream.shape[0] // per
    return stream[: n * per].reshape(n, batch, seq_len + 1)


def merge_shards_fn(table: LogStructuredTable, task: CompactionTask,
                    out_path: str) -> DataFile:
    """Compaction merge for token shards (kernel-backed)."""
    payloads = []
    lengths = []
    for f in task.inputs:
        raw = table.store.get(f.path)
        payloads.append(sh.decode_shard_padded(raw))
        lengths.append(len(sh.decode_shard(raw)))
    flat = np.concatenate(payloads) if payloads else np.zeros(0, np.int32)
    counts = [p.shape[0] // CHUNK_TOKENS for p in payloads]
    chunk_map = plan_compaction(counts)
    merged = np.asarray(compact_chunks(jnp.asarray(flat), chunk_map))
    # re-encode with the true concatenated length (drop inter-shard padding
    # bookkeeping: lengths are tracked per fragment)
    tokens = np.concatenate([
        merged[sum(c * CHUNK_TOKENS for c in counts[:i]):][:lengths[i]]
        for i in range(len(counts))]) if counts else merged[:0]
    raw = sh.encode_shard(tokens)
    table.store.put(out_path, raw)
    return DataFile(path=out_path, size_bytes=len(raw),
                    num_rows=int(tokens.shape[0]), partition=task.scope,
                    created_at=table.now_fn())
