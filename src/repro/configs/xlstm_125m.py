"""xLSTM-125M [arXiv:2405.04517; ssm — sLSTM + mLSTM blocks].

12L d_model=768 4H vocab=50304, d_ff=0 (blocks carry their own projections).
Every `mlstm_every`-th block is an mLSTM (matrix memory, chunkwise-parallel
training form); the rest are sLSTM (scalar memory, recurrent scan). Recurrent
state is O(1) per token => long_500k decode runs.
"""
from repro.configs import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm_xlstm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    head_dim=192,
    mlstm_every=2,
    causal=True,
)
