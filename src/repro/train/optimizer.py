"""Sharded AdamW with fp32 moments over (possibly bf16) parameters.

The moments inherit each parameter's logical axes, so optimizer state is
sharded exactly like the parameters (ZeRO-style when FSDP rules are active).
Cross-pod gradient "compression" falls out of the dtype split: gradients
cross the network in bf16 (reduce-scatter/all-reduce), while Adam runs in
fp32 on the local shard. The explicit int8+error-feedback transport
(``grad_transport="int8_ef"`` in ``make_train_step``) carries its per-leaf
residual in this state under the ``"ef"`` key — ``init_state`` /
``abstract_state`` / ``state_axes`` grow it when ``error_feedback=True``,
and ``apply_updates`` passes it through untouched (the train step owns it).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000


def lr_schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def _ef_shape(p, ef_devices: Optional[int]) -> Tuple[int, ...]:
    # the shard_map data-parallel transport carries one residual per device
    # (each device's quantization error differs); the SPMD path carries a
    # single parameter-shaped residual.
    return tuple(p.shape) if ef_devices is None \
        else (ef_devices,) + tuple(p.shape)


def init_state(params, error_feedback: bool = False,
               ef_devices: Optional[int] = None) -> Dict[str, Any]:
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    state = {"mu": jax.tree.map(f32, params),
             "nu": jax.tree.map(f32, params),
             "step": jnp.zeros((), jnp.int32)}
    if error_feedback:
        state["ef"] = jax.tree.map(
            lambda p: jnp.zeros(_ef_shape(p, ef_devices), jnp.float32), params)
    return state


def abstract_state(abstract_params, error_feedback: bool = False,
                   ef_devices: Optional[int] = None) -> Dict[str, Any]:
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    state = {"mu": jax.tree.map(f32, abstract_params),
             "nu": jax.tree.map(f32, abstract_params),
             "step": jax.ShapeDtypeStruct((), jnp.int32)}
    if error_feedback:
        state["ef"] = jax.tree.map(
            lambda p: jax.ShapeDtypeStruct(_ef_shape(p, ef_devices),
                                           jnp.float32), abstract_params)
    return state


def state_axes(param_axes_tree, error_feedback: bool = False
               ) -> Dict[str, Any]:
    axes = {"mu": param_axes_tree, "nu": param_axes_tree, "step": ()}
    if error_feedback:
        axes["ef"] = param_axes_tree   # residual sharded exactly like params
    return axes


def global_norm(tree) -> jnp.ndarray:
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def apply_updates(cfg: AdamWConfig, params, grads, state
                  ) -> Tuple[Any, Dict[str, Any], Dict[str, jnp.ndarray]]:
    """One AdamW step. grads may be bf16 (network dtype); math is fp32."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = lr_schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g)
        mhat = mu / b1c
        nhat = nu / b2c
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps) \
            + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    out = [upd(p, g, mu, nu) for p, g, mu, nu
           in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    # extra entries (e.g. the "ef" transport residual) ride through untouched
    new_state = dict(state)
    new_state.update({"mu": new_mu, "nu": new_nu, "step": step})
    return new_p, new_state, metrics
