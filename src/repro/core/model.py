"""Data model shared by the OODA phases (§3.3, §4.1)."""

from __future__ import annotations

import dataclasses
import enum
from typing import Any, Dict, Optional, Tuple

from repro.lst.files import DataFile
from repro.lst.table import LogStructuredTable


class Scope(enum.Enum):
    TABLE = "table"
    PARTITION = "partition"
    SNAPSHOT = "snapshot"


@dataclasses.dataclass
class CandidateStats:
    """Output of the observe phase: generic statistics (§4.1) + custom."""
    file_count: int
    total_bytes: int
    small_file_count: int
    small_bytes: int
    size_histogram: Tuple[int, ...]          # counts per power-of-two bucket
    partition_count: int
    created_at: float
    last_write_at: float
    custom: Dict[str, Any] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class Candidate:
    """A collection of files to be compacted (§4.1): table, partition, or
    snapshot scoped. A candidate carrying a ``delete_route`` is a DELETE
    entering the pool (see ``core.retention``): its act dispatch drops/
    rewrites the routed files instead of bin-packing the scope."""
    table: LogStructuredTable
    scope: Scope
    partition: Optional[str] = None
    snapshot_id: Optional[int] = None
    stats: Optional[CandidateStats] = None
    traits: Dict[str, float] = dataclasses.field(default_factory=dict)
    normalized: Dict[str, float] = dataclasses.field(default_factory=dict)
    score: float = 0.0
    delete_route: Optional[Any] = None   # lst.retention.DeleteRoute

    @property
    def key(self) -> Tuple[str, str, str, str]:
        op = self.delete_route.op if self.delete_route is not None else None
        return (self.table.table_id, self.scope.value, self.partition or "",
                getattr(op, "name", ""))

    def files(self) -> Tuple[DataFile, ...]:
        files = self.table.current_files(self.snapshot_id)
        if self.scope == Scope.PARTITION and self.partition is not None:
            return tuple(f for f in files
                         if (f.partition or "") == self.partition)
        return files


def generate_candidates(tables, scope: Scope = Scope.TABLE,
                        hybrid: bool = False):
    """Candidate generation. ``hybrid``: partition scope for partitioned
    tables, table scope otherwise (the §6 'hybrid' strategy)."""
    out = []
    for t in tables:
        if hybrid:
            use = Scope.PARTITION if t.meta.partition_spec else Scope.TABLE
        else:
            use = scope
        if use == Scope.PARTITION and t.meta.partition_spec:
            for p in t.partitions():
                out.append(Candidate(t, Scope.PARTITION, partition=p))
        else:
            out.append(Candidate(t, Scope.TABLE))
    return out
