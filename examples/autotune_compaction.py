"""Auto-tuning compaction triggers (§6.3 / Fig. 9): tune the small-file-count
threshold of an optimize-after-write trigger against end-to-end workload
duration, for two workload profiles (write-heavy vs read-heavy). Shows the
paper's "one size does not fit all" conclusion: the best threshold differs
per workload, and for write-dominated workloads compaction can be a net
loss.

Run:  PYTHONPATH=src python examples/autotune_compaction.py
"""

import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, _ROOT)
sys.path.insert(0, os.path.join(_ROOT, "src"))

from benchmarks.workload_sim import run_sim  # reuse the bench harness
from repro.core.autotune import tune_threshold


def main():
    for profile in ("read_heavy", "write_heavy"):
        def objective(threshold: float) -> float:
            return run_sim(strategy="table-10", profile=profile,
                           trigger="small_files", threshold=threshold,
                           hours=3, seed=3)["duration_s"]

        res = tune_threshold(objective, lo=50, hi=2000, coarse=4,
                             refine_rounds=2)
        print(f"[{profile}] best threshold={res.best_threshold:.0f} "
              f"duration={res.best_objective:.2f}s "
              f"({res.iterations} evaluations)")
        for thr, dur in res.history:
            print(f"    thr={thr:6.1f} -> {dur:7.2f}s")


if __name__ == "__main__":
    main()
