"""The docs lint that tier-1 CI runs (scripts/check_docs.py): package
README presence, relative-link resolution, launcher-flag coverage of
the serving operator's guide, gated-metric doc coverage, and the real
repo passing all four."""

import importlib.util
import os

_SPEC = importlib.util.spec_from_file_location(
    "check_docs",
    os.path.join(os.path.dirname(__file__), "..", "scripts",
                 "check_docs.py"))
check_docs = importlib.util.module_from_spec(_SPEC)
_SPEC.loader.exec_module(check_docs)


def _mk_repo(tmp_path, readme_for=("good",), links=""):
    src = tmp_path / "src" / "repro"
    for name in ("good", "bare"):
        pkg = src / name
        pkg.mkdir(parents=True)
        (pkg / "__init__.py").write_text("")
        if name in readme_for:
            body = links if name == "good" else ""
            (pkg / "README.md").write_text(f"# {name}\n{body}")
    # a plain directory (no __init__.py) is NOT a package: no README owed
    (src / "scriptsdir").mkdir()
    return tmp_path


class TestCheckDocs:
    def test_missing_package_readme_reported(self, tmp_path):
        root = _mk_repo(tmp_path, readme_for=("good",))
        missing = check_docs.missing_readmes(root)
        assert len(missing) == 1 and "bare" in missing[0]

    def test_non_package_dir_owes_nothing(self, tmp_path):
        root = _mk_repo(tmp_path, readme_for=("good", "bare"))
        assert check_docs.missing_readmes(root) == []

    def test_broken_relative_link_reported(self, tmp_path):
        root = _mk_repo(tmp_path, readme_for=("good", "bare"),
                        links="see [other](../nowhere/README.md)")
        broken = check_docs.broken_links(root)
        assert len(broken) == 1 and "nowhere" in broken[0]

    def test_resolving_links_and_anchors_pass(self, tmp_path):
        root = _mk_repo(
            tmp_path, readme_for=("good", "bare"),
            links="[peer](../bare/README.md#section) "
                  "[web](https://example.com) [anchor](#local)")
        assert check_docs.broken_links(root) == []

    def test_this_repo_is_clean(self):
        root = check_docs.repo_root()
        assert check_docs.missing_readmes(root) == []
        assert check_docs.broken_links(root) == []
        assert check_docs.missing_flag_docs(root) == []
        assert check_docs.missing_metric_docs(root) == []
        # the spine the ISSUE demands actually exists
        assert (root / "README.md").exists()
        assert (root / "src" / "repro" / "lst" / "README.md").exists()
        assert (root / "docs" / "serving.md").exists()


def _mk_launcher_repo(tmp_path, flags=("--batch",), doc_text=None):
    launch = tmp_path / "src" / "repro" / "launch"
    launch.mkdir(parents=True)
    lines = "".join(f'    ap.add_argument("{f}", type=int)\n'
                    for f in flags)
    (launch / "serve.py").write_text(f"def build_parser(ap):\n{lines}")
    if doc_text is not None:
        (tmp_path / "docs").mkdir()
        (tmp_path / "docs" / "serving.md").write_text(doc_text)
    return tmp_path


class TestFlagCoverage:
    def test_extracted_flags_are_sorted_and_deduped(self, tmp_path):
        root = _mk_launcher_repo(
            tmp_path, flags=("--zeta", "--alpha", "--alpha"))
        flags = check_docs.extract_flags(
            root / "src" / "repro" / "launch" / "serve.py")
        assert flags == ["--alpha", "--zeta"]

    def test_missing_guide_reported(self, tmp_path):
        root = _mk_launcher_repo(tmp_path, doc_text=None)
        problems = check_docs.missing_flag_docs(root)
        assert len(problems) == 1 and "docs/serving.md is missing" \
            in problems[0]

    def test_undocumented_flag_reported(self, tmp_path):
        root = _mk_launcher_repo(tmp_path, flags=("--batch", "--paged"),
                                 doc_text="only `--batch` is covered")
        problems = check_docs.missing_flag_docs(root)
        assert len(problems) == 1 and "--paged" in problems[0]

    def test_documented_flags_pass(self, tmp_path):
        root = _mk_launcher_repo(tmp_path, flags=("--batch", "--paged"),
                                 doc_text="`--batch` and `--paged`")
        assert check_docs.missing_flag_docs(root) == []

    def test_repo_without_launchers_owes_nothing(self, tmp_path):
        assert check_docs.missing_flag_docs(
            _mk_repo(tmp_path, readme_for=("good", "bare"))) == []

    def test_real_serve_flags_extracted(self):
        """The regex actually sees the real launcher's argparse calls
        (no import — serve.py pulls in jax)."""
        root = check_docs.repo_root()
        flags = check_docs.extract_flags(
            root / "src" / "repro" / "launch" / "serve.py")
        assert {"--paged", "--workers", "--evict", "--horizon",
                "--pool-pages"} <= set(flags)


class TestMetricCoverage:
    def test_template_covers_concrete_keys(self):
        rx = check_docs._template_to_regex("kernel_<op>_tuned_s")
        assert rx.match("kernel_flash_attn_tuned_s")
        assert rx.match("kernel_paged_attn_tuned_s")
        assert not rx.match("kernel_flash_attn_default_s")
        rx2 = check_docs._template_to_regex(
            "disagg_collective_s_<transfer>x<storage>")
        assert rx2.match("disagg_collective_s_int8xf8")
        assert not rx2.match("disagg_collective_s_int8")

    def test_repo_without_bench_diff_owes_nothing(self, tmp_path):
        root = _mk_repo(tmp_path, readme_for=("good", "bare"))
        assert check_docs.gated_metrics(root) == {}
        assert check_docs.missing_metric_docs(root) == []

    def test_every_gated_metric_is_documented_here(self):
        """The real repo's METRICS dict is fully covered by the docs —
        the check the fanin/paged keys must not regress."""
        root = check_docs.repo_root()
        metrics = check_docs.gated_metrics(root)
        assert {"fanin_admission_wait_s", "fanin_evictions",
                "paged_hbm_bytes_per_slot"} <= set(metrics)
        assert check_docs.missing_metric_docs(root) == []
