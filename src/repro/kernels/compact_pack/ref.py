"""Pure-jnp oracle for the compaction gather."""

from __future__ import annotations

import jax.numpy as jnp


def compact_chunks_ref(src: jnp.ndarray, chunk_map: jnp.ndarray
                       ) -> jnp.ndarray:
    return jnp.take(src, chunk_map, axis=0)
