"""LogStructuredTable: Iceberg-semantics table with optimistic concurrency.

Commit protocol: a Transaction captures the table version it was based on;
``commit()`` atomically swaps table metadata iff the version is unchanged,
otherwise it validates whether the concurrent commits conflict:

  * appends commute with anything (rebased automatically);
  * rewrites (compaction) conflict with concurrent commits that touched the
    same files — OR, under ``conflict_granularity="table"`` (the Iceberg
    v1.2.0 behavior observed in §4.4/§6.2 of the paper: "compaction
    operations executed concurrently could result in conflicts when
    targeting distinct partitions"), with ANY concurrent rewrite/delete on
    the table.

Raises CommitConflict when validation fails; callers (compaction scheduler,
write pipelines) implement retry policies, and Table 1 of the paper is
reproduced by counting these.
"""

from __future__ import annotations

import json
import threading
import time
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.lst.files import DataFile, ManifestFile, Snapshot, TableMetadata
from repro.lst.storage import ObjectStore


class CommitConflict(Exception):
    def __init__(self, msg: str, kind: str = "conflict"):
        super().__init__(msg)
        self.kind = kind


def _logical_now() -> float:
    return time.monotonic()


class LogStructuredTable:
    def __init__(self, store: ObjectStore, table_id: str,
                 partition_spec: Optional[str] = None,
                 properties: Optional[Dict] = None,
                 now_fn=_logical_now) -> None:
        self.store = store
        self.now_fn = now_fn
        self.meta = TableMetadata(
            table_id=table_id, partition_spec=partition_spec,
            properties=dict(properties or {}), snapshots=[],
            current_snapshot_id=None, created_at=now_fn())
        self._files: Dict[int, Tuple[DataFile, ...]] = {}   # snapshot -> files
        self._lock = threading.RLock()
        self.cas_retries = 0    # commits that found a moved base (client retry)
        self._persist_metadata()

    # ------------------------------------------------------------------ props
    @property
    def table_id(self) -> str:
        return self.meta.table_id

    @property
    def conflict_granularity(self) -> str:
        return self.meta.properties.get("conflict_granularity", "table")

    @property
    def version(self) -> int:
        return self.meta.version

    # ------------------------------------------------------------------ reads
    def current_files(self, snapshot_id: Optional[int] = None
                      ) -> Tuple[DataFile, ...]:
        with self._lock:
            sid = snapshot_id if snapshot_id is not None \
                else self.meta.current_snapshot_id
            if sid is None:
                return ()
            return self._files[sid]

    def scan(self, partition: Optional[str] = None,
             snapshot_id: Optional[int] = None) -> List[DataFile]:
        """Plan a scan: reads manifest metadata (metered) + filters."""
        files = self.current_files(snapshot_id)
        snap = self.meta.current() if snapshot_id is None else \
            next(s for s in self.meta.snapshots if s.snapshot_id == snapshot_id)
        if snap is not None:           # metadata read cost: manifest list
            self.store.get(snap.manifest_list_path)
        if partition is None:
            return list(files)
        return [f for f in files if f.partition == partition]

    def partitions(self) -> List[str]:
        return sorted({f.partition or "" for f in self.current_files()})

    def file_count(self) -> int:
        return len(self.current_files())

    def total_bytes(self) -> int:
        return sum(f.size_bytes for f in self.current_files())

    # ------------------------------------------------------------ transactions
    def new_transaction(self) -> "Transaction":
        with self._lock:
            return Transaction(self, self.meta.version,
                               self.meta.current_snapshot_id)

    def append(self, files: Sequence[DataFile]) -> Snapshot:
        txn = self.new_transaction()
        txn.append_files(files)
        return txn.commit()

    def rewrite(self, removed: Sequence[DataFile], added: Sequence[DataFile],
                scope: Optional[str] = None) -> Snapshot:
        txn = self.new_transaction()
        txn.rewrite_files(removed, added, scope)
        return txn.commit()

    def delete_files(self, removed: Sequence[DataFile],
                     scope: Optional[str] = None) -> Snapshot:
        txn = self.new_transaction()
        txn.remove_files(removed, scope=scope)
        return txn.commit()

    # ------------------------------------------------------------ maintenance
    def expire_snapshots(self, keep_last: int = 5) -> int:
        """Drop old snapshot metadata + orphaned data files. Returns #objects
        removed (snapshot expiry is itself a storage-healing operation)."""
        with self._lock:
            if len(self.meta.snapshots) <= keep_last:
                return 0
            keep = self.meta.snapshots[-keep_last:]
            drop = self.meta.snapshots[:-keep_last]
            live: set = set()
            for s in keep:
                live |= {f.path for f in self._files[s.snapshot_id]}
            removed = 0
            for s in drop:
                for f in self._files.pop(s.snapshot_id, ()):
                    if f.path not in live and self.store.exists(f.path):
                        self.store.delete(f.path)
                        removed += 1
                self.store.delete(s.manifest_list_path)
                removed += 1
            self.meta.snapshots = keep
            self._persist_metadata()
            return removed

    # ------------------------------------------------------------- internals
    def _next_snapshot_id(self) -> int:
        """Per-table snapshot IDs, seeded from the table's own metadata.

        NFR2 determinism: a module-global counter (the old
        ``itertools.count``) leaks allocation order across every table in
        the process, so identical catalog states produced different
        snapshot IDs and manifest paths depending on what else had
        committed first. Deriving the next ID from the newest snapshot in
        ``self.meta`` makes IDs (and the metadata paths built from them) a
        pure function of table history — two identical runs serialize
        byte-identical metadata. Expiry only drops *old* snapshots, so the
        newest survives and IDs stay strictly increasing.
        """
        if self.meta.snapshots:
            return self.meta.snapshots[-1].snapshot_id + 1
        return 1

    def _persist_metadata(self) -> None:
        path = f"{self.meta.table_id}/metadata/v{self.meta.version}.json"
        self.store.put(path, self.meta.serialize())

    def _try_commit(self, txn: "Transaction") -> Snapshot:
        with self._lock:
            if self.meta.version != txn.base_version:
                self.cas_retries += 1       # stale base: CAS retry happened
                self._validate(txn)
            # rebase onto current state
            base = self.current_files()
            removed_paths = {f.path for f in txn.removed}
            if txn.operation in ("replace", "delete"):
                missing = removed_paths - {f.path for f in base}
                if missing:
                    raise CommitConflict(
                        f"files vanished under rewrite: {sorted(missing)[:3]}",
                        kind="stale_files")
            new_files = tuple(f for f in base if f.path not in removed_paths
                              ) + tuple(txn.added)
            sid = self._next_snapshot_id()
            seq = (self.meta.snapshots[-1].sequence_number + 1
                   if self.meta.snapshots else 1)
            manifest = ManifestFile(
                f"{self.table_id}/metadata/manifest-{sid}.json",
                tuple(txn.added), tuple(sorted(removed_paths)))
            self.store.put(manifest.path, manifest.serialize())
            mlist_path = f"{self.table_id}/metadata/snap-{sid}.json"
            self.store.put(mlist_path, json.dumps(
                {"manifests": [manifest.path]}).encode())
            snap = Snapshot(
                snapshot_id=sid, parent_id=self.meta.current_snapshot_id,
                sequence_number=seq, timestamp=self.now_fn(),
                operation=txn.operation, manifest_list_path=mlist_path,
                summary={"added": len(txn.added),
                         "removed": len(removed_paths),
                         "scope": txn.scope})
            self.meta.snapshots.append(snap)
            self.meta.current_snapshot_id = sid
            self.meta.version += 1
            self.meta.last_write_at = snap.timestamp
            self._files[sid] = new_files
            self._persist_metadata()
            return snap

    def _validate(self, txn: "Transaction") -> None:
        """Conflict validation against commits since txn.base_version."""
        later = [s for s in self.meta.snapshots
                 if txn.base_snapshot_id is None
                 or s.snapshot_id > (txn.base_snapshot_id or 0)]
        if txn.operation == "append":
            return                        # appends always rebase cleanly
        stale_thresh = int(self.meta.properties.get(
            "stale_metadata_threshold", 2))
        for s in later:
            if s.operation == "append":
                # Iceberg v1.2 behavior (§4.4/§6.2): a long-running rewrite
                # accumulating enough concurrent commits fails with a
                # stale-metadata conflict even though appends are logically
                # compatible — short (partition-scope) windows rarely hit
                # this, long table-scope jobs do
                if self.conflict_granularity == "table" \
                        and len(later) >= stale_thresh:
                    raise CommitConflict(
                        f"stale metadata: {len(later)} commits since rewrite "
                        f"basis", kind="stale_metadata")
                continue
            if self.conflict_granularity == "table":
                raise CommitConflict(
                    f"concurrent {s.operation} (snapshot {s.snapshot_id}) "
                    f"conflicts at table granularity", kind="table_granularity")
            if s.summary.get("scope") == txn.scope or s.summary.get("scope") \
                    is None or txn.scope is None:
                raise CommitConflict(
                    f"concurrent {s.operation} on scope {txn.scope!r}",
                    kind="partition_overlap")


class Transaction:
    def __init__(self, table: LogStructuredTable, base_version: int,
                 base_snapshot_id: Optional[int]) -> None:
        self.table = table
        self.base_version = base_version
        self.base_snapshot_id = base_snapshot_id
        self.added: List[DataFile] = []
        self.removed: List[DataFile] = []
        self.operation = "append"
        self.scope: Optional[str] = None

    def append_files(self, files: Sequence[DataFile]) -> "Transaction":
        self.added.extend(files)
        self.operation = "append"
        return self

    def remove_files(self, files: Sequence[DataFile],
                     scope: Optional[str] = None) -> "Transaction":
        """File-level delete. ``scope`` narrows the conflict window under
        partition granularity when every removed file shares one partition
        (a partition-aligned retention drop), exactly as rewrites do."""
        self.removed.extend(files)
        self.operation = "delete"
        self.scope = scope
        return self

    def rewrite_files(self, removed: Sequence[DataFile],
                      added: Sequence[DataFile],
                      scope: Optional[str] = None) -> "Transaction":
        self.removed.extend(removed)
        self.added.extend(added)
        self.operation = "replace"
        self.scope = scope
        return self

    def commit(self) -> Snapshot:
        return self.table._try_commit(self)
