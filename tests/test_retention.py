"""Tiered DELETE/retention (lst/retention.py + core/retention.py).

Router decision table, the tier-1 metadata-only drop guarantee under
concurrent writers (mirrors compaction's live-input accounting tests), the
tier-2 rewrite planner, and the fleet integration: delete candidates enter
the shared-budget pool, file drops are budget-free, one-shot ops retire,
standing policies re-route, deferred deletes stay pending, and a
rewrite-delete through the fleet commits bit-identical tables on the fused
and reference filter paths.
"""

import itertools

import numpy as np
import pytest

from repro.core.act import Scheduler
from repro.core.fleet import ClassProfile, FleetScheduler, build_class_pipeline
from repro.lst import (Catalog, InMemoryStore, PredicateDelete,
                       RetentionPolicy, execute_file_drops,
                       plan_rewrite_delete, route_delete)
from repro.lst import compaction as comp
from repro.lst.files import DataFile
from repro.lst.workload import SimClock

MB = 1 << 20
_FILE_IDS = itertools.count(1)


def make_table(granularity="table", partition_spec="p"):
    clock = SimClock()
    store = InMemoryStore()
    cat = Catalog(store, now_fn=clock.now)
    t = cat.create_table("ns", "t", partition_spec,
                         properties={"conflict_granularity": granularity})
    t.now_fn = clock.now
    return clock, cat, t, store


def add_files(t, n, size=4 * MB, parts=("a", "b"), created_at=0.0, rows=10):
    files = []
    for i in range(n):
        fid = next(_FILE_IDS)
        path = f"{t.table_id}/data/f{fid:06d}.bin"
        t.store.put(path, b"x" * 128)
        files.append(DataFile(path, size, rows, parts[i % len(parts)],
                              created_at=created_at))
    t.append(files)
    return files


# --------------------------------------------------------------- the router

class TestRouter:
    def test_age_based_retention_drops_aged_files(self):
        clock, _, t, _ = make_table()
        old = add_files(t, 4, created_at=0.0)
        clock.advance(48.0)
        young = add_files(t, 2, created_at=clock.now())
        route = route_delete(t, RetentionPolicy("ttl", max_age_hours=24.0))
        assert {f.path for f in route.file_drops} == {f.path for f in old}
        assert route.rewrite_files == ()
        assert route.drop_rows == sum(f.num_rows for f in old)
        assert all(f.path not in {d.path for d in route.file_drops}
                   for f in young)

    def test_nothing_aged_routes_empty(self):
        clock, _, t, _ = make_table()
        add_files(t, 4)
        clock.advance(10.0)
        route = route_delete(t, RetentionPolicy("ttl", max_age_hours=24.0))
        assert route.empty

    def test_partition_drop_is_exact(self):
        _, _, t, _ = make_table()
        files = add_files(t, 6, parts=("a", "b"))
        route = route_delete(t, RetentionPolicy("drop-a",
                                                drop_partitions=("a",)))
        assert {f.path for f in route.file_drops} == \
            {f.path for f in files if f.partition == "a"}
        assert route.rewrite_files == ()

    def test_retention_policy_never_rewrites(self):
        clock, _, t, _ = make_table()
        add_files(t, 8, parts=("a", "b", "c"))
        clock.advance(100.0)
        route = route_delete(t, RetentionPolicy(
            "both", max_age_hours=1.0, drop_partitions=("b",)))
        assert route.rewrite_files == ()
        assert len(route.file_drops) == 8

    def test_predicate_file_evidence_tiers(self):
        """file_predicate True -> drop, False -> keep, None -> rewrite."""
        _, _, t, _ = make_table()
        add_files(t, 6, parts=("a", "b", "c"))
        verdict = {"a": True, "b": False, "c": None}
        op = PredicateDelete(
            "gdpr", row_predicate=lambda rows, task: rows[:, 0] < 0,
            file_predicate=lambda f: verdict[f.partition])
        route = route_delete(t, op)
        assert {f.partition for f in route.file_drops} == {"a"}
        assert {f.partition for f in route.rewrite_files} == {"c"}

    def test_predicate_without_file_evidence_rewrites_everything(self):
        _, _, t, _ = make_table()
        files = add_files(t, 5)
        op = PredicateDelete("gdpr",
                             row_predicate=lambda rows, task: rows[:, 0] < 0)
        route = route_delete(t, op)
        assert route.file_drops == ()
        assert len(route.rewrite_files) == len(files)

    def test_est_reclaim_prices_drops_full_and_rewrites_by_selectivity(self):
        _, _, t, _ = make_table()
        add_files(t, 4, size=10 * MB, parts=("a", "b"))
        op = PredicateDelete(
            "gdpr", row_predicate=lambda rows, task: rows[:, 0] < 0,
            file_predicate=lambda f: True if f.partition == "a" else None,
            est_selectivity=0.25)
        route = route_delete(t, op)
        assert route.est_reclaim_bytes == pytest.approx(
            route.drop_bytes + 0.25 * route.rewrite_bytes)
        assert route.drop_bytes == 20 * MB and route.rewrite_bytes == 20 * MB

    def test_table_scoping(self):
        op = RetentionPolicy("ttl", max_age_hours=1.0, tables=("ns/t",))
        assert op.applies_to("ns/t") and not op.applies_to("ns/other")


# ------------------------------------------------------- tier-2 bin planner

class TestRewritePlan:
    def test_never_crosses_partitions(self):
        _, _, t, _ = make_table()
        files = add_files(t, 10, parts=("a", "b"))
        for task in plan_rewrite_delete(t, files, target_bytes=64 * MB):
            assert len({f.partition for f in task.inputs}) == 1

    def test_every_matched_file_planned_no_size_cutoff(self):
        """Unlike plan_binpack: a lone small file and an over-target file
        both MUST be rewritten — a delete has no minimum batch."""
        _, _, t, _ = make_table()
        small = add_files(t, 1, size=1 * MB, parts=("a",))
        big = add_files(t, 1, size=600 * MB, parts=("b",))
        tasks = plan_rewrite_delete(t, small + big, target_bytes=512 * MB)
        planned = {f.path for task in tasks for f in task.inputs}
        assert planned == {small[0].path, big[0].path}
        assert all(len(task.inputs) >= 1 for task in tasks)

    def test_plan_scoped_ids_deterministic(self):
        _, _, t, _ = make_table()
        files = add_files(t, 9, parts=("a", "b", "c"))
        a = plan_rewrite_delete(t, files, target_bytes=8 * MB)
        b = plan_rewrite_delete(t, files, target_bytes=8 * MB)
        assert [(task.task_id, tuple(f.path for f in task.inputs))
                for task in a] == \
               [(task.task_id, tuple(f.path for f in task.inputs))
                for task in b]
        assert [task.task_id for task in a] == list(range(1, len(a) + 1))


# ------------------------------------------------------ tier-1 file drops

class TestFileDrops:
    def test_drop_is_metadata_only(self):
        """The tier-1 guarantee: one `delete` snapshot, ZERO bytes
        rewritten, zero GBHr, blobs physically reclaimed."""
        clock, _, t, store = make_table()
        files = add_files(t, 6)
        clock.advance(48.0)
        route = route_delete(t, RetentionPolicy("ttl", max_age_hours=24.0))
        res = execute_file_drops(t, route.file_drops)
        assert res.success
        assert res.bytes_rewritten == 0
        assert res.gbhr == 0.0
        assert res.files_removed == 6
        assert res.rows_dropped == sum(f.num_rows for f in files)
        assert res.bytes_reclaimed == sum(f.size_bytes for f in files)
        assert t.current_files() == ()
        assert all(not store.exists(f.path) for f in files)
        snap = t.meta.snapshots[-1]
        assert snap.operation == "delete"
        assert snap.summary["removed"] == 6 and snap.summary["added"] == 0

    def test_empty_plan_is_vacuous_success(self):
        _, _, t, _ = make_table()
        n_snaps = len(t.meta.snapshots)
        res = execute_file_drops(t, [])
        assert res.success and res.files_removed == 0
        assert len(t.meta.snapshots) == n_snaps   # no commit at all

    def test_single_partition_drop_narrows_scope(self):
        """All dropped files in one partition -> the delete snapshot
        carries that scope, so partition-granularity writers elsewhere
        don't conflict with it."""
        _, _, t, _ = make_table(granularity="partition")
        files = add_files(t, 6, parts=("a", "b"))
        only_a = [f for f in files if f.partition == "a"]
        res = execute_file_drops(t, only_a)
        assert res.success
        assert t.meta.snapshots[-1].summary["scope"] == "a"
        assert {f.partition for f in t.current_files()} == {"b"}


class TestConcurrentWriters:
    def test_concurrent_delete_not_credited_to_drop(self):
        """A file a concurrent writer removed in the plan->commit window is
        neither counted as OUR removal nor physically deleted — its blob
        belongs to whoever removed the entry."""
        _, _, t, store = make_table()
        files = add_files(t, 8)
        dead = files[0]
        done = {"hit": False}

        def delete_one(table, _task):
            if not done["hit"]:
                done["hit"] = True
                table.delete_files([dead])

        res = execute_file_drops(t, files, interleave_fn=delete_one)
        assert res.success
        assert res.files_removed == len(files) - 1
        assert res.rows_dropped == sum(f.num_rows for f in files[1:])
        assert store.exists(dead.path)
        for f in files[1:]:
            assert not store.exists(f.path)

    def test_reappended_path_survives_drop(self):
        """The race the ISSUE names: a writer drops a planned file and
        re-appends a FRESH entry at the same path between plan and commit.
        The planned generation is gone, so the drop must not remove the
        look-alike entry — and must never delete its blob."""
        clock, _, t, store = make_table()
        files = add_files(t, 4)
        target = files[0]
        reborn = DataFile(target.path, target.size_bytes, 99,
                          target.partition, created_at=7.5)

        def reref(table, _task):
            if not getattr(reref, "hit", False):
                reref.hit = True
                table.delete_files([target])
                table.append([reborn])

        res = execute_file_drops(t, files, interleave_fn=reref)
        assert res.success
        assert res.files_removed == len(files) - 1
        assert res.rows_dropped == sum(f.num_rows for f in files[1:])
        # the re-referenced entry is still in the table, blob intact
        live = {f.path: f for f in t.current_files()}
        assert live == {target.path: reborn}
        assert store.exists(target.path)
        for f in files[1:]:
            assert not store.exists(f.path)

    def test_stale_metadata_conflict_retries_and_commits(self):
        """Table-granularity: >= 2 commits since the txn basis trip the
        stale-metadata conflict; the drop retries on a fresh basis."""
        _, _, t, store = make_table()
        files = add_files(t, 4)

        def two_appends(table, _task):
            if not getattr(two_appends, "hit", False):
                two_appends.hit = True
                add_files(table, 1, parts=("z",))
                add_files(table, 1, parts=("z",))

        res = execute_file_drops(t, files, interleave_fn=two_appends)
        assert res.success and res.conflict and res.retries >= 1
        assert res.files_removed == 4
        assert all(not store.exists(f.path) for f in files)
        assert len(t.current_files()) == 2    # the interleaved appends

    def test_everything_gone_is_vacuous_success(self):
        _, _, t, store = make_table()
        files = add_files(t, 3)

        def delete_all(table, _task):
            if not getattr(delete_all, "hit", False):
                delete_all.hit = True
                table.delete_files(list(files))

        res = execute_file_drops(t, files, interleave_fn=delete_all)
        assert res.success
        assert res.files_removed == 0 and res.rows_dropped == 0
        # the concurrent deleter owns those blobs, not us
        assert all(store.exists(f.path) for f in files)


# -------------------------------------------------------- fleet integration

def mk_retention_fleet(n_tables=3, n_files=10, budget=0.0, **fleet_kw):
    clock = SimClock()
    store = InMemoryStore()
    catalog = Catalog(store, now_fn=clock.now)
    catalog.create_namespace("db", total_quota=10_000_000)
    tables = []
    for i in range(n_tables):
        t = catalog.create_table("db", f"t{i:03d}", None)
        t.now_fn = clock.now
        add_files(t, n_files, size=1 * MB, parts=(None,), rows=100)
        tables.append(t)
    fleet = FleetScheduler(catalog, budget_gbhr=budget, **fleet_kw)
    return clock, store, catalog, tables, fleet


class TestFleetRetention:
    def test_ttl_drops_are_budget_free(self):
        """A zero-GBHr fleet budget admits file drops (explicit 0.0 cost)
        while ordinary compaction can't buy a single rewrite."""
        clock, store, _, tables, fleet = mk_retention_fleet(budget=0.0)
        clock.advance(48.0)
        fleet.submit_retention(RetentionPolicy("ttl", max_age_hours=24.0))
        rep = fleet.run_cycle()
        assert rep.n_delete_candidates == len(tables)
        assert rep.spent_gbhr == 0.0
        assert rep.files_dropped == len(tables) * 10
        assert rep.rows_dropped == len(tables) * 10 * 100
        assert rep.retention_bytes_rewritten == 0
        for t in tables:
            assert t.current_files() == ()

    def test_standing_policy_reroutes_each_cycle(self):
        clock, _, _, tables, fleet = mk_retention_fleet(n_tables=1,
                                                        budget=0.0)
        clock.advance(48.0)
        fleet.submit_retention(RetentionPolicy("ttl", max_age_hours=24.0))
        rep1 = fleet.run_cycle()
        assert rep1.rows_dropped > 0
        # quiet cycle: nothing newly aged, empty route, NOT retired
        rep2 = fleet.run_cycle()
        assert rep2.n_delete_candidates == 0
        assert fleet.retention.has_pending()
        # new writes age out -> the same policy fires again
        add_files(tables[0], 5, parts=(None,), rows=100,
                  created_at=clock.now())
        clock.advance(48.0)
        rep3 = fleet.run_cycle()
        assert rep3.n_delete_candidates == 1 and rep3.files_dropped == 5

    def test_one_shot_predicate_retires_after_commit(self):
        clock, _, _, tables, fleet = mk_retention_fleet(
            n_tables=1, budget=50.0,
            profiles={"steady": ClassProfile("steady", scope="table",
                                             min_small_files=1_000_000)})
        tid = tables[0].table_id
        op = PredicateDelete(
            "gdpr", row_predicate=lambda rows, task: rows[:, 0] % 2 == 0,
            est_selectivity=0.5, tables=(tid,))
        fleet.submit_delete(op)
        rep1 = fleet.run_cycle()
        assert rep1.n_delete_candidates == 1
        assert rep1.rows_dropped > 0
        assert rep1.retention_bytes_rewritten > 0
        assert rep1.bytes_reclaimed > 0
        # fully committed -> retired; next cycle proposes nothing
        assert not fleet.retention.has_pending()
        rep2 = fleet.run_cycle()
        assert rep2.n_delete_candidates == 0
        tot = fleet.totals()
        assert tot["rows_dropped"] == rep1.rows_dropped
        assert tot["retention_bytes_rewritten"] == \
            rep1.retention_bytes_rewritten

    def test_deferred_delete_stays_pending_and_lands_offpeak(self):
        """A closed off-peak window defers the delete; it must NOT be
        retired or lost — it re-enters the pool and commits once the
        window opens."""
        window = {"open": False}
        clock, _, _, tables, fleet = mk_retention_fleet(
            n_tables=1, budget=0.0,
            pipeline_factory=lambda p, activity=None, stats=None:
                build_class_pipeline(
                    p, activity, stats=stats,
                    scheduler=Scheduler(
                        512 * MB,
                        offpeak_window=lambda: window["open"])))
        clock.advance(48.0)
        fleet.submit_retention(RetentionPolicy("ttl", max_age_hours=24.0))
        rep1 = fleet.run_cycle()
        assert rep1.n_delete_candidates == 1
        assert len(rep1.deferred_keys) == 1
        assert rep1.rows_dropped == 0 and rep1.files_dropped == 0
        assert len(tables[0].current_files()) == 10
        window["open"] = True
        rep2 = fleet.run_cycle()
        assert rep2.rows_dropped == 1000 and rep2.files_dropped == 10
        assert tables[0].current_files() == ()

    def test_after_write_cycle_still_sees_quiet_tables(self):
        """An explicit-tables (after_write) cycle extends its table set
        with retention targets: a compliance delete can't wait for someone
        to write to the table."""
        clock, _, _, tables, fleet = mk_retention_fleet(budget=0.0)
        clock.advance(48.0)
        fleet.submit_retention(RetentionPolicy("ttl", max_age_hours=24.0))
        rep = fleet.run_cycle(tables=[])     # nobody wrote anything
        assert rep.n_delete_candidates == len(tables)
        assert rep.rows_dropped == len(tables) * 10 * 100


class TestFleetRewriteBitMatch:
    """Rewrite-deletes THROUGH the fleet: the fused filter+pack path and
    the two-pass reference must commit identical tables and identical
    rows_dropped accounting (the tier-2 analogue of
    test_data_pipeline.TestRewriteDeletes, but driven by a PredicateDelete
    entering the shared-budget pool)."""

    @staticmethod
    def _drop_even(rows, task):
        return rows[:, 0] % 2 == 0          # DROP even-leading rows

    def _run(self, fused):
        from repro.data import (TokenShardWriter, decode_shard,
                                merge_shards_fn)
        clock = SimClock()
        store = InMemoryStore()
        cat = Catalog(store, now_fn=clock.now)
        t = cat.create_table("train", "corpus",
                             properties={"conflict_granularity": "table"})
        t.now_fn = clock.now
        w = TokenShardWriter(t, vocab=997, seed=3)
        for _ in range(3):
            w.trickle_append(n_files=6, tokens_per_file=3000)
        fleet = FleetScheduler(
            cat, budget_gbhr=100.0,
            profiles={"steady": ClassProfile("steady", scope="table",
                                             min_small_files=1_000_000)},
            pipeline_factory=lambda p, activity=None, stats=None:
                build_class_pipeline(
                    p, activity, stats=stats,
                    scheduler=Scheduler(512 * MB, merge_fn=merge_shards_fn,
                                        fused_filter=fused)))
        fleet.submit_delete(PredicateDelete(
            "purge", row_predicate=self._drop_even,
            tables=(t.table_id,)))
        rep = fleet.run_cycle()
        assert rep.n_delete_candidates == 1
        toks = sorted((decode_shard(store.get(f.path))
                       for f in t.current_files()),
                      key=lambda a: (a.shape[0], tuple(a[:8])))
        return rep.rows_dropped, toks

    def test_fused_and_reference_commit_identical_tables(self):
        dropped_fused, toks_fused = self._run(fused=True)
        dropped_ref, toks_ref = self._run(fused=False)
        assert dropped_fused == dropped_ref > 0
        assert len(toks_fused) == len(toks_ref)
        assert all(np.array_equal(a, b)
                   for a, b in zip(toks_fused, toks_ref))
        # the delete held: every surviving 128-token row leads odd
        for arr in toks_fused:
            assert (arr.reshape(-1, 128)[:, 0] % 2 == 1).all()
