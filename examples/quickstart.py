"""Quickstart: AutoComp on a synthetic data-lake in ~60 lines.

Creates a catalog of trickle-written tables, shows the small-file
distribution (Fig. 1/2-style), runs one AutoComp OODA cycle under a GBHr
budget, and prints the before/after distributions and decisions.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, _ROOT)
sys.path.insert(0, os.path.join(_ROOT, "src"))

from repro.core import (AutoCompPipeline, MoopRanker, StatsCollector,
                        TraitContext)
from repro.core.act import Scheduler
from repro.core.model import Scope
from repro.core.orient import (ComputeCostTrait, FileCountReductionTrait,
                               FileEntropyTrait)
from repro.lst import Catalog, InMemoryStore
from repro.lst.workload import SimClock, WorkloadGenerator, WorkloadSpec

MB = 1 << 20
TARGET = 512 * MB


def histogram(catalog, title):
    files = [f for t in catalog.tables() for f in t.current_files()]
    buckets = [(1, "<1MB"), (8, "1-8MB"), (64, "8-64MB"), (512, "64-512MB"),
               (1 << 30, ">=512MB")]
    print(f"\n{title}  ({len(files)} files)")
    lo = 0
    for hi, label in buckets:
        n = sum(1 for f in files if lo * MB <= f.size_bytes < hi * MB)
        print(f"  {label:>10}: {'#' * min(60, n // 8)} {n}")
        lo = hi


def main():
    clock = SimClock()
    store = InMemoryStore()
    catalog = Catalog(store, now_fn=clock.now)
    gen = WorkloadGenerator(catalog, WorkloadSpec(n_databases=3,
                                                  tables_per_db=4, seed=42),
                            clock)
    gen.setup()
    for _ in range(3):
        gen.run_hour()
    histogram(catalog, "BEFORE compaction (trickle-written user tables)")
    print(f"store objects={store.object_count} rpc={store.metrics.rpc_total}")

    pipeline = AutoCompPipeline(
        stats=StatsCollector(TARGET),
        traits=(FileCountReductionTrait(), FileEntropyTrait(),
                ComputeCostTrait()),
        trait_ctx=TraitContext(target_file_bytes=TARGET),
        ranker=MoopRanker({"file_count_reduction": 0.7, "compute_cost": 0.3}),
        scheduler=Scheduler(TARGET),
        scope=Scope.TABLE,
        top_k=10,
        budget_gbhr=5.0,
    )
    rep = pipeline.run_cycle(catalog)
    print(f"\nAutoComp cycle: {rep.n_candidates} candidates -> "
          f"{rep.n_selected} selected -> {rep.files_removed} files removed, "
          f"{rep.act.files_added} written, {rep.gbhr:.3f} GBHr, "
          f"{rep.act.conflicts} conflicts")
    for key in rep.selected_keys[:5]:
        print("  selected:", key)
    histogram(catalog, "AFTER compaction")


if __name__ == "__main__":
    main()
