#!/usr/bin/env bash
# Tier-1 gate: install dev deps and run the full suite. A collection error
# anywhere (e.g. a module importing a package that does not exist) fails
# this script, so seed-style breakage can never land again.
set -euo pipefail
cd "$(dirname "$0")/.."

python -m pip install -r requirements-dev.txt

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
