"""Dropped-token Mixture-of-Experts layer (Qwen3-MoE style: top-k softmax-
renormalized gates, no shared expert).

TPU-native dispatch: tokens are processed in groups of ``GROUP`` tokens; each
group dispatches into per-expert capacity buffers with a deterministic
einsum (Mesh-TensorFlow formulation). Group size is deliberately small —
dispatch/combine FLOPs are 2*tokens*cf*GROUP*k*d, *independent of E*, so
small groups keep dispatch overhead ~10% of expert compute (see
EXPERIMENTS.md §Perf napkin math). Experts are sharded over the "model" mesh
axis (EP); XLA SPMD inserts the all-to-alls.
"""

from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig
from repro.dist.collectives import current_act_transport
from repro.dist.sharding import constrain
from repro.kernels.expert_a2a import expert_a2a
from repro.models.common import Spec

GROUP = 512  # tokens per dispatch group (upper bound)


def moe_specs(cfg: ModelConfig) -> Dict[str, Spec]:
    d, e, f = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    return {
        "router": Spec((d, e), ("embed", "experts"), dtype=jnp.float32),
        "w_gate": Spec((e, d, f), ("experts", "embed", "expert_mlp")),
        "w_up": Spec((e, d, f), ("experts", "embed", "expert_mlp")),
        "w_down": Spec((e, f, d), ("experts", "expert_mlp", "embed")),
    }


def _group_size(n_tokens: int) -> int:
    g = min(GROUP, n_tokens)
    while n_tokens % g:
        g -= 1
    return g


def capacity(cfg: ModelConfig, group: int) -> int:
    return max(1, math.ceil(cfg.capacity_factor * group * cfg.top_k / cfg.n_experts))


def moe_apply(cfg: ModelConfig, p, x: jnp.ndarray, mode: str = "train"
              ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """x: (B, S, d) -> (B, S, d), aux metrics (load-balance loss etc.).

    Under expert-parallel decode with ``act_transport="int8"``, the token
    dispatch (the expert all-to-all's payload) routes through the
    ``expert_a2a`` tunable op — int8 blockwise on the wire, dequantized on
    the expert shard. Train/prefill keep the bf16 einsum dispatch so the
    training loss path stays bit-identical.
    """
    b, s, d = x.shape
    n_tokens = b * s
    m = _group_size(n_tokens)
    g = n_tokens // m
    e, k = cfg.n_experts, cfg.top_k
    c = capacity(cfg, m)

    xt = constrain(x.reshape(g, m, d), "batch", None, "act_embed")
    logits = constrain(
        jnp.einsum("gmd,de->gme", xt.astype(jnp.float32), p["router"]),
        "batch", None, None)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, sel = jax.lax.top_k(probs, k)                     # (g,m,k)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)  # renorm (Qwen3)

    onehot = jax.nn.one_hot(sel, e, dtype=jnp.float32)           # (g,m,k,e)
    flat = onehot.reshape(g, m * k, e)
    # position of each (token, choice) within its expert's buffer
    pos_in_e = jnp.cumsum(flat, axis=1) - flat                   # (g,mk,e)
    slot = jnp.sum(pos_in_e * flat, axis=-1).astype(jnp.int32)   # (g,mk)
    keep = (slot < c).astype(jnp.float32).reshape(g, m, k)
    slot_oh = jax.nn.one_hot(slot.reshape(g, m, k), c, dtype=jnp.float32)

    # dispatch mask (g,m,e,c) and gate-weighted combine mask
    dispatch = constrain(
        jnp.einsum("gmke,gmkc->gmec", onehot * keep[..., None], slot_oh),
        "batch", None, "experts", None)
    combine = constrain(
        jnp.einsum("gmke,gmkc->gmec",
                   onehot * (gate_vals * keep)[..., None], slot_oh),
        "batch", None, "experts", None)

    xe = jnp.einsum("gmec,gmd->gecd", dispatch.astype(x.dtype), xt)  # (g,e,c,d)
    if mode == "decode" and current_act_transport() == "int8":
        xe = expert_a2a(xe)
    else:
        xe = constrain(xe, "batch", "experts", None, "act_embed")
    h_gate = constrain(jnp.einsum("gecd,edf->gecf", xe, p["w_gate"]),
                       "batch", "experts", None, None)
    h_up = jnp.einsum("gecd,edf->gecf", xe, p["w_up"])
    ye = constrain(jnp.einsum("gecf,efd->gecd",
                              jax.nn.silu(h_gate) * h_up, p["w_down"]),
                   "batch", "experts", None, "act_embed")
    y = constrain(jnp.einsum("gmec,gecd->gmd", combine.astype(x.dtype), ye),
                  "batch", None, "act_embed")

    # aux: load-balance loss (Switch style) + router z-loss + drop fraction
    density = jnp.mean(onehot, axis=(1, 2))                      # (g,e) selection freq
    density_prob = jnp.mean(probs, axis=1)                       # (g,e)
    lb_loss = e * jnp.mean(jnp.sum(density * density_prob, axis=-1))
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    dropped = 1.0 - jnp.mean(keep)
    aux = {"moe_lb_loss": lb_loss, "moe_z_loss": z_loss,
           "moe_drop_frac": dropped}
    return y.reshape(b, s, d), aux
