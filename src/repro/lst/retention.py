"""Tiered DELETE/retention for log-structured tables.

Two delete tiers, one router. The LSM design-space literature treats data
removal as a first-class compaction primitive, and tombstone-based deletes
are the main read-amplification hazard log-structured tables face — so this
layer never writes a tombstone. Every delete resolves to one of:

  file-level drop   the predicate aligns with file/partition boundaries
                    (time-based retention on immutable files, explicit
                    partition drops, predicates that provably match every
                    row of a file). A pure METADATA commit: a ``delete``
                    snapshot removes the entries, zero bytes are rewritten,
                    and the commit validates against concurrent writers
                    exactly like compaction's atomic path — liveness is
                    re-checked per attempt, and a blob is physically
                    deleted only if OUR commit removed its entry and no
                    concurrent commit re-referenced the path.

  rewrite-delete    sparse predicates (GDPR erasure, tag-scoped cleanup):
                    the files that MAY contain matching rows are rewritten
                    with a filter attached — a rewrite that drops rows is
                    just a compaction with a filter, so it reuses
                    ``compaction.execute_task(filter_fn=)`` and the fused
                    filter+pack kernel. ``core.retention.RetentionQueue``
                    prices these into the fleet scheduler's shared GBHr
                    budget instead of running them as ad-hoc jobs.

``route_delete`` is the router; ``execute_file_drops`` the tier-1 executor
(returns a ``CompactionResult`` with ``bytes_rewritten == 0`` so the act
layer aggregates both tiers uniformly); ``plan_rewrite_delete`` bins the
tier-2 files into compaction tasks (unlike ``plan_binpack`` it takes every
matched file regardless of size and allows single-file bins — a 600 MB
file with matching rows still has to be rewritten).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.lst.compaction import CompactionResult, CompactionTask
from repro.lst.files import DataFile
from repro.lst.table import CommitConflict, LogStructuredTable


@dataclasses.dataclass(frozen=True)
class RetentionPolicy:
    """Time/partition-aligned retention: a STANDING policy, re-routed every
    cycle — each cycle drops whatever newly aged out. Both predicates are
    file-aligned by construction (files are immutable and carry their
    ``created_at``; partitions are file attributes), so a retention policy
    always routes to tier-1 file drops and never rewrites a byte."""
    name: str
    max_age_hours: Optional[float] = None     # drop files older than this
    drop_partitions: Tuple[str, ...] = ()     # explicit partition drops
    tables: Optional[Tuple[str, ...]] = None  # table_ids; None = all

    def applies_to(self, table_id: str) -> bool:
        return self.tables is None or table_id in self.tables

    def matches_file(self, f: DataFile, now: float) -> bool:
        if (f.partition or "") in self.drop_partitions:
            return True
        return (self.max_age_hours is not None
                and now - f.created_at >= self.max_age_hours)


@dataclasses.dataclass(frozen=True)
class PredicateDelete:
    """Row-level delete (GDPR/tag-scoped): ONE-SHOT — pending until every
    target table's rewrite commits, then retired by the queue.

    ``row_predicate(rows, task) -> drop_mask`` marks rows to DELETE (the
    natural polarity for a delete job); :meth:`filter_fn` adapts it to the
    keep-mask contract of ``execute_task(filter_fn=)``. ``file_predicate``
    lets file-level metadata short-circuit the row scan per file:
    ``True`` = every row matches (tier-1 drop, no rewrite), ``False`` = no
    row can match (skip entirely), ``None`` = unknown (tier-2 rewrite).
    ``est_selectivity`` is the expected dropped-row fraction of the files
    that need rewriting — it prices the candidate's reclaimed bytes before
    any byte is read."""
    name: str
    row_predicate: Callable = None            # (rows, task) -> bool drop mask
    file_predicate: Optional[Callable] = None  # DataFile -> True|False|None
    est_selectivity: float = 0.1
    tables: Optional[Tuple[str, ...]] = None

    def applies_to(self, table_id: str) -> bool:
        return self.tables is None or table_id in self.tables

    def filter_fn(self) -> Callable:
        """Keep-mask adapter for the compaction substrate."""
        def keep(rows, task):
            drop = np.asarray(self.row_predicate(rows, task), bool)
            return ~drop.reshape(-1)
        return keep


@dataclasses.dataclass
class DeleteRoute:
    """Router output for one (op, table): which files drop at the metadata
    tier and which must be rewritten with the filter attached."""
    op: object                                # RetentionPolicy | PredicateDelete
    table_id: str
    file_drops: Tuple[DataFile, ...] = ()
    rewrite_files: Tuple[DataFile, ...] = ()

    @property
    def empty(self) -> bool:
        return not self.file_drops and not self.rewrite_files

    @property
    def drop_bytes(self) -> int:
        return sum(f.size_bytes for f in self.file_drops)

    @property
    def drop_rows(self) -> int:
        return sum(f.num_rows for f in self.file_drops)

    @property
    def rewrite_bytes(self) -> int:
        return sum(f.size_bytes for f in self.rewrite_files)

    @property
    def rewrite_rows(self) -> int:
        return sum(f.num_rows for f in self.rewrite_files)

    @property
    def est_reclaim_bytes(self) -> float:
        """Priced benefit: dropped files reclaim everything; rewrites
        reclaim their estimated selectivity."""
        sel = getattr(self.op, "est_selectivity", 0.0)
        return self.drop_bytes + sel * self.rewrite_bytes


def route_delete(table: LogStructuredTable, op,
                 now: Optional[float] = None) -> DeleteRoute:
    """Decide, per current file, which tier serves it.

    Decision table (see ``lst/README.md`` for worked examples):

      op kind           file evidence                     tier
      ----------------  --------------------------------  -----------------
      RetentionPolicy   partition in drop_partitions      file-level drop
      RetentionPolicy   created_at older than max_age     file-level drop
      RetentionPolicy   neither                           keep (no action)
      PredicateDelete   file_predicate(f) is True         file-level drop
      PredicateDelete   file_predicate(f) is False        keep (no action)
      PredicateDelete   file_predicate(f) is None / unset rewrite-delete
    """
    now = table.now_fn() if now is None else now
    drops: List[DataFile] = []
    rewrites: List[DataFile] = []
    for f in table.current_files():
        if isinstance(op, RetentionPolicy):
            if op.matches_file(f, now):
                drops.append(f)
            continue
        verdict = op.file_predicate(f) if op.file_predicate is not None \
            else None
        if verdict is True:
            drops.append(f)
        elif verdict is None:
            rewrites.append(f)
    return DeleteRoute(op=op, table_id=table.table_id,
                       file_drops=tuple(drops), rewrite_files=tuple(rewrites))


def plan_rewrite_delete(table: LogStructuredTable,
                        files: Sequence[DataFile],
                        target_bytes: int) -> List[CompactionTask]:
    """Bin the tier-2 files into rewrite tasks. Execution never crosses
    partitions (same rule as compaction); every matched file is planned —
    no small-file cutoff, single-file bins allowed, an over-target file
    gets its own bin. Task IDs are plan-scoped (NFR2)."""
    by_part = {}
    for f in files:
        by_part.setdefault(f.partition or "", []).append(f)
    tasks: List[CompactionTask] = []
    for part in sorted(by_part):
        group = sorted(by_part[part], key=lambda f: (-f.size_bytes, f.path))
        bins: List[List[DataFile]] = []
        sizes: List[int] = []
        for f in group:
            for i, s in enumerate(sizes):
                if s + f.size_bytes <= target_bytes:
                    bins[i].append(f)
                    sizes[i] += f.size_bytes
                    break
            else:
                bins.append([f])
                sizes.append(f.size_bytes)
        for b, s in zip(bins, sizes):
            tasks.append(CompactionTask(len(tasks) + 1, table.table_id,
                                        part or None, tuple(b), s))
    return tasks


def execute_file_drops(table: LogStructuredTable,
                       files: Sequence[DataFile],
                       max_retries: int = 2,
                       interleave_fn: Optional[Callable] = None
                       ) -> CompactionResult:
    """Tier-1 executor: commit ONE ``delete`` snapshot removing the planned
    entries. Zero bytes rewritten, zero GBHr — the whole point of routing
    boundary-aligned deletes here.

    Concurrent-writer safety mirrors ``execute_tasks_atomic``'s live-input
    accounting: liveness is recomputed per commit attempt, so a file a
    concurrent writer already removed is neither counted as OUR removal nor
    physically deleted (its blob belongs to whoever removed it — possibly a
    compaction output still referencing those bytes). After the commit,
    blobs are deleted only for paths that are no longer referenced by the
    table: if a concurrent commit re-referenced a planned path between plan
    and commit, the entry is removed by our snapshot rebase but the BLOB
    survives for the re-referencing writer.
    """
    agg = CompactionTask(0, table.table_id, None, tuple(files), 0)
    res = CompactionResult(task=agg, success=False)
    if not files:
        res.success = True
        return res
    scopes = {f.partition or "" for f in files}
    scope = next(iter(scopes)) or None if len(scopes) == 1 else None
    txn = table.new_transaction()         # plan-time basis
    if interleave_fn is not None:
        interleave_fn(table, agg)         # the plan -> commit window
    live_inputs: List[DataFile] = []
    for attempt in range(max_retries + 1):
        # liveness is by ENTRY IDENTITY (path + generation), not path alone:
        # if a concurrent writer dropped a planned file and re-appended a
        # fresh entry at the same path, the planned file is gone — removing
        # the look-alike would delete data the writer just (re)committed
        alive = {(f.path, f.created_at, f.size_bytes)
                 for f in table.current_files()}
        live_inputs = [f for f in agg.inputs
                       if (f.path, f.created_at, f.size_bytes) in alive]
        if not live_inputs:
            # everything already gone (concurrent writers beat us to it):
            # vacuous success, nothing removed, nothing to clean
            res.success = True
            return res
        try:
            txn.remove_files(live_inputs, scope=scope)
            txn.commit()
            res.success = True
            break
        except CommitConflict:
            res.conflict = True
            res.retries = attempt + 1
            txn = table.new_transaction()  # fresh basis for the retry
    if res.success:
        # physical cleanup: only entries OUR commit removed, and only if no
        # later commit re-referenced the path
        still_live = {f.path for f in table.current_files()}
        for f in live_inputs:
            if f.path not in still_live and table.store.exists(f.path):
                table.store.delete(f.path)
        res.files_removed = len(live_inputs)
        res.bytes_rewritten = 0           # the tier-1 guarantee
        res.rows_dropped = sum(f.num_rows for f in live_inputs)
        res.bytes_reclaimed = sum(f.size_bytes for f in live_inputs)
        res.gbhr = 0.0
    else:
        res.error = (f"retries exhausted after {res.retries} "
                     f"conflicting commit attempts")
    return res
