"""Per-kernel allclose sweeps: shapes x dtypes vs the pure-jnp oracles,
all in interpret mode on CPU."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

KEY = jax.random.PRNGKey(0)


def rand(shape, dtype, k=0):
    x = jax.random.normal(jax.random.fold_in(KEY, k), shape, jnp.float32)
    return x.astype(dtype)


def max_err(a, b):
    return float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))


class TestCompactPack:
    @pytest.mark.parametrize("counts,order", [
        ([1], None),
        ([3, 1, 2], [2, 0, 1]),
        ([8, 8, 8, 8], [3, 2, 1, 0]),
        ([5, 1, 7, 2, 9], [4, 0, 3, 1, 2]),
    ])
    @pytest.mark.parametrize("dtype", [jnp.int32, jnp.float32])
    def test_matches_oracle(self, counts, order, dtype):
        from repro.kernels.compact_pack import compact_chunks, plan_compaction
        from repro.kernels.compact_pack.compact_pack import CHUNK_TOKENS
        cm = plan_compaction(counts, order)
        n = sum(counts) * CHUNK_TOKENS
        src = (jnp.arange(n) % 971).astype(dtype)
        out_k = compact_chunks(src, cm)
        out_r = compact_chunks(src, cm, use_ref=True)
        assert (out_k == out_r).all()

    def test_plan_is_permutation(self):
        from repro.kernels.compact_pack import plan_compaction
        cm = plan_compaction([4, 2, 6], [2, 1, 0])
        assert sorted(cm.tolist()) == list(range(12))


class TestFlashAttention:
    @pytest.mark.parametrize("b,h,hkv,s,d", [
        (1, 4, 4, 128, 64),     # MHA
        (2, 4, 2, 256, 64),     # GQA
        (1, 8, 1, 128, 32),     # MQA
        (1, 4, 2, 256, 128),
    ])
    @pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
    def test_causal_matches_oracle(self, b, h, hkv, s, d, dtype):
        from repro.kernels.flash_attn import flash_attention
        from repro.kernels.flash_attn.ref import flash_attention_ref
        q = rand((b, h, s, d), dtype, 1)
        k = rand((b, hkv, s, d), dtype, 2)
        v = rand((b, hkv, s, d), dtype, 3)
        out = flash_attention(q, k, v, causal=True, block_q=128, block_k=128)
        ref = flash_attention_ref(q, k, v, causal=True)
        tol = 5e-2 if dtype == jnp.bfloat16 else 1e-5
        assert max_err(out, ref) < tol

    @pytest.mark.parametrize("window", [32, 128])
    def test_sliding_window(self, window):
        from repro.kernels.flash_attn import flash_attention
        from repro.kernels.flash_attn.ref import flash_attention_ref
        q = rand((1, 2, 256, 64), jnp.bfloat16, 4)
        k = rand((1, 2, 256, 64), jnp.bfloat16, 5)
        v = rand((1, 2, 256, 64), jnp.bfloat16, 6)
        out = flash_attention(q, k, v, causal=True, window=window,
                              block_q=128, block_k=128)
        ref = flash_attention_ref(q, k, v, causal=True, window=window)
        assert max_err(out, ref) < 5e-2

    def test_non_causal(self):
        from repro.kernels.flash_attn import flash_attention
        from repro.kernels.flash_attn.ref import flash_attention_ref
        q = rand((1, 2, 128, 64), jnp.bfloat16, 7)
        k = rand((1, 2, 128, 64), jnp.bfloat16, 8)
        v = rand((1, 2, 128, 64), jnp.bfloat16, 9)
        out = flash_attention(q, k, v, causal=False, block_q=128, block_k=128)
        ref = flash_attention_ref(q, k, v, causal=False)
        assert max_err(out, ref) < 5e-2


class TestDecodeAttention:
    @pytest.mark.parametrize("b,h,hkv,s,d", [
        (2, 4, 2, 512, 64),
        (4, 8, 8, 256, 64),
        (1, 8, 2, 1024, 128),
    ])
    def test_matches_oracle_ragged_lengths(self, b, h, hkv, s, d):
        from repro.kernels.decode_attn import decode_attention
        from repro.kernels.decode_attn.ref import decode_attention_ref
        q = rand((b, h, d), jnp.bfloat16, 10)
        k = rand((b, s, hkv, d), jnp.bfloat16, 11)
        v = rand((b, s, hkv, d), jnp.bfloat16, 12)
        lens = jnp.asarray(
            np.random.RandomState(0).randint(1, s + 1, size=b), jnp.int32)
        out = decode_attention(q, k, v, lens, block_k=128)
        ref = decode_attention_ref(q, k, v, lens)
        assert max_err(out, ref) < 5e-2


class TestRMSNorm:
    @pytest.mark.parametrize("r,d", [(256, 128), (1024, 512), (128, 1024)])
    @pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
    def test_matches_oracle(self, r, d, dtype):
        from repro.kernels.rmsnorm import rmsnorm
        from repro.kernels.rmsnorm.ref import rmsnorm_ref
        x = rand((r, d), dtype, 13)
        sc = rand((d,), dtype, 14)
        out = rmsnorm(x, sc, block_rows=128)
        ref = rmsnorm_ref(x, sc)
        tol = 1e-1 if dtype == jnp.bfloat16 else 1e-5
        assert max_err(out, ref) < tol

    def test_matches_model_rms_norm(self):
        from repro.kernels.rmsnorm import rmsnorm
        from repro.models.common import rms_norm
        x = rand((64, 64), jnp.bfloat16, 15)
        sc = rand((64,), jnp.bfloat16, 16)
        assert max_err(rmsnorm(x, sc), rms_norm(x, sc)) < 1e-1
