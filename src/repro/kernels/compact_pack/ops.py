"""Public compaction op: plan (host, numpy) + execute (Pallas / oracle).

``plan_compaction`` converts ragged fragment descriptors into the
chunk-permutation consumed by the kernel; ``compact_chunks`` executes it.
The data layer (repro.data.packing) feeds real token shards through this.
"""

from __future__ import annotations

from functools import partial
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.compact_pack.compact_pack import (
    CHUNK_TOKENS, CHUNK_ROWS, CHUNK_COLS, compact_chunks_kernel)
from repro.kernels.compact_pack.ref import compact_chunks_ref


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


def plan_compaction(fragment_chunk_counts: Sequence[int],
                    fragment_order: Sequence[int] | None = None
                    ) -> np.ndarray:
    """Host-side planning: fragments (each a run of chunks laid out
    back-to-back in the source buffer) -> output chunk map.

    fragment_chunk_counts[i]: chunks in source fragment i.
    fragment_order: output order of fragments (default: input order).
    """
    counts = np.asarray(fragment_chunk_counts, dtype=np.int64)
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    order = np.arange(len(counts)) if fragment_order is None \
        else np.asarray(fragment_order)
    out: List[np.ndarray] = [starts[f] + np.arange(counts[f]) for f in order]
    if not out:
        return np.zeros((0,), np.int32)
    return np.concatenate(out).astype(np.int32)


@partial(jax.jit, static_argnames=("interpret",))
def _run(src3, chunk_map, interpret):
    return compact_chunks_kernel(src3, chunk_map, interpret=interpret)


def compact_chunks(src_tokens: jnp.ndarray, chunk_map: np.ndarray,
                   use_ref: bool = False) -> jnp.ndarray:
    """Compact a flat, CHUNK_TOKENS-aligned token buffer.

    src_tokens: (n_chunks * CHUNK_TOKENS,) -- aligned token buffer
    chunk_map:  (n_out,) int32
    returns (n_out * CHUNK_TOKENS,)
    """
    n = src_tokens.shape[0]
    assert n % CHUNK_TOKENS == 0, n
    src3 = src_tokens.reshape(-1, CHUNK_ROWS, CHUNK_COLS)
    cm = jnp.asarray(chunk_map, jnp.int32)
    if use_ref:
        out = compact_chunks_ref(src3, cm)
    else:
        out = _run(src3, cm, _use_interpret())
    return out.reshape(-1)
