"""Qwen3-MoE-30B-A3B [hf:Qwen/Qwen3-30B-A3B; moe].

48L d_model=2048 32H (GQA kv=4) per-expert d_ff=768 vocab=151936,
MoE 128 experts top-8.
"""
from repro.configs import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_ff=0,
    d_ff_expert=768,
    n_experts=128,
    top_k=8,
    vocab=151936,
    head_dim=64,
    rope_theta=1e6,
)
