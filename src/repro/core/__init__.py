"""AutoComp: automated data compaction for log-structured tables (the
paper's contribution), structured as the OODA workflow of Fig. 4:

  candidates -> [observe: stats] -> (filter) -> [orient: traits] -> (filter)
             -> [decide: rank + select] -> [act: schedule + execute]
             -> feedback loop back to observe

Every phase is a pluggable component (NFR1) and every default implementation
is deterministic under identical inputs (NFR2). Nothing here knows about
Iceberg vs. our LST substrate beyond the connector protocol (NFR3).
"""

from repro.core.model import Candidate, CandidateStats, Scope  # noqa: F401
from repro.core.observe import StatsCollector  # noqa: F401
from repro.core.orient import (  # noqa: F401
    ComputeCostTrait, FileCountReductionTrait, FileEntropyTrait, TraitContext,
)
from repro.core.decide import (  # noqa: F401
    BudgetSelection, MoopRanker, ThresholdPolicy, TopKSelection,
    quota_adaptive_weights, select_budget, select_topk,
)
from repro.core.ooda import AutoCompPipeline, CycleReport  # noqa: F401
from repro.core.fleet import (  # noqa: F401
    ClassProfile, FleetCycleReport, FleetScheduler, classify_table,
)
from repro.core.service import AutoCompService  # noqa: F401
