"""Sharded serving on a real multi-device mesh — the serve-side mirror of
tests/test_multidevice.py, run by the CI ``multidevice`` job under
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.

On a forced (data=4, model=2) mesh with the ``serve_sp`` preset: the KV
cache's resolved sharding is data (batch) x model (sequence), the compiled
decode step all-gathers the sequence-sharded cache, and the
``act_transport="int8"`` program moves that gather as s8 chunks + f32
scales — < 1/1.5 the bf16 program's all-gather wire bytes — while greedy
decode stays token-for-token identical to bf16. Skipped below 8 devices
(the plain tier-1 job)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import smoke_config
from repro.dist import sharding as shd
from repro.launch import analysis
from repro.launch.serve import generate
from repro.models import transformer
from repro.train import step as step_lib

pytestmark = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")

DATA, MODEL = 4, 2
BATCH, TOTAL = 8, 512        # decode horizon: cache gather dominates wire


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((DATA, MODEL), ("data", "model"))


@pytest.fixture(scope="module")
def cfg():
    return smoke_config("paper-lm-100m")


RULES = shd.PRESETS["serve_sp"]


class TestServeShardings:
    def test_cache_sharded_over_data_x_sequence(self, mesh, cfg):
        """serve_sp: batch dim -> data, kv_seq dim -> model — read back
        from committed arrays, not just the resolver."""
        cache = transformer.init_cache(cfg, BATCH, TOTAL)
        shards = shd.tree_shardings(
            transformer.abstract_cache(cfg, BATCH, TOTAL),
            transformer.cache_axes(cfg, BATCH, TOTAL), mesh, RULES)
        placed = jax.device_put(cache, shards)
        for name in ("k", "v"):
            leaf = placed[name]      # (layers, B, S, Hkv, hd)
            assert leaf.sharding.spec == P(None, "data", "model")
            local = leaf.addressable_shards[0].data
            assert local.shape == (cfg.n_layers, BATCH // DATA,
                                   TOTAL // MODEL, cfg.n_kv_heads,
                                   cfg.head_dim)

    def test_weights_replicated_over_data(self, mesh, cfg):
        """Serving drops the FSDP embed shard: weights are read-only and
        resident, so no per-token regather dilutes the wire."""
        p_shard = shd.tree_shardings(transformer.abstract_params(cfg),
                                     transformer.param_axes(cfg), mesh, RULES)
        gate_spec = p_shard["layers"]["mlp"]["gate"].spec
        assert "data" not in jax.tree.leaves(tuple(gate_spec))
        assert "model" in jax.tree.leaves(tuple(gate_spec))


def _decode_artifacts(cfg, mesh, act_transport):
    """Compile the serve decode step with explicit serve_sp shardings."""
    p_abs = transformer.abstract_params(cfg)
    p_shard = shd.tree_shardings(p_abs, transformer.param_axes(cfg),
                                 mesh, RULES)
    c_abs = transformer.abstract_cache(cfg, BATCH, TOTAL)
    c_shard = shd.tree_shardings(
        c_abs, transformer.cache_axes(cfg, BATCH, TOTAL), mesh, RULES)
    batch = {"tokens": jax.ShapeDtypeStruct((BATCH, 1), jnp.int32),
             "pos": jax.ShapeDtypeStruct((), jnp.int32)}
    fn = step_lib.make_decode_step(cfg, TOTAL, act_transport)
    jfn = jax.jit(fn, in_shardings=(p_shard, c_shard, None),
                  out_shardings=(None, c_shard))
    with shd.axis_rules(mesh, RULES):
        return jfn.lower(p_abs, c_abs, batch).compile()


class TestInt8ActivationCollectives:
    """The acceptance gate: decode's cache all-gather moves s8 on the wire
    and < 1/1.5 the bf16 bytes, HLO-verified on the (4, 2) mesh."""

    @pytest.fixture(scope="class")
    def artifacts(self, mesh, cfg):
        return {t: _decode_artifacts(cfg, mesh, t)
                for t in ("bf16", "int8")}

    def test_decode_emits_cache_all_gather(self, artifacts):
        """The sequence-sharded cache must be gathered for attention — the
        single-device jit never exercises this."""
        coll = analysis.hlo_collective_bytes(artifacts["bf16"].as_text())
        assert coll["all-gather"]["count"] > 0
        assert coll["all-gather"]["wire_bytes_bf16eq"] > 0

    def test_int8_decode_moves_s8_payloads(self, artifacts):
        hlo = artifacts["int8"].as_text()
        ag = [l for l in hlo.splitlines()
              if "all-gather(" in l and " = " in l and "-done" not in l]
        assert any("s8[" in l for l in ag), \
            "int8 act transport must put s8 payloads on the gather wire"
        coll = analysis.hlo_collective_bytes(hlo)
        s8 = coll["all-gather"]["wire_bytes_bf16eq_s8"]
        assert s8 > 0
        # and the s8 share dominates the int8 program's gather traffic
        assert s8 > coll["all-gather"]["wire_bytes_bf16eq"] / 2

    def test_int8_gather_wire_below_bf16_over_1p5(self, artifacts):
        coll = {t: analysis.hlo_collective_bytes(a.as_text())
                for t, a in artifacts.items()}
        ag = {t: c["all-gather"]["wire_bytes_bf16eq"]
              for t, c in coll.items()}
        assert ag["int8"] <= ag["bf16"] / 1.5, ag
        # the whole program's wire shrinks too (scales + shared traffic in)
        assert coll["int8"]["total_wire_bytes_bf16eq"] \
            < coll["bf16"]["total_wire_bytes_bf16eq"]

    def test_bf16_baseline_keeps_raw_payload(self, artifacts):
        hlo = artifacts["bf16"].as_text()
        ag = [l for l in hlo.splitlines()
              if "all-gather(" in l and " = " in l and "-done" not in l]
        assert not any("s8[" in l for l in ag)


class TestPrefillActivationGather:
    def test_prefill_int8_gathers_s8(self, mesh, cfg):
        """Prefill's sp residual-stream gather (sequence-sharded post-norm
        activations -> full sequence for attention) carries s8 under the
        int8 transport."""
        p_abs = transformer.abstract_params(cfg)
        p_shard = shd.tree_shardings(p_abs, transformer.param_axes(cfg),
                                     mesh, RULES)
        batch = {"tokens": jax.ShapeDtypeStruct((BATCH, 64), jnp.int32)}
        fn = step_lib.make_prefill_step(cfg, "int8")
        jfn = jax.jit(fn, in_shardings=(p_shard, None))
        with shd.axis_rules(mesh, RULES):
            hlo = jfn.lower(p_abs, batch).compile().as_text()
        coll = analysis.hlo_collective_bytes(hlo)
        assert coll["all-gather"]["wire_bytes_bf16eq_s8"] > 0


class TestGreedyEquivalence:
    @pytest.fixture(scope="class")
    def setup(self, cfg):
        params = transformer.init_params(cfg, jax.random.PRNGKey(0))
        rng = np.random.RandomState(0)
        prompts = rng.randint(0, cfg.vocab, size=(8, 16)).astype(np.int32)
        lens = rng.randint(8, 17, size=(8,)).astype(np.int32)
        return params, prompts, lens

    def test_int8_greedy_token_identical_to_bf16(self, mesh, cfg, setup):
        """The acceptance criterion: on the smoke config the quantized
        activation gather must not flip a single greedy token."""
        params, prompts, lens = setup
        outs = {t: generate(cfg, params, prompts, max_new=12,
                            prompt_lens=lens, mesh=mesh, act_transport=t)
                for t in ("bf16", "int8")}
        assert (outs["bf16"] == outs["int8"]).all(), outs

    def test_mesh_serving_tracks_single_device(self, mesh, cfg, setup):
        """Mesh placement is a layout change, not a model change: most rows
        must match the single-device run exactly (argmax near-ties under a
        different reduction order may flip an occasional row, which then
        compounds — so gate on row agreement, not full equality)."""
        params, prompts, lens = setup
        single = generate(cfg, params, prompts, max_new=12, prompt_lens=lens)
        meshed = generate(cfg, params, prompts, max_new=12, prompt_lens=lens,
                          mesh=mesh)
        rows_equal = (single == meshed).all(axis=1)
        assert rows_equal.mean() >= 0.5, (single, meshed)
