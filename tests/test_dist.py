"""Distribution layer: logical-rule resolution with divisibility fallback,
sharding trees, int8 compressed collectives (hypothesis error bounds)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.dist import sharding as shd
from repro.dist.collectives import dequantize_int8, quantize_int8
from repro.launch.mesh import make_local_mesh


@pytest.fixture(scope="module")
def mesh():
    return make_local_mesh()   # 1 CPU device -> (1, 1) mesh


class TestResolveSpec:
    def test_basic_mapping(self, mesh):
        spec = shd.resolve_spec((16, 64), ("batch", "mlp"), mesh)
        assert isinstance(spec, P)

    def test_divisibility_fallback(self):
        """An axis that doesn't divide the mesh size stays unsharded
        instead of failing (e.g. yi-34b's 56 heads on model=16)."""
        from jax.sharding import AbstractMesh
        mesh16 = AbstractMesh((16, 16), ("data", "model"))
        spec = shd.resolve_spec((56, 64, 128), ("heads", "batch", "mlp"),
                                mesh16)
        # heads=56 not divisible by 16 -> None; batch 64 -> data; mlp -> model
        assert spec == P(None, "data", "model")

    def test_production_mesh_rules_on_abstract_mesh(self):
        from jax.sharding import AbstractMesh
        mesh = AbstractMesh((2, 16, 16), ("pod", "data", "model"))
        spec = shd.resolve_spec((256, 4096), ("batch", None), mesh)
        assert spec == P(("pod", "data"))
        spec = shd.resolve_spec((94, 4096, 64, 64),
                                ("layers", "embed", "heads", "head_dim"),
                                mesh)
        assert spec == P(None, "data", "model")

    def test_mesh_axis_used_once(self, mesh):
        spec = shd.resolve_spec((8, 8), ("embed", "embed"), mesh)
        entries = [e for e in spec if e is not None]
        flat = []
        for e in entries:
            flat.extend(e if isinstance(e, tuple) else [e])
        assert len(flat) == len(set(flat))

    def test_constrain_noop_without_context(self):
        x = jnp.ones((4, 4))
        assert shd.constrain(x, "batch", "embed") is x

    def test_constrain_applies_in_context(self, mesh):
        x = jnp.ones((4, 4))
        with shd.axis_rules(mesh):
            y = jax.jit(lambda t: shd.constrain(t, "batch", None))(x)
        assert y.shape == x.shape

    def test_tree_shardings_structure(self, mesh):
        abs_tree = {"w": jax.ShapeDtypeStruct((8, 16), jnp.float32)}
        axes = {"w": ("embed", "mlp")}
        out = shd.tree_shardings(abs_tree, axes, mesh)
        assert set(out) == {"w"}


class TestPresets:
    def _mesh(self):
        from jax.sharding import AbstractMesh
        return AbstractMesh((2, 16, 16), ("pod", "data", "model"))

    def test_registry_has_all_presets(self):
        assert {"baseline", "sp", "ddp", "ep", "fsdp"} <= set(shd.PRESETS)

    def test_ep_distributes_experts_over_data(self):
        """qwen3-30b w_gate (128 experts, 2048, 768): EP puts whole experts
        on the data axis and keeps tensor parallelism inside the expert —
        baseline instead burns the model axis on the expert dim."""
        mesh = self._mesh()
        axes = ("experts", "embed", "expert_mlp")
        ep = shd.resolve_spec((128, 2048, 768), axes, mesh,
                              shd.PRESETS["ep"])
        assert ep == P("data", None, "model")
        base = shd.resolve_spec((128, 2048, 768), axes, mesh,
                                shd.PRESETS["baseline"])
        assert base == P("model", "data")   # expert_mlp left unsharded

    def test_fsdp_shards_weights_over_pod(self):
        mesh = self._mesh()
        spec = shd.resolve_spec((151936, 4096), ("vocab", "embed"), mesh,
                                shd.PRESETS["fsdp"])
        assert spec == P("model", ("pod", "data"))
        base = shd.resolve_spec((151936, 4096), ("vocab", "embed"), mesh,
                                shd.PRESETS["baseline"])
        assert base == P("model", "data")   # baseline stops at the pod edge

    def test_new_presets_keep_each_axis_once(self):
        mesh = self._mesh()
        for preset in ("ep", "fsdp"):
            spec = shd.resolve_spec((256, 4096, 64, 64),
                                    ("batch", "embed", "heads", "head_dim"),
                                    mesh, shd.PRESETS[preset])
            flat = []
            for e in spec:
                if e is not None:
                    flat.extend(e if isinstance(e, tuple) else [e])
            assert len(flat) == len(set(flat))


class TestCompressedCollectives:
    @given(st.integers(min_value=1, max_value=2000),
           st.floats(min_value=0.01, max_value=100.0))
    @settings(max_examples=25, deadline=None)
    def test_quantize_roundtrip_error_bound(self, n, scale):
        rng = np.random.RandomState(0)
        x = jnp.asarray(rng.randn(n) * scale, jnp.float32)
        q, s = quantize_int8(x, block=256)
        out = dequantize_int8(q, s, n)
        max_abs = float(jnp.max(jnp.abs(x)))
        # blockwise symmetric int8: error <= block_max / 127 per element
        assert float(jnp.max(jnp.abs(out - x))) <= max_abs / 127.0 + 1e-6

    def test_error_feedback_reduces_bias(self):
        """Accumulated error feedback keeps the long-run mean unbiased."""
        rng = np.random.RandomState(1)
        from repro.dist.collectives import compressed_psum
        # emulate psum on a single device (axis over dummy mesh of size 1)
        mesh = make_local_mesh()

        @jax.jit
        def step(x, err):
            q, s = quantize_int8(x + err, block=64)
            deq = dequantize_int8(q, s, x.shape[0])
            return deq, (x + err) - deq

        x = jnp.asarray(rng.randn(512), jnp.float32)
        err = jnp.zeros_like(x)
        acc = jnp.zeros_like(x)
        for _ in range(16):
            out, err = step(x, err)
            acc = acc + out
        # with error feedback the accumulated sum converges to 16*x
        rel = float(jnp.linalg.norm(acc - 16 * x) / jnp.linalg.norm(16 * x))
        assert rel < 0.02
