"""Decide phase (§4.3): rank candidates and select work.

Two regimes, exactly as the paper:

* Unconstrained: ``ThresholdPolicy`` — act immediately when a trait crosses
  a threshold (e.g. estimated file-count reduction >= 10%).
* Resource-constrained: ``MoopRanker`` — min-max normalize each trait across
  the pool, scalarize with a weighted sum (benefits positive, costs
  negative), rank descending; then ``select_topk`` / ``select_budget``
  (greedy fit into a GBHr budget).

All ranking is deterministic (NFR2): ties break on (-score, table_id,
partition).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.core.model import Candidate


def minmax_normalize(cands: Sequence[Candidate], trait_names: Sequence[str]
                     ) -> None:
    """T'_{i,c} = (T_{i,c} - min T_i) / (max T_i - min T_i), in [0, 1]."""
    for name in trait_names:
        vals = [c.traits.get(name, 0.0) for c in cands]
        lo, hi = (min(vals), max(vals)) if vals else (0.0, 0.0)
        span = hi - lo
        for c in cands:
            c.normalized[name] = 0.0 if span <= 0 else \
                (c.traits.get(name, 0.0) - lo) / span


FLEET_NORM_TRAITS = ("file_count_reduction", "reclaim_bytes", "compute_cost")


def pooled_benefit(c: Candidate) -> float:
    """Benefit of a pooled (fleet) candidate: normalized file-count
    reduction PLUS normalized reclaimed bytes.

    The reclaim term is the rewrite-delete pricing fix: a delete
    candidate's value is the rows/bytes it removes from the table, and a
    drop-heavy candidate — a GDPR rewrite over two large files, a
    retention drop of one cold partition — may barely reduce the file
    count at all. Scoring benefit on ``file_count_reduction`` alone priced
    such candidates near zero, so they never won the shared budget against
    ordinary compaction no matter how many bytes they reclaimed. Pools
    without any ``reclaim_bytes`` trait are unaffected: min-max
    normalization maps the all-absent trait to 0 for every candidate."""
    return (c.normalized.get("file_count_reduction", 0.0)
            + c.normalized.get("reclaim_bytes", 0.0))


@dataclasses.dataclass
class ThresholdPolicy:
    """Unconstrained regime: fire when ``trait >= threshold`` (absolute) or,
    with ``relative_to``, when trait/denominator >= threshold."""
    trait: str
    threshold: float
    relative_to: Optional[str] = None    # e.g. "file_count"

    def triggered(self, c: Candidate) -> bool:
        val = c.traits.get(self.trait, 0.0)
        if self.relative_to:
            denom = float(getattr(c.stats, self.relative_to, 0) or 0)
            if denom <= 0:
                return False
            val = val / denom
        return val >= self.threshold

    def decide(self, cands: Iterable[Candidate]) -> List[Candidate]:
        out = [c for c in cands if self.triggered(c)]
        out.sort(key=lambda c: (-c.traits.get(self.trait, 0.0),) + c.key)
        return out


class MoopRanker:
    """Weighted-sum scalarization of the multi-objective problem:
        S_c = Σ_benefit w_i T'_i  -  Σ_cost w_j T'_j ,  Σ w = 1.
    """

    def __init__(self, weights: Dict[str, float], costs: Sequence[str] = ("compute_cost",)):
        total = sum(weights.values())
        if not 0.999 <= total <= 1.001:
            raise ValueError(f"MOOP weights must sum to 1 (got {total})")
        self.weights = dict(weights)
        self.costs = set(costs)

    def rank(self, cands: Sequence[Candidate]) -> List[Candidate]:
        minmax_normalize(cands, list(self.weights))
        for c in cands:
            s = 0.0
            for name, w in self.weights.items():
                t = c.normalized.get(name, 0.0)
                s += -w * t if name in self.costs else w * t
            c.score = s
        return sorted(cands, key=lambda c: (-c.score,) + c.key)


def quota_adaptive_weights(used_quota: float, total_quota: float,
                           cost_trait: str = "compute_cost",
                           benefit_trait: str = "file_count_reduction"
                           ) -> Dict[str, float]:
    """Production weight adaptation (§7):
        w1 = 0.5 * (1 + UsedQuota/TotalQuota),  w2 = 1 - w1.
    A tenant near its namespace quota gets more aggressive compaction."""
    util = 0.0 if total_quota <= 0 else min(1.0, used_quota / total_quota)
    w1 = 0.5 * (1.0 + util)
    w1 = min(w1, 1.0)
    return {benefit_trait: w1, cost_trait: 1.0 - w1}


def select_topk(ranked: Sequence[Candidate], k: int) -> List[Candidate]:
    return list(ranked[:k])


def select_budget(ranked: Sequence[Candidate], budget_gbhr: float,
                  cost_trait: str = "compute_cost",
                  max_k: Optional[int] = None,
                  unpriced: Optional[List[Candidate]] = None
                  ) -> List[Candidate]:
    """Greedy: fit as many high-priority tasks as possible in the budget
    (§4.3). Deterministic; skips items that don't fit and keeps going.

    A candidate MISSING the cost trait is conservative-skipped (and
    collected into ``unpriced`` when a list is passed): unpriced work must
    never bypass the budget by defaulting to free. An explicit cost of
    0.0 is priced-free and still admissible.
    """
    out: List[Candidate] = []
    spent = 0.0
    for c in ranked:
        cost = c.traits.get(cost_trait)
        if cost is None:
            if unpriced is not None:
                unpriced.append(c)
            continue
        if spent + cost <= budget_gbhr:
            out.append(c)
            spent += cost
        if max_k is not None and len(out) >= max_k:
            break
    return out


# -- injectable selection strategies (the decide tail of the OODA loop) ------
#
# ``AutoCompPipeline`` and ``FleetScheduler`` both end their decide phase in
# one of these objects; the pipeline builds a default from its legacy
# ``top_k``/``budget_gbhr`` knobs, the fleet layer injects a shared-budget
# selection over the pooled candidates of many pipelines.

@dataclasses.dataclass
class TopKSelection:
    """Fixed-k selection (the paper's rollout weeks 3-5)."""
    k: Optional[int] = None

    def select(self, ranked: Sequence[Candidate]) -> List[Candidate]:
        return select_topk(ranked, self.k if self.k is not None
                           else len(ranked))


@dataclasses.dataclass
class BudgetSelection:
    """Dynamic-k under a GBHr budget (§7 week 6+). Records the unpriced
    candidates it conservatively skipped in ``last_unpriced``."""
    budget_gbhr: float
    max_k: Optional[int] = None
    cost_trait: str = "compute_cost"
    last_unpriced: List[Candidate] = dataclasses.field(
        default_factory=list, repr=False)

    def select(self, ranked: Sequence[Candidate]) -> List[Candidate]:
        self.last_unpriced = []
        return select_budget(ranked, self.budget_gbhr,
                             cost_trait=self.cost_trait, max_k=self.max_k,
                             unpriced=self.last_unpriced)
