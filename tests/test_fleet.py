"""Fleet-scale scheduler invariants (core/fleet.py).

Property tests (hypothesis) on the cross-table decide: the shared budget is
conserved, no fragmented table starves past the aging bound, and the pooled
ranking is deterministic under permuted input order (NFR2). Plus the
satellite behaviors this PR wires through the stack: memoized observe
staleness, deferred-candidate requeue, workload classification, and a
~2k-table cycle with sub-linear re-observation.
"""

import itertools

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.act import Scheduler
from repro.core.fleet import (ClassProfile, FleetScheduler, classify_table,
                              build_class_pipeline)
from repro.core.model import Candidate, Scope
from repro.core.observe import StatsCollector
from repro.core.service import AutoCompService, ServiceConfig
from repro.lst import Catalog, InMemoryStore
from repro.lst.files import DataFile
from repro.lst.workload import (ActivityTracker, FleetSpec, QueryEvent,
                                SimClock, WorkloadGenerator, WorkloadSpec)

MB = 1 << 20
_FILE_IDS = itertools.count(1)


def mk_world():
    clock = SimClock()
    store = InMemoryStore()
    return clock, store, Catalog(store, now_fn=clock.now)


def append_small(table, n, size_mb=1.0, partition=None):
    files = []
    for _ in range(n):
        fid = next(_FILE_IDS)
        path = f"{table.table_id}/data/part-{fid:08d}.parquet"
        table.store.put(path, b"x")
        files.append(DataFile(path, int(size_mb * MB), 100, partition))
    table.append(files)
    return files


def mk_fleet_world(n_tables, n_files=10, budget=1.0, **fleet_kw):
    clock, store, catalog = mk_world()
    catalog.create_namespace("db", total_quota=10_000_000)
    tables = []
    for i in range(n_tables):
        t = catalog.create_table("db", f"t{i:03d}", None)
        t.now_fn = clock.now
        append_small(t, n_files)
        tables.append(t)
    fleet = FleetScheduler(catalog, budget_gbhr=budget, **fleet_kw)
    return clock, catalog, tables, fleet


def mk_pool_candidate(i, benefit, cost, unpriced=False):
    """A pool-level candidate with traits pre-set (decide-phase input)."""
    store = InMemoryStore()
    catalog = Catalog(store)
    catalog.create_namespace("p", total_quota=10_000)
    t = catalog.create_table("p", f"t{i:03d}", None)
    append_small(t, 2)
    c = Candidate(t, Scope.TABLE)
    StatsCollector(512 * MB).observe(c)
    c.traits = {"file_count_reduction": float(benefit)}
    if not unpriced:
        c.traits["compute_cost"] = float(cost)
    c.fleet_class = "steady"
    return c


def pool_fleet(**kw):
    _, _, catalog = mk_world()
    return FleetScheduler(catalog, **kw)


pool_strategy = st.lists(
    st.tuples(st.floats(0, 1e4), st.floats(0.01, 10.0),
              st.booleans()),
    min_size=1, max_size=25)


class TestFleetDecide:
    @given(pool_strategy, st.floats(min_value=0.0, max_value=30.0))
    @settings(max_examples=25, deadline=None)
    def test_budget_conservation(self, vals, budget):
        """Invariant: Σ selected compute_cost <= shared budget; unpriced
        candidates are never admitted."""
        fleet = pool_fleet(budget_gbhr=budget)
        pool = [mk_pool_candidate(i, b, c, unpriced=u)
                for i, (b, c, u) in enumerate(vals)]
        _, selected, unpriced = fleet.decide(pool)
        assert sum(c.traits["compute_cost"]
                   for c in selected) <= budget + 1e-9
        assert all("compute_cost" in c.traits for c in selected)
        assert len(unpriced) == sum(1 for _, _, u in vals if u)

    @given(pool_strategy, st.randoms())
    @settings(max_examples=20, deadline=None)
    def test_ranking_permutation_invariant(self, vals, rnd):
        """NFR2: permuting candidate enumeration order never changes the
        fleet's ranking or selection."""
        fleet = pool_fleet(budget_gbhr=5.0)
        a_pool = [mk_pool_candidate(i, b, c, unpriced=u)
                  for i, (b, c, u) in enumerate(vals)]
        b_pool = [mk_pool_candidate(i, b, c, unpriced=u)
                  for i, (b, c, u) in enumerate(vals)]
        rnd.shuffle(b_pool)
        ra, sa, _ = fleet.decide(a_pool)
        rb, sb, _ = fleet.decide(b_pool)
        assert [c.key for c in ra] == [c.key for c in rb]
        assert [c.key for c in sa] == [c.key for c in sb]

    def test_aging_promotes_starved_table(self):
        """A table at the starvation bound jumps ahead of higher-scored
        competitors (hard promotion, not just a score boost)."""
        fleet = pool_fleet(budget_gbhr=100.0, starvation_cycles=3)
        pool = [mk_pool_candidate(0, benefit=1.0, cost=1.0),
                mk_pool_candidate(1, benefit=100.0, cost=1.0)]
        starved = pool[0].table.table_id
        fleet.skip_cycles[starved] = 3
        ranked, _, _ = fleet.decide(pool)
        assert ranked[0].table.table_id == starved

    def test_query_frequency_weights_benefit(self):
        """Equal layouts: the hotter table (higher query_freq) wins."""
        fleet = pool_fleet(budget_gbhr=100.0)
        cold = mk_pool_candidate(0, benefit=10.0, cost=1.0)
        hot = mk_pool_candidate(1, benefit=10.0, cost=1.0)
        tail = mk_pool_candidate(2, benefit=1.0, cost=1.0)
        cold.stats.custom["query_freq"] = 0.1
        hot.stats.custom["query_freq"] = 50.0
        ranked, _, _ = fleet.decide([cold, hot, tail])
        assert ranked[0] is hot


class TestStarvationBound:
    def test_no_table_waits_past_bound(self):
        """Two permanently-hotter tables are refragmented every cycle; the
        budget (max_k) serves only two of four. The two colder tables age
        to the bound, get promoted oldest-first, and are served — no
        fragmented table ever waits longer than starvation_cycles."""
        clock, catalog, tables, fleet = mk_fleet_world(
            4, n_files=10, budget=100.0, max_k=2, starvation_cycles=2)
        for cyc in range(6):
            # keep t000/t001 strictly more fragmented (higher benefit)
            for t in tables[:2]:
                append_small(t, 14)
            rep = fleet.run_cycle()
            clock.advance(1.0)
            assert rep.spent_gbhr <= fleet.budget_gbhr + 1e-9
            assert rep.max_skip_cycles <= fleet.starvation_cycles
        assert fleet.max_skip_ever <= fleet.starvation_cycles
        # the cold pair actually reached the bound and got served via
        # promotion (not coincidentally selected on score)
        assert fleet.max_skip_ever == fleet.starvation_cycles
        assert sum(r.starved_served for r in fleet.reports) >= 2

    def test_deferred_counts_as_unserved(self):
        """A closed off-peak window defers the selection; deferred tables
        keep aging (window closure must not mask starvation)."""
        def factory(profile, activity=None, stats=None):
            return build_class_pipeline(
                profile, activity, stats=stats,
                scheduler=Scheduler(profile.target_file_mb * MB,
                                    offpeak_window=lambda: False))
        clock, catalog, tables, fleet = mk_fleet_world(
            2, budget=100.0, starvation_cycles=3,
            pipeline_factory=factory)
        rep = fleet.run_cycle()
        assert rep.n_selected == 2
        assert len(rep.deferred_keys) == 2
        assert rep.files_removed == 0
        assert all(fleet.skip_cycles[t.table_id] == 1 for t in tables)


class TestMemoizedObserve:
    def test_hit_on_same_snapshot_miss_after_append(self):
        clock, store, catalog = mk_world()
        catalog.create_namespace("db", total_quota=10_000)
        t = catalog.create_table("db", "t0", None)
        append_small(t, 6)
        coll = StatsCollector(512 * MB)
        c = Candidate(t, Scope.TABLE)
        s1 = coll.observe(c)
        s2 = coll.observe(Candidate(t, Scope.TABLE))
        assert (coll.memo_hits, coll.memo_misses) == (1, 1)
        assert s2.file_count == s1.file_count == 6
        # staleness: a commit moves the snapshot -> fresh scan, not the memo
        append_small(t, 3)
        s3 = coll.observe(Candidate(t, Scope.TABLE))
        assert coll.memo_misses == 2
        assert s3.file_count == 9

    def test_activity_stats_never_cached(self):
        """Query frequency moves without a new snapshot; a memo hit must
        still return fresh activity numbers."""
        clock, store, catalog = mk_world()
        catalog.create_namespace("db", total_quota=10_000)
        t = catalog.create_table("db", "t0", None)
        append_small(t, 4)
        tracker = ActivityTracker(now_fn=clock.now)
        coll = StatsCollector(512 * MB, activity=tracker)
        s1 = coll.observe(Candidate(t, Scope.TABLE))
        assert s1.custom["query_freq"] == 0.0
        tracker.record([QueryEvent(0.0, "read", t.table_id)] * 8)
        s2 = coll.observe(Candidate(t, Scope.TABLE))
        assert coll.memo_hits == 1
        assert s2.custom["query_freq"] == pytest.approx(8.0)


class TestClassification:
    def test_classify_from_activity(self):
        clock = SimClock(start=4.0)
        tracker = ActivityTracker(now_fn=clock.now)
        evs = []
        for h in range(4):
            # storm: 6 writes/h x 40 files; steady: 1 write/h x 4 files
            evs += [QueryEvent(float(h), "write", "db/storm",
                               files_written=40)] * 6
            evs += [QueryEvent(float(h), "write", "db/steady",
                               files_written=4),
                    QueryEvent(float(h), "read", "db/steady")]
        # bursty: a trickle across the window, then one concentrated burst
        evs += [QueryEvent(0.0, "write", "db/bursty", files_written=2),
                QueryEvent(1.0, "write", "db/bursty", files_written=2)]
        evs += [QueryEvent(3.5, "write", "db/bursty", files_written=6)] * 8
        evs += [QueryEvent(3.5, "read", "db/bursty")] * 4
        # cold: one tiny write long ago
        evs += [QueryEvent(0.5, "write", "db/cold", files_written=1)]
        tracker.record(evs)

        def cls(tid):
            return classify_table(tracker.read_rate(tid),
                                  tracker.write_file_rate(tid),
                                  tracker.burstiness(tid))
        assert cls("db/storm") == "append-storm"
        assert cls("db/bursty") == "bursty"
        assert cls("db/cold") == "cold"
        assert cls("db/steady") == "steady"

    def test_fleet_groups_by_class_and_applies_profiles(self):
        """cold profile (min_small_files=32) filters a mildly-fragmented
        cold table that the steady profile (8) would have proposed."""
        clock, store, catalog = mk_world()
        catalog.create_namespace("db", total_quota=100_000)
        hot = catalog.create_table("db", "hot", None)
        cold = catalog.create_table("db", "cold", None)
        for t in (hot, cold):
            t.now_fn = clock.now
            append_small(t, 12)
        clock.advance(4.0)
        tracker = ActivityTracker(now_fn=clock.now)
        tracker.record([QueryEvent(float(h), "read", hot.table_id)
                        for h in range(4)] * 2
                       + [QueryEvent(float(h), "write", hot.table_id,
                                     files_written=4) for h in range(4)])
        fleet = FleetScheduler(catalog, budget_gbhr=100.0, activity=tracker)
        rep = fleet.run_cycle()
        assert rep.class_counts == {"cold": 1, "steady": 1}
        sel_tables = {k[0] for k in rep.selected_keys}
        assert hot.table_id in sel_tables
        assert cold.table_id not in sel_tables     # filtered by its profile


class TestTuneProfile:
    def test_hillclimb_installs_winner(self):
        fleet = pool_fleet(budget_gbhr=10.0)

        def evaluate(profile):
            # favor fine-grained eager compaction, deterministically
            return (profile.min_small_files
                    + (0.0 if profile.scope == "hybrid" else 5.0)
                    + profile.target_file_mb / 512.0)

        best, res = fleet.tune_profile("steady", evaluate)
        assert best.min_small_files == 2
        assert best.scope == "hybrid"
        assert best.target_file_mb == 128
        assert fleet.profiles["steady"] == best
        assert fleet.pipelines["steady"].hybrid
        # warm start came from the incumbent profile
        assert res.history[0][0]["min_small_files"] == 8

    def test_set_profile_shares_collector_per_target(self):
        fleet = pool_fleet(budget_gbhr=10.0)
        same = fleet.pipelines["steady"].stats
        fleet.set_profile(ClassProfile("steady", min_small_files=2))
        assert fleet.pipelines["steady"].stats is same
        assert fleet.pipelines["cold"].stats is same   # same 512MB target


class TestServiceRequeue:
    def test_deferred_tables_reenter_next_cycle(self):
        """after_write mode: a deferred selection is requeued even though
        the table is no longer dirty."""
        clock, store, catalog = mk_world()
        catalog.create_namespace("db", total_quota=100_000)
        t = catalog.create_table("db", "t0", None)
        t.now_fn = clock.now
        window = {"open": False}
        profile = ClassProfile("steady", scope="table", min_small_files=4)
        pipe = build_class_pipeline(
            profile, scheduler=Scheduler(512 * MB,
                                         offpeak_window=lambda: window["open"]))
        svc = AutoCompService(catalog, pipe,
                              ServiceConfig(interval_hours=1.0,
                                            mode="after_write"),
                              now_fn=clock.now)
        append_small(t, 10)                 # marks dirty via notify_write?
        catalog.notify_write(t)
        clock.advance(1.0)
        rep1 = svc.tick()
        assert len(rep1.deferred_keys) == 1
        assert rep1.files_removed == 0
        # no new writes; the requeue alone brings the table back
        window["open"] = True
        clock.advance(1.0)
        rep2 = svc.tick()
        assert rep2.n_selected == 1
        assert rep2.files_removed > 0
        assert svc.totals()["deferred"] == 1


class TestFleetScale:
    def test_2k_table_cycle_sublinear_reobserve(self):
        """Acceptance: a ~2k-table fleet runs full cycles; the second
        cycle re-scans only the tables whose snapshot moved (memo), and
        every cycle's selection respects the shared budget. The budget is
        deliberately tight so cycle 1 compacts only a sliver of the fleet
        and cycle 2's hit rate is attributable to the memo, not to an
        empty pool."""
        clock = SimClock()
        store = InMemoryStore()
        catalog = Catalog(store, now_fn=clock.now)
        gen = WorkloadGenerator(catalog, WorkloadSpec(seed=0), clock)
        gen.setup_fleet(FleetSpec(n_tables=2000, seed=0))
        fleet = FleetScheduler(catalog, budget_gbhr=0.05)
        rep1 = fleet.run_cycle()
        assert rep1.n_tables == 2000
        assert 0 < rep1.spent_gbhr <= 0.05 + 1e-9
        misses_c1 = sum(c.memo_misses for c in fleet._collectors.values())
        rep2 = fleet.run_cycle()
        assert rep2.spent_gbhr <= 0.05 + 1e-9
        misses_c2 = sum(c.memo_misses for c in fleet._collectors.values())
        hits_c2 = sum(c.memo_hits for c in fleet._collectors.values())
        # nothing ingested between cycles: only tables compacted in cycle 1
        # moved, so cycle 2 is nearly all memo hits
        assert misses_c2 - misses_c1 < 0.1 * rep2.n_candidates
        assert hits_c2 > 0.9 * rep2.n_candidates
