"""Disaggregated prefill/decode serving on a real multi-device mesh — the
CI ``multidevice`` job runs this under
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.

Prefill compiles sequence-parallel (``serve_sp``) on its own mesh, decode
batch-heavy (``serve_decode``) on a disjoint mesh, and the KV cache is
handed off between them — whole-batch (raw bf16 or a seq-blockwise int8
stream, ``--cache-transfer``) or continuously per request
(``--stream slots``: slot admission into a running decode batch), with
orthogonal int8/f8 *resident* storage arms (``--kv-storage``).
Assertions mirror the acceptance criteria: resolved decode-side
shardings, s8 on the transfer wire (< bf16/1.5, HLO-parsed),
token-for-token colocated-vs-slot-streamed equivalence for the bf16
stream (slots freed and reused without cross-request bleed), logit
tolerance for int8/f8 storage, f8 residency exactly half of bf16, and
the full transfer x storage x block dryrun report (per-slot wire,
overlap fractions, tuned point). Skipped below 8 devices."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import smoke_config
from repro.dist import sharding as shd
from repro.launch import analysis
from repro.launch import serve
from repro.models import transformer
from repro.train import step as step_lib

pytestmark = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")

BATCH, TOTAL = 8, 512


@pytest.fixture(scope="module")
def cfg():
    return smoke_config("paper-lm-100m")


@pytest.fixture(scope="module")
def mesh():
    """The colocated (4, 2) mesh of the acceptance criteria."""
    return jax.make_mesh((4, 2), ("data", "model"))


@pytest.fixture(scope="module")
def disagg_meshes(cfg):
    return serve.make_disagg_meshes(cfg)


@pytest.fixture(scope="module")
def setup(cfg):
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    prompts = rng.randint(0, cfg.vocab, size=(8, 16)).astype(np.int32)
    lens = rng.randint(8, 17, size=(8,)).astype(np.int32)
    return params, prompts, lens


class TestDisaggMeshes:
    def test_meshes_are_disjoint_halves(self, disagg_meshes):
        pre, dec = disagg_meshes
        pre_ids = {d.id for d in pre.devices.flat}
        dec_ids = {d.id for d in dec.devices.flat}
        assert pre_ids.isdisjoint(dec_ids)
        assert len(pre_ids) == len(dec_ids) == jax.device_count() // 2

    def test_serve_decode_cache_resident_not_seq_sharded(self, cfg,
                                                         disagg_meshes):
        """serve_decode: batch -> data, sequence REPLICATED (no per-step
        cache gather) — read back from committed arrays."""
        _, dec = disagg_meshes
        rules = shd.PRESETS["serve_decode"]
        cache = transformer.init_cache(cfg, BATCH, TOTAL)
        shards = shd.tree_shardings(
            transformer.abstract_cache(cfg, BATCH, TOTAL),
            transformer.cache_axes(cfg, BATCH, TOTAL), dec, rules)
        placed = jax.device_put(cache, shards)
        data = dec.shape["data"]
        for name in ("k", "v"):
            leaf = placed[name]          # (layers, B, S, Hkv, hd)
            assert leaf.sharding.spec == P(None, "data")
            local = leaf.addressable_shards[0].data
            # full sequence resident per batch shard
            assert local.shape[1:3] == (BATCH // data, TOTAL)


def _transfer_hlo(cfg, mesh, mode):
    c_abs = transformer.abstract_cache(cfg, BATCH, TOTAL)
    c_axes = transformer.cache_axes(cfg, BATCH, TOTAL)
    pre = shd.tree_shardings(c_abs, c_axes, mesh, shd.PRESETS["serve_sp"])
    dec = shd.tree_shardings(c_abs, c_axes, mesh,
                             shd.PRESETS["serve_decode"])
    fn = serve.make_cache_transfer_step(cfg, BATCH, TOTAL, mode)
    with shd.axis_rules(mesh, shd.PRESETS["serve_decode"]):
        return jax.jit(fn, in_shardings=(pre,), out_shardings=dec
                       ).lower(c_abs).compile().as_text()


class TestCacheStreamWire:
    """The transfer acceptance gate: the serve_sp -> serve_decode cache
    reshard moves s8 under the int8 stream, < 1/1.5 the bf16 wire."""

    @pytest.fixture(scope="class")
    def coll(self, cfg, mesh):
        return {t: analysis.hlo_collective_bytes(_transfer_hlo(cfg, mesh, t))
                for t in ("bf16", "int8")}

    def test_bf16_transfer_reshards_and_moves_no_s8(self, coll):
        assert coll["bf16"]["total_wire_bytes_bf16eq"] > 0
        assert coll["bf16"]["total_wire_bytes_bf16eq_s8"] == 0

    def test_int8_transfer_wire_is_mostly_s8(self, coll):
        s8 = coll["int8"]["total_wire_bytes_bf16eq_s8"]
        assert s8 > 0
        assert s8 > coll["int8"]["total_wire_bytes_bf16eq"] / 2

    def test_int8_transfer_below_bf16_over_1p5(self, coll):
        wire = {t: c["total_wire_bytes_bf16eq"] for t, c in coll.items()}
        assert wire["int8"] <= wire["bf16"] / 1.5, wire


class TestDisaggEquivalence:
    def test_bf16_stream_token_identical_to_colocated(self, cfg, mesh,
                                                      disagg_meshes, setup):
        """The acceptance criterion: splitting prefill/decode onto
        separate meshes (bf16 handoff) must not flip a single greedy
        token vs colocated serve_sp serving."""
        params, prompts, lens = setup
        pre, dec = disagg_meshes
        colo = serve.generate(cfg, params, prompts, max_new=12,
                              prompt_lens=lens, mesh=mesh)
        dis = serve.generate(cfg, params, prompts, max_new=12,
                             prompt_lens=lens, mesh=pre, decode_mesh=dec)
        assert (colo == dis).all(), (colo, dis)

    def test_int8_stream_int8_storage_tracks_bf16(self, cfg, disagg_meshes,
                                                  setup):
        """The fully quantized pipeline (s8 handoff + s8-resident cache)
        is lossy; on the smoke config it must still agree on (almost)
        every row with the bf16 pipeline."""
        params, prompts, lens = setup
        pre, dec = disagg_meshes
        base = serve.generate(cfg, params, prompts, max_new=12,
                              prompt_lens=lens, mesh=pre, decode_mesh=dec)
        quant = serve.generate(cfg, params, prompts, max_new=12,
                               prompt_lens=lens, mesh=pre, decode_mesh=dec,
                               cache_transfer="int8", kv_storage="int8")
        rows_equal = (base == quant).all(axis=1)
        assert rows_equal.mean() >= 0.5, (base, quant)


class TestInt8StorageLogits:
    def test_int8_storage_matches_bf16_logits(self, cfg, mesh):
        """kv_storage="int8" decode matches the bf16-resident decode's
        logits within quantization tolerance, on the decode mesh."""
        params = transformer.init_params(cfg, jax.random.PRNGKey(1))
        b, s0, total = 8, 16, 32
        rules = shd.PRESETS["serve_decode"]
        prompts = np.random.RandomState(1).randint(
            0, cfg.vocab, size=(b, s0)).astype(np.int32)
        with shd.axis_rules(mesh, rules):
            p_shard = shd.tree_shardings(transformer.abstract_params(cfg),
                                         transformer.param_axes(cfg),
                                         mesh, rules)
            placed = jax.device_put(params, p_shard)
            _, cache = jax.jit(step_lib.make_prefill_step(cfg))(
                placed, {"tokens": jnp.asarray(prompts)})
            cache = serve.grow_cache(
                cache, transformer.abstract_cache(cfg, b, total))
            tok = jnp.full((b, 1), 7, jnp.int32)
            batch = {"tokens": tok, "pos": jnp.asarray(s0, jnp.int32)}
            logits = {}
            for storage in ("bf16", "int8"):
                c = cache
                if storage == "int8":
                    c = jax.jit(transformer.quantize_cache_int8)(cache)
                fn = step_lib.make_decode_step(cfg, total, "bf16", storage)
                lg, _ = jax.jit(fn)(placed, c, batch)
                logits[storage] = np.asarray(lg, np.float32)
        diff = np.abs(logits["bf16"] - logits["int8"]).max()
        scale = max(np.abs(logits["bf16"]).max(), 1.0)
        assert diff / scale < 0.05, diff
        agree = (logits["bf16"].argmax(-1) == logits["int8"].argmax(-1))
        assert agree.mean() >= 0.9


class TestSlotStreaming:
    """Continuous cross-batch disaggregation on the real meshes: the
    acceptance criterion — slot-streamed serving (bf16 stream) produces
    greedy tokens identical to colocated serving, slots are freed and
    reused across admissions without cross-request cache bleed."""

    def test_slot_stream_token_identical_to_colocated(self, cfg, mesh,
                                                      disagg_meshes, setup):
        params, prompts, lens = setup
        pre, dec = disagg_meshes
        colo = serve.generate(cfg, params, prompts, max_new=12,
                              prompt_lens=lens, mesh=mesh)
        slot = serve.generate(cfg, params, prompts, max_new=12,
                              prompt_lens=lens, mesh=pre, decode_mesh=dec,
                              stream="slots")
        assert (colo == slot).all(), (colo, slot)

    def test_slots_freed_and_reused_without_bleed(self, cfg, mesh,
                                                  disagg_meshes, setup):
        """slots=3 < batch=8 forces five admissions into freed rows —
        every later occupant's tokens must still match the whole-batch
        run (admission overwrites the entire slot row, so no trace of
        the previous request survives)."""
        params, prompts, lens = setup
        pre, dec = disagg_meshes
        colo = serve.generate(cfg, params, prompts, max_new=12,
                              prompt_lens=lens, mesh=mesh)
        slot = serve.generate(cfg, params, prompts, max_new=12,
                              prompt_lens=lens, mesh=pre, decode_mesh=dec,
                              stream="slots", slots=3)
        assert (colo == slot).all(), (colo, slot)
        assert serve._generate_slots.last_stats["admissions"] == 8

    def test_quantized_slot_pipeline_tracks_bf16(self, cfg, disagg_meshes,
                                                 setup):
        """The fully continuous quantized pipeline — s8 slice stream into
        an f8-resident running cache — stays row-wise close to bf16."""
        params, prompts, lens = setup
        pre, dec = disagg_meshes
        base = serve.generate(cfg, params, prompts, max_new=12,
                              prompt_lens=lens, mesh=pre, decode_mesh=dec,
                              stream="slots")
        quant = serve.generate(cfg, params, prompts, max_new=12,
                               prompt_lens=lens, mesh=pre, decode_mesh=dec,
                               stream="slots", cache_transfer="int8",
                               kv_storage="f8")
        rows_equal = (base == quant).all(axis=1)
        assert rows_equal.mean() >= 0.5, (base, quant)


class TestF8StorageOnMesh:
    def test_f8_storage_matches_bf16_logits(self, cfg, mesh):
        """kv_storage="f8" decode matches the bf16-resident decode's
        logits within e4m3 tolerance, on the decode mesh."""
        params = transformer.init_params(cfg, jax.random.PRNGKey(1))
        b, s0, total = 8, 16, 32
        rules = shd.PRESETS["serve_decode"]
        prompts = np.random.RandomState(1).randint(
            0, cfg.vocab, size=(b, s0)).astype(np.int32)
        with shd.axis_rules(mesh, rules):
            p_shard = shd.tree_shardings(transformer.abstract_params(cfg),
                                         transformer.param_axes(cfg),
                                         mesh, rules)
            placed = jax.device_put(params, p_shard)
            _, cache = jax.jit(step_lib.make_prefill_step(cfg))(
                placed, {"tokens": jnp.asarray(prompts)})
            cache = serve.grow_cache(
                cache, transformer.abstract_cache(cfg, b, total))
            tok = jnp.full((b, 1), 7, jnp.int32)
            batch = {"tokens": tok, "pos": jnp.asarray(s0, jnp.int32)}
            logits = {}
            for storage in ("bf16", "f8"):
                c = jax.jit(lambda x, s=storage:
                            transformer.quantize_cache(x, s))(cache)
                fn = step_lib.make_decode_step(cfg, total, "bf16", storage)
                lg, _ = jax.jit(fn)(placed, c, batch)
                logits[storage] = np.asarray(lg, np.float32)
        diff = np.abs(logits["bf16"] - logits["f8"]).max()
        scale = max(np.abs(logits["bf16"]).max(), 1.0)
        assert diff / scale < 0.08, diff
        agree = (logits["bf16"].argmax(-1) == logits["f8"].argmax(-1))
        assert agree.mean() >= 0.9


class TestDisaggDryrunReport:
    @pytest.fixture(scope="class")
    def report(self, cfg, mesh):
        return serve.disagg_decode_report(cfg, BATCH, TOTAL, mesh,
                                          blocks=(256, 128))

    def test_all_six_combinations_reported(self, report):
        assert set(report["cells"]) == {
            f"{t}x{s}" for t in ("bf16", "int8")
            for s in ("bf16", "int8", "f8")}
        assert report["unsupported_storage"] == []
        for cell in report["cells"].values():
            assert cell["collective_s"] >= 0
            assert cell["cache_resident_bytes_per_device"] > 0
            assert 0.0 <= cell["slot_stream_overlap_frac"] <= 1.0

    def test_quantized_storage_shrinks_resident_bytes(self, report):
        cells = report["cells"]
        bf16 = cells["bf16xbf16"]["cache_resident_bytes_per_device"]
        assert cells["bf16xint8"]["cache_resident_bytes_per_device"] < bf16
        # f8 is scale-free: exactly half the bf16 bytes — the acceptance
        # criterion's residency claim
        assert cells["bf16xf8"]["cache_resident_bytes_per_device"] \
            == bf16 // 2

    def test_int8_transfer_shrinks_transfer_wire(self, report):
        cells = report["cells"]
        assert cells["int8xbf16"]["transfer_wire_bytes_bf16eq"] \
            <= cells["bf16xbf16"]["transfer_wire_bytes_bf16eq"] / 1.5
        assert cells["int8xbf16"]["transfer_wire_bytes_bf16eq_s8"] > 0

    def test_slot_stream_wire_is_per_request_sized(self, report):
        """The per-slot admission program ships ONE request's slice: its
        wire is ~1/BATCH of the whole-batch transfer, s8-dominant under
        the int8 stream and < bf16/1.5."""
        ss = report["slot_stream"]
        cells = report["cells"]
        for t in ("bf16", "int8"):
            assert 0 < ss[t]["wire_bytes_bf16eq"] \
                <= cells[f"{t}xbf16"]["transfer_wire_bytes_bf16eq"] / 2
        assert ss["int8"]["wire_bytes_bf16eq_s8"] \
            > ss["int8"]["wire_bytes_bf16eq"] / 2
        assert ss["int8"]["wire_bytes_bf16eq"] \
            <= ss["bf16"]["wire_bytes_bf16eq"] / 1.5

    def test_block_sweep_and_tuned_point(self, report):
        """Smaller stream blocks mean more f32 scales on the wire; the
        hillclimb's pick is a member of the swept space."""
        sweep = report["block_sweep"]["int8"]
        assert set(sweep) == {128, 256}
        assert sweep[128]["transfer_wire_bytes_bf16eq"] \
            >= sweep[256]["transfer_wire_bytes_bf16eq"]
        tuned = report["tuned"]
        assert tuned["point"]["cache_transfer"] in ("bf16", "int8")
        assert tuned["point"]["kv_storage"] in ("bf16", "int8", "f8")
        assert tuned["point"]["block"] in (128, 256)
        assert tuned["collective_s"] > 0
        assert tuned["evaluations"] >= 1


class TestFanInOnMesh:
    """The fan-in acceptance criterion on the forced 8-device mesh:
    paged + preempted greedy tokens bit-match the unpaged uncontended
    path, colocated AND disaggregated across per-worker prefill meshes
    (serve.make_fanin_meshes)."""

    @pytest.fixture(scope="class")
    def fanin_meshes(self, cfg):
        return serve.make_fanin_meshes(cfg, workers=2)

    @pytest.fixture(scope="class")
    def golden(self, cfg, mesh, setup):
        params, prompts, lens = setup
        return serve.generate(cfg, params, prompts, max_new=12,
                              prompt_lens=lens, mesh=mesh)

    def test_worker_meshes_partition_the_prefill_half(self, fanin_meshes):
        pres, dec = fanin_meshes
        assert len(pres) == 2
        dec_ids = {d.id for d in dec.devices.flat}
        pre_ids = [frozenset(d.id for d in m.devices.flat) for m in pres]
        assert pre_ids[0] and pre_ids[1]
        assert pre_ids[0].isdisjoint(pre_ids[1])
        for ids in pre_ids:
            assert ids.isdisjoint(dec_ids)

    def test_paged_preempted_matches_colocated(self, cfg, mesh, setup,
                                               golden):
        """slots=3 < batch=8 forces preemption (class pressure + the
        promotion bound); the paged, contended run bit-matches the
        dense uncontended one."""
        params, prompts, lens = setup
        out = serve.generate(cfg, params, prompts, max_new=12,
                             prompt_lens=lens, mesh=mesh, workers=2,
                             slots=3, evict="priority", paged=True,
                             priorities=(np.arange(8) % 2).astype(np.int32))
        assert (out == golden).all(), (out, golden)
        st = serve._generate_fanin.last_stats
        assert st["evictions"] > 0
        assert st["hbm_bytes_per_slot"] < st["dense_hbm_bytes_per_slot"]

    def test_paged_preempted_matches_across_fanin_meshes(
            self, cfg, fanin_meshes, setup, golden):
        """Two real prefill worker meshes feeding the decode-mesh slot
        table: live pages ship across meshes, victims re-prefill on
        their own worker, tokens still bit-match."""
        params, prompts, lens = setup
        pres, dec = fanin_meshes
        out = serve.generate(cfg, params, prompts, max_new=12,
                             prompt_lens=lens, mesh=pres[0],
                             prefill_meshes=pres, decode_mesh=dec,
                             workers=2, slots=3, evict="oldest",
                             paged=True)
        assert (out == golden).all(), (out, golden)
        assert serve._generate_fanin.last_stats["admissions"] >= 8
