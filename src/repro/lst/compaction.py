"""Bin-pack compaction execution (Iceberg's rewriteDataFiles analogue).

``plan_binpack`` groups undersized files into bins of ~target size;
``execute_task`` rewrites one bin: read inputs (metered), merge content
through a pluggable ``merge_fn`` (token shards use the Pallas-backed packer
in repro.data), write output(s), and commit a ``replace`` snapshot with
retry-on-conflict. Supports partial progress (per-bin commits) — FR1's
fine-grained work units — and failure injection for fault-tolerance tests.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.lst.files import DataFile
from repro.lst.table import CommitConflict, LogStructuredTable


@dataclasses.dataclass
class CompactionTask:
    task_id: int
    table_id: str
    scope: Optional[str]                 # partition value or None (table scope)
    inputs: Tuple[DataFile, ...]
    est_output_bytes: int

    @property
    def input_bytes(self) -> int:
        return sum(f.size_bytes for f in self.inputs)


@dataclasses.dataclass
class CompactionResult:
    task: CompactionTask
    success: bool
    conflict: bool = False
    retries: int = 0
    files_removed: int = 0
    files_added: int = 0
    bytes_rewritten: int = 0
    rows_dropped: int = 0                # rows deleted by a filtered rewrite
    bytes_reclaimed: int = 0             # input bytes that left the table:
    gbhr: float = 0.0                    # dropped files + filtered-out rows
    error: Optional[str] = None


def plan_binpack(files: Sequence[DataFile], target_bytes: int,
                 min_input_files: int = 2,
                 scope: Optional[str] = None) -> List[CompactionTask]:
    """First-fit-decreasing bin packing of small files into ~target bins."""
    small = sorted((f for f in files if f.size_bytes < target_bytes),
                   key=lambda f: -f.size_bytes)
    bins: List[List[DataFile]] = []
    sizes: List[int] = []
    for f in small:
        for i, s in enumerate(sizes):
            if s + f.size_bytes <= target_bytes:
                bins[i].append(f)
                sizes[i] += f.size_bytes
                break
        else:
            bins.append([f])
            sizes.append(f.size_bytes)
    # Task IDs are scoped to the plan (1..N, bin order) — NFR2 determinism:
    # two plans over the same catalog state yield identical IDs, with no
    # module-global counter leaking state across tables or test runs.
    tasks = []
    for b, s in zip(bins, sizes):
        if len(b) >= min_input_files:
            tasks.append(CompactionTask(len(tasks) + 1, "", scope,
                                        tuple(b), s))
    return tasks


def plan_table(table: LogStructuredTable, target_bytes: int,
               scope: str = "table", min_input_files: int = 2
               ) -> List[CompactionTask]:
    """Plan tasks for a table at the given candidate scope.

    Execution ALWAYS respects partition boundaries (compaction never merges
    across partitions — §7); the scope only controls candidate granularity
    upstream. This is exactly why the paper's table-level ΔF_c estimator
    overestimates on partitioned tables: it counts small files across the
    whole table, while execution can only merge within each partition.
    """
    tasks: List[CompactionTask] = []
    for part in table.partitions() or [""]:
        files = [f for f in table.current_files()
                 if (f.partition or "") == part]
        for t in plan_binpack(files, target_bytes, min_input_files,
                              part or None):
            t.table_id = table.table_id
            t.task_id = len(tasks) + 1   # plan-scoped: unique across partitions
            tasks.append(t)
    return tasks


def default_merge_fn(table: LogStructuredTable, task: CompactionTask,
                     out_path: str, filter_fn: Optional[Callable] = None,
                     fused_filter: bool = True):
    """Synthetic merge: concatenates the raw payloads of the inputs.

    With ``filter_fn`` it models a rewrite-delete over synthetic rows: each
    input row is represented by a stable integer id (crc32 of its file path
    plus the row index, column 0 of the rows array), so a deterministic
    predicate — e.g. a GDPR-style hash match on the id — drops the same
    rows on every plan. Output payload, size and row count shrink to the
    kept fraction; returns ``(DataFile, rows_dropped)`` like the real
    token-shard merge. ``fused_filter`` is accepted for signature parity
    (there is no kernel here to fuse)."""
    blobs = [table.store.get(f.path) for f in task.inputs]
    data = b"".join(blobs)
    in_bytes = sum(f.size_bytes for f in task.inputs)
    n_rows = sum(f.num_rows for f in task.inputs)
    if filter_fn is None:
        table.store.put(out_path, data)
        return DataFile(path=out_path, size_bytes=in_bytes, num_rows=n_rows,
                        partition=task.scope, created_at=table.now_fn())
    import zlib

    import numpy as np
    ids = (np.concatenate(
        [zlib.crc32(f.path.encode()) + np.arange(f.num_rows, dtype=np.int64)
         for f in task.inputs])
        if n_rows else np.zeros((0,), np.int64))
    keep = np.asarray(filter_fn(ids.reshape(-1, 1), task), bool).reshape(-1)
    kept = int(keep.sum())
    frac = kept / n_rows if n_rows else 0.0
    out_bytes = int(round(in_bytes * frac))
    table.store.put(out_path, data[:out_bytes] if out_bytes else b"")
    out = DataFile(path=out_path, size_bytes=out_bytes, num_rows=kept,
                   partition=task.scope, created_at=table.now_fn())
    return out, n_rows - kept


def _merge_output(out) -> Tuple[DataFile, int]:
    """Normalize a merge_fn return: plain DataFile, or (DataFile,
    rows_dropped) from a filtered rewrite."""
    if isinstance(out, tuple):
        f, dropped = out
        return f, int(dropped)
    return out, 0


def _delete_orphans(table: LogStructuredTable,
                    written: Sequence[DataFile]) -> None:
    """Remove output blobs of a rewrite that never committed."""
    live = {f.path for f in table.current_files()}
    for f in written:
        if f.path not in live and table.store.exists(f.path):
            table.store.delete(f.path)


def execute_tasks_atomic(table: LogStructuredTable,
                         tasks: Sequence[CompactionTask],
                         merge_fn: Callable = default_merge_fn,
                         max_retries: int = 2,
                         executor_memory_gb: float = 8.0,
                         rewrite_bytes_per_hour: float = 256e9,
                         interleave_fn: Optional[Callable] = None,
                         filter_fn: Optional[Callable] = None,
                         fused_filter: bool = True
                         ) -> CompactionResult:
    """Table-scope execution: ALL bins of a candidate rewritten in ONE
    commit (Iceberg's default rewriteDataFiles). The conflict window spans
    the whole rewrite — this is why the paper's table-scope runs hit
    cluster-side conflicts that partition-scope (per-partition commits)
    avoids.

    ``filter_fn`` turns the rewrite into rewrite-deletes-as-compaction:
    it is forwarded to the merge_fn (with ``fused_filter`` selecting the
    fused filter+pack kernel vs the filter-then-pack reference), rows it
    drops never land in the outputs, and the per-bin drop counts sum into
    ``rows_dropped``."""
    agg = CompactionTask(0, table.table_id, None,
                         tuple(f for t in tasks for f in t.inputs),
                         sum(t.est_output_bytes for t in tasks))
    res = CompactionResult(task=agg, success=False)
    if not tasks:
        res.success = True
        return res
    txn = table.new_transaction()       # plan-time basis for the whole job
    merge_kwargs = {} if filter_fn is None else \
        {"filter_fn": filter_fn, "fused_filter": fused_filter}
    new_files = []
    for t in tasks:
        ext = t.inputs[0].path.rsplit(".", 1)[-1] if t.inputs else "bin"
        # deterministic per catalog state (NFR2), unique across cycles:
        # the snapshot basis version advances with every commit
        out_path = (f"{table.table_id}/data/"
                    f"compacted-{txn.base_version}-{t.task_id}.{ext}")
        try:
            f, dropped = _merge_output(
                merge_fn(table, t, out_path, **merge_kwargs))
            new_files.append(f)
            res.rows_dropped += dropped
        except FileNotFoundError as e:
            res.error = f"missing input: {e}"
            _delete_orphans(table, new_files)
            return res
        if interleave_fn is not None:
            interleave_fn(table, t)
    for attempt in range(max_retries + 1):
        inputs_alive = {f.path for f in table.current_files()}
        live_inputs = [f for f in agg.inputs if f.path in inputs_alive]
        if attempt > 0 and len(live_inputs) < 2:
            # same guard as execute_task: a conflict that killed the inputs
            # must not resurrect their rows via the merged outputs. Known
            # limitation (matches Iceberg's file-granularity semantics and
            # execute_task): when >= 2 inputs stay live the merged outputs
            # still commit, and they were built from ALL planned inputs —
            # rows of an input deleted concurrently mid-rewrite survive
            # inside the compacted file even though the file-level delete
            # stands. Row-level reconciliation belongs to the merge_fn.
            res.error = "inputs no longer live after conflict"
            break
        try:
            txn.rewrite_files(live_inputs, new_files, scope=None)
            txn.commit()
            res.success = True
            break
        except CommitConflict:
            res.conflict = True
            res.retries = attempt + 1
            txn = table.new_transaction()
    if res.success:
        # Only the inputs OUR commit replaced count (and get their blobs
        # dropped): ``live_inputs`` is exactly what the successful
        # transaction removed. The old accounting re-scanned liveness
        # *after* the commit and credited every planned input that was
        # gone — including files concurrent writers deleted — and worse,
        # deleted the blobs of inputs that were already dead at commit
        # time (another committer's files to clean, possibly still
        # referenced by its snapshots). Mirrors execute_task's
        # ``len(live_inputs)``.
        for f in live_inputs:
            if table.store.exists(f.path):
                table.store.delete(f.path)
        res.files_removed = len(live_inputs)
        res.files_added = len(new_files)
        res.bytes_rewritten = sum(f.size_bytes for f in live_inputs)
        if filter_fn is not None:
            res.bytes_reclaimed = max(0, res.bytes_rewritten
                                      - sum(f.size_bytes for f in new_files))
        res.gbhr = executor_memory_gb * (res.bytes_rewritten
                                         / rewrite_bytes_per_hour)
    else:
        # a compaction system must not create small-file garbage: drop the
        # already-written outputs of an uncommitted rewrite
        _delete_orphans(table, new_files)
        res.rows_dropped = 0             # nothing committed, nothing deleted
        if res.error is None:
            res.error = (f"retries exhausted after {res.retries} "
                         f"conflicting commit attempts")
    return res


def execute_task(table: LogStructuredTable, task: CompactionTask,
                 merge_fn: Callable = default_merge_fn,
                 max_retries: int = 2,
                 executor_memory_gb: float = 8.0,
                 rewrite_bytes_per_hour: float = 256e9,
                 fail_fn: Optional[Callable[[CompactionTask], bool]] = None,
                 interleave_fn: Optional[Callable] = None,
                 filter_fn: Optional[Callable] = None,
                 fused_filter: bool = True
                 ) -> CompactionResult:
    """Rewrite one bin and commit.

    Faithful long-running-job semantics: the rewrite TRANSACTION is opened at
    plan time (before the merge work), so concurrent commits that land while
    the rewrite runs trigger conflict validation at commit — the §4.4/§6.2
    behavior. ``interleave_fn(table)`` (tests/benchmarks) injects concurrent
    work into that window. Retries re-open a fresh-basis transaction.

    ``filter_fn`` (forwarded to merge_fn, with ``fused_filter`` choosing
    the fused filter+pack kernel vs the two-pass reference) makes this a
    rewrite-deletes-as-compaction: dropped rows are counted in
    ``rows_dropped`` and never written to the output.
    """
    res = CompactionResult(task=task, success=False)
    if fail_fn is not None and fail_fn(task):
        res.error = "injected_failure"
        return res
    ext = task.inputs[0].path.rsplit(".", 1)[-1] if task.inputs else "bin"
    txn = table.new_transaction()       # plan-time snapshot basis
    # deterministic per catalog state (NFR2), unique across cycles: the
    # snapshot basis version advances with every commit
    out_path = (f"{table.table_id}/data/"
                f"compacted-{txn.base_version}-{task.task_id}.{ext}")
    merge_kwargs = {} if filter_fn is None else \
        {"filter_fn": filter_fn, "fused_filter": fused_filter}
    try:
        new_file, res.rows_dropped = _merge_output(
            merge_fn(table, task, out_path, **merge_kwargs))
    except FileNotFoundError as e:
        res.error = f"missing input: {e}"
        _delete_orphans(table, [DataFile(out_path, 0, 0, task.scope)])
        return res
    if interleave_fn is not None:
        interleave_fn(table, task)      # concurrent user work mid-rewrite
    inputs_alive = {f.path for f in table.current_files()}
    live_inputs = [f for f in task.inputs if f.path in inputs_alive]
    for attempt in range(max_retries + 1):
        try:
            txn.rewrite_files(live_inputs, [new_file], scope=task.scope)
            txn.commit()
            res.success = True
            break
        except CommitConflict:
            res.conflict = True
            res.retries = attempt + 1
            inputs_alive = {f.path for f in table.current_files()}
            live_inputs = [f for f in task.inputs if f.path in inputs_alive]
            txn = table.new_transaction()   # fresh basis for the retry
            if len(live_inputs) < 2:
                res.error = "inputs no longer live after conflict"
                break
    if res.success:
        # physical cleanup of the files OUR commit replaced; inputs a
        # concurrent writer already removed are its blobs to clean
        for f in live_inputs:
            if table.store.exists(f.path):
                table.store.delete(f.path)
        res.files_removed = len(live_inputs)
        res.files_added = 1
        res.bytes_rewritten = sum(f.size_bytes for f in live_inputs)
        if filter_fn is not None:
            res.bytes_reclaimed = max(0, res.bytes_rewritten
                                      - new_file.size_bytes)
        # paper §4.2: GBHr_c = ExecutorMemoryGB * DataSize_c / RewriteBytesPerHour
        res.gbhr = executor_memory_gb * (res.bytes_rewritten
                                         / rewrite_bytes_per_hour)
    else:
        # aborted rewrite (conflict-dead inputs or exhausted retries): the
        # merged blob never entered table metadata — delete it, a compaction
        # system must not create small-file garbage
        _delete_orphans(table, [new_file])
        res.rows_dropped = 0             # nothing committed, nothing deleted
        if res.error is None:
            res.error = (f"retries exhausted after {res.retries} "
                         f"conflicting commit attempts")
    return res
