"""Import-sweep regression: every module under ``src/repro`` must import.

The seed shipped with ``repro.models``/``repro.launch`` importing a
``repro.dist`` package that did not exist, so 5 of 11 test modules died at
collection. This sweep turns any future missing-package (or version-skew
AttributeError at import time) into one focused failure.
"""

import importlib
import pkgutil

import repro


def _iter_module_names():
    yield "repro"
    for m in pkgutil.walk_packages(repro.__path__, prefix="repro."):
        yield m.name


def test_every_repro_module_imports():
    failed = {}
    for name in sorted(_iter_module_names()):
        try:
            importlib.import_module(name)
        except Exception as e:  # noqa: BLE001 — collect them all, report once
            failed[name] = repr(e)
    assert not failed, f"modules failed to import: {failed}"


def test_dist_public_surface():
    from repro import dist

    for attr in ("resolve_spec", "axis_rules", "constrain", "tree_shardings",
                 "mesh_axis_size", "PRESETS"):
        assert hasattr(dist, attr), attr
    assert "baseline" in dist.PRESETS
