"""Fig. 10 + §7 — production fleet simulation.

Weekly rollout schedule as deployed at LinkedIn:
  weeks 1-2:   MANUAL compaction — a FIXED list of "known-bad" tables chosen
               once up front (the paper's k~100 hand-picked tables), re-
               compacted every cycle (diminishing returns);
  weeks 3-5:   AutoComp, top-k=10 over the WHOLE fleet (MOOP ranking with
               quota-adaptive w1) — adapts to where fragmentation actually
               is;
  week 6:      AutoComp, dynamic k under a GBHr budget (select_budget).

Reports files removed + compute per week (Fig. 10a/b), the file-count
trajectory (Fig. 10c), and the §7 model-accuracy comparison of predicted
ΔF_c / GBHr_c vs actuals (table-scope estimates overestimate on partitioned
tables because execution cannot merge across partitions)."""

from __future__ import annotations

from typing import List

import numpy as np

from benchmarks.workload_sim import make_pipeline
from repro.core.decide import quota_adaptive_weights
from repro.core.model import Scope, generate_candidates
from repro.core.orient import compute_traits
from repro.lst import Catalog, InMemoryStore
from repro.lst.workload import SimClock, WorkloadGenerator, WorkloadSpec

MB = 1 << 20
TARGET = 512 * MB


def main(weeks: int = 6, hours_per_week: int = 2) -> List[str]:
    clock = SimClock()
    store = InMemoryStore()
    catalog = Catalog(store, now_fn=clock.now)
    gen = WorkloadGenerator(catalog, WorkloadSpec(
        n_databases=5, tables_per_db=8, seed=5), clock)
    gen.setup()

    rows: List[str] = []
    weekly_removed, weekly_gbhr, trajectory = [], [], []
    pred_err_files, pred_err_gbhr = [], []

    # manual: choose the most fragmented ~1/3 of the fleet ONCE
    by_frag = sorted(catalog.tables(),
                     key=lambda t: -sum(1 for f in t.current_files()
                                        if f.size_bytes < TARGET))
    manual_list = by_frag[: max(3, len(by_frag) // 3)]
    manual_pipe = make_pipeline("table", k=len(manual_list))
    auto_pipe = make_pipeline("table", k=10)
    auto_pipe.weights_fn = lambda c: quota_adaptive_weights(
        catalog.namespace_of(c.table).used_quota(),
        catalog.namespace_of(c.table).total_quota)
    budget_pipe = make_pipeline("hybrid", k=2500, budget=3.0)

    for week in range(1, weeks + 1):
        for _ in range(hours_per_week):
            gen.run_hour()
        if week <= 2:
            pipe, mode, tables = manual_pipe, "manual-fixed", manual_list
        elif week <= 5:
            pipe, mode, tables = auto_pipe, "auto-k10", None
        else:
            pipe, mode, tables = budget_pipe, "auto-dynamic-k(budget)", None

        # record predictions before acting (§7 model accuracy)
        cands = generate_candidates(
            tables if tables is not None else catalog.tables(),
            hybrid=pipe.hybrid)
        pipe.stats.observe_all(cands)
        compute_traits(cands, pipe.traits, pipe.trait_ctx)
        pred = {c.key: (c.traits["file_count_reduction"],
                        c.traits["compute_cost"]) for c in cands}

        rep = pipe.run_cycle(catalog, tables=tables)
        removed = rep.files_removed - rep.act.files_added
        weekly_removed.append(removed)
        weekly_gbhr.append(rep.gbhr)
        trajectory.append(gen.total_file_count())
        rows.append(f"fig10_week{week}[{mode}],{removed},"
                    f"gbhr={rep.gbhr:.4f};k={rep.n_selected};"
                    f"file_count={gen.total_file_count()}")

        # accuracy: actuals per (table, partition-scope) candidate
        actual = {}
        for r in rep.act.results:
            key = (r.task.table_id, r.task.scope or "")
            a = actual.setdefault(key, [0, 0.0])
            a[0] += r.files_removed - r.files_added
            a[1] += r.gbhr
        sel = set(rep.selected_keys)
        for c in cands:
            if c.key not in sel or pred[c.key][0] <= 0:
                continue
            if c.scope == Scope.PARTITION:
                act = actual.get((c.table.table_id, c.partition or ""), [0, 0.0])
            else:  # table scope: sum across its partitions
                act = [0, 0.0]
                for (tid, _), a in actual.items():
                    if tid == c.table.table_id:
                        act[0] += a[0]
                        act[1] += a[1]
            pred_err_files.append(
                abs(pred[c.key][0] - act[0]) / max(pred[c.key][0], 1))
            if pred[c.key][1] > 0:
                pred_err_gbhr.append(
                    abs(pred[c.key][1] - act[1]) / pred[c.key][1])

    manual_avg = np.mean(weekly_removed[:2])
    auto_avg = np.mean(weekly_removed[2:5])
    rows.append(f"fig10_removed_auto_over_manual,"
                f"{auto_avg/max(manual_avg,1):.2f},"
                f"manual_avg={manual_avg:.0f};auto_avg={auto_avg:.0f};"
                f"manual_tables={len(manual_list)}")
    rows.append(f"fig10c_file_count_trajectory,{trajectory[-1]},"
                f"weekly={'|'.join(map(str, trajectory))}")
    if pred_err_files:
        rows.append(f"s7_model_accuracy_file_reduction_err,"
                    f"{float(np.mean(pred_err_files)):.3f},n={len(pred_err_files)}")
    if pred_err_gbhr:
        rows.append(f"s7_model_accuracy_gbhr_err,"
                    f"{float(np.mean(pred_err_gbhr)):.3f},n={len(pred_err_gbhr)}")
    return rows


if __name__ == "__main__":
    for r in main():
        print(r)
