"""Orient phase (§4.2): turn statistics into *traits* — decision helpers
describing either the benefit of compacting a candidate (file-count
reduction, file entropy) or its cost (compute GBHr).

Traits are defined independently of one another and combined only at
ranking time, exactly as the paper prescribes.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Iterable, List, Optional, Protocol

from repro.core.model import Candidate


@dataclasses.dataclass
class TraitContext:
    target_file_bytes: int
    executor_memory_gb: float = 8.0
    rewrite_bytes_per_hour: float = 256e9   # calibrated from the packer bench


class Trait(Protocol):
    name: str
    kind: str                                # "benefit" | "cost"

    def compute(self, cand: Candidate, ctx: TraitContext) -> float: ...


class FileCountReductionTrait:
    """Paper §4.2: ΔF_c = Σ_i 1(FileSize_i < TargetFileSize).

    ``partition_aware=True`` applies the §7 refinement: compaction does not
    cross partition boundaries, so the achievable reduction is
    Σ_partitions (small_p - ceil(small_bytes_p / target)).
    """
    name = "file_count_reduction"
    kind = "benefit"

    def __init__(self, partition_aware: bool = False):
        self.partition_aware = partition_aware

    def compute(self, cand: Candidate, ctx: TraitContext) -> float:
        if not self.partition_aware:
            return float(cand.stats.small_file_count)
        per_part: Dict[str, List[int]] = {}
        for f in cand.files():
            if f.size_bytes < ctx.target_file_bytes:
                per_part.setdefault(f.partition or "", []).append(f.size_bytes)
        red = 0.0
        for sizes in per_part.values():
            out_files = math.ceil(sum(sizes) / ctx.target_file_bytes) or 1
            red += max(0, len(sizes) - out_files)
        return red


class FileEntropyTrait:
    """File entropy (Netflix auto-optimize [65]): Shannon entropy of the
    file-size distribution. A table fully packed at the target size has
    entropy ~log(N) with uniform p_i; heavy fragmentation (many small files)
    raises entropy *relative to the ideal packing of the same bytes*. We
    report  H_actual - H_ideal  (>= 0, higher = more fragmented):
        H = -Σ (s_i/S) ln (s_i/S)
        H_ideal computed for ceil(S/target) equal files.
    """
    name = "file_entropy"
    kind = "benefit"

    def compute(self, cand: Candidate, ctx: TraitContext) -> float:
        files = cand.files()
        total = sum(f.size_bytes for f in files)
        if total <= 0 or not files:
            return 0.0
        h = 0.0
        for f in files:
            p = max(f.size_bytes, 1) / total
            h -= p * math.log(p)
        n_ideal = max(1, math.ceil(total / ctx.target_file_bytes))
        h_ideal = math.log(n_ideal)
        return max(0.0, h - h_ideal)


class ComputeCostTrait:
    """Paper §4.2: GBHr_c = ExecutorMemoryGB * DataSize_c / RewriteBytesPerHour
    where DataSize_c counts the bytes that must actually be rewritten (small
    files only)."""
    name = "compute_cost"
    kind = "cost"

    def __init__(self, small_files_only: bool = True):
        self.small_files_only = small_files_only

    def compute(self, cand: Candidate, ctx: TraitContext) -> float:
        data = cand.stats.small_bytes if self.small_files_only \
            else cand.stats.total_bytes
        return ctx.executor_memory_gb * (data / ctx.rewrite_bytes_per_hour)


DEFAULT_TRAITS = (FileCountReductionTrait(), FileEntropyTrait(),
                  ComputeCostTrait())


def compute_traits(cands: Iterable[Candidate], traits, ctx: TraitContext
                   ) -> List[Candidate]:
    out = []
    for c in cands:
        for t in traits:
            c.traits[t.name] = float(t.compute(c, ctx))
        out.append(c)
    return out
