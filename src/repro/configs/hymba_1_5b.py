"""Hymba-1.5B [arXiv:2411.13676; hybrid parallel attn+mamba heads].

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16.
Attention heads use sliding-window attention (global KV bounded), running in
parallel with mamba (SSM) heads inside each layer — this is what makes
long_500k decode feasible (sub-quadratic, bounded cache).
"""
from repro.configs import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab=32001,
    head_dim=64,
    ssm_state=16,
    ssm_expand=2,
    ssm_conv=4,
    attn_window=2048,
    rope_theta=1e4,
)
