import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))
# The two lines above MUST run before any jax import (jax locks the device
# count on first init). Do not move them.

import argparse
import json
import re
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.configs import shapes as shapes_lib
from repro.dist import sharding as shd
from repro.launch import analysis
from repro.launch.mesh import make_production_mesh
from repro.launch import serve as serve_lib
from repro.models import transformer
from repro.train import optimizer as opt_lib
from repro.train import step as step_lib

# --- TPU v5e-class hardware constants (per chip) ---------------------------
PEAK_FLOPS = 197e12        # bf16 FLOP/s
HBM_BW = 819e9             # bytes/s
ICI_BW = 50e9              # bytes/s per link (1 link assumed: conservative)

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


# collective parsing (loop-trip-count aware) lives in repro.launch.analysis
parse_collectives = analysis.hlo_collective_bytes


def _mem_analysis(compiled) -> Dict[str, Optional[int]]:
    try:
        m = compiled.memory_analysis()
    except Exception:
        m = None
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "generated_code_size_in_bytes",
            "alias_size_in_bytes")
    if m is None:
        return {k: None for k in keys}
    return {k: int(getattr(m, k, 0) or 0) for k in keys}


def _cost_analysis(compiled) -> Dict[str, float]:
    try:
        c = compiled.cost_analysis()
    except Exception:
        return {}
    if isinstance(c, (list, tuple)):
        c = c[0] if c else {}
    return {k: float(v) for k, v in c.items()
            if isinstance(v, (int, float)) and not k.startswith("bytes accessed{")}


model_flops = analysis.model_flops


# Wire ratio of the two-stage int8 exchange vs a ring bf16 all-reduce for
# the same payload: (1 int8 byte + f32 scale per block) on each of the two
# stages, against 2 bf16 bytes on each of the two ring phases.
INT8_EF_WIRE_RATIO = (1 + 4 / 256) / 2

# Parsed serve-cell collectives, keyed by the full cell variant + act
# transport: in an --act-transport both sweep each program is the sibling
# cell's counterpart, so memoizing here means every distinct serve program
# compiles exactly once per process instead of twice.
_SERVE_COLL_MEMO: Dict[tuple, Dict[str, Any]] = {}

# Disaggregated-decode design-space reports (cache_transfer x kv_storage),
# memoized the same way: the report is independent of the record's own
# preset/act_transport, so a --preset/--act-transport sweep compiles the
# transfer + storage-arm programs once per decode cell.
_DISAGG_MEMO: Dict[tuple, Dict[str, Any]] = {}


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               skip_compile: bool = False, preset: str = "baseline",
               microbatches: Optional[int] = None,
               remat_block: Optional[int] = None,
               capacity_factor: Optional[float] = None,
               grad_transport: str = "bf16",
               act_transport: str = "bf16",
               cache_transfers: tuple = ("bf16", "int8"),
               kv_storages: tuple = ("bf16", "int8"),
               stream_blocks: tuple = (256,),
               workers: int = 2,
               page_size: int = 0) -> Dict[str, Any]:
    import dataclasses as _dc
    cfg = get_config(arch)
    if remat_block is not None:
        cfg = _dc.replace(cfg, remat_block=remat_block)
    if capacity_factor is not None:
        cfg = _dc.replace(cfg, capacity_factor=capacity_factor)
    shape = shapes_lib.SHAPES[shape_name]
    if microbatches is not None and shape.kind == "train":
        shape = _dc.replace(shape, microbatches=microbatches)
    rules = shd.PRESETS[preset]
    is_train = shape.kind == "train"
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": shape.kind, "preset": preset,
        "grad_transport": grad_transport if is_train else None,
        "act_transport": None if is_train else act_transport,
        "microbatches": shape.microbatches,
        "remat_block": cfg.remat_block,
        "capacity_factor": cfg.capacity_factor,
        "params": cfg.param_count(), "active_params": cfg.active_param_count(),
    }
    ok, why = shapes_lib.applicable(cfg, shape)
    if not ok:
        rec["status"] = "skip"
        rec["skip_reason"] = why
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(mesh.devices.shape))
    rec["chips"] = n_chips

    p_axes = transformer.param_axes(cfg)
    p_abs = transformer.abstract_params(cfg)
    p_shard = shd.tree_shardings(p_abs, p_axes, mesh, rules)
    batch_sds, cache_sds = shapes_lib.input_specs(cfg, shape)
    b_axes = shapes_lib.batch_axes(cfg, shape)
    b_shard = {k: NamedSharding(mesh, shd.resolve_spec(
        batch_sds[k].shape, b_axes[k], mesh, rules)) for k in batch_sds}

    fn, kind = step_lib.step_for_shape(cfg, shape,
                                       grad_transport=grad_transport,
                                       act_transport=act_transport)
    ctx = shd.axis_rules(mesh, rules)
    t0 = time.time()
    jit_serve = None
    if kind == "train":
        ef = grad_transport == "int8_ef"
        o_abs = opt_lib.abstract_state(p_abs, error_feedback=ef)
        o_axes = opt_lib.state_axes(p_axes, error_feedback=ef)
        o_shard = shd.tree_shardings(o_abs, o_axes, mesh, rules)
        jfn = jax.jit(fn, in_shardings=(p_shard, o_shard, b_shard),
                      out_shardings=(p_shard, o_shard, None))
        lower_args = (p_abs, o_abs, batch_sds)
    elif kind in ("prefill", "encode"):
        def jit_serve(f):
            return jax.jit(f, in_shardings=(p_shard, b_shard))
        jfn = jit_serve(fn)
        lower_args = (p_abs, batch_sds)
    else:  # decode
        c_axes = transformer.cache_axes(cfg, shape.global_batch, shape.seq_len)
        c_shard = shd.tree_shardings(cache_sds, c_axes, mesh, rules)

        def jit_serve(f):
            return jax.jit(f, in_shardings=(p_shard, c_shard, b_shard),
                           out_shardings=(None, c_shard))
        jfn = jit_serve(fn)
        lower_args = (p_abs, cache_sds, batch_sds)
    with ctx:
        lowered = jfn.lower(*lower_args)
    rec["lower_s"] = round(time.time() - t0, 2)

    # exact analytic cost (scan-trip-count aware), global -> per device
    t0 = time.time()
    with shd.axis_rules(mesh, rules):
        jc = analysis.jaxpr_cost(fn, *lower_args)
    rec["jaxpr_cost"] = jc
    rec["jaxpr_cost_s"] = round(time.time() - t0, 2)

    if skip_compile:
        rec["status"] = "lowered"
        return rec

    t0 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t0, 2)

    mem = _mem_analysis(compiled)
    cost = _cost_analysis(compiled)
    hlo = compiled.as_text()
    coll = parse_collectives(hlo)
    rec["memory_analysis"] = mem
    rec["cost_analysis"] = {k: cost[k] for k in ("flops", "bytes accessed")
                            if k in cost}
    rec["collectives"] = coll

    flops_dev = jc["flops"] / n_chips          # analytic, trip-count exact
    bytes_dev = jc["hbm_bytes"] / n_chips      # dot-operand HBM traffic model
    # per-device link traffic (ring wire model), loop-aware, adjusted for the
    # CPU backend's bf16->f32 dot promotion (TPU keeps these payloads bf16);
    # raw result-shape bytes stay in the record under coll["total_bytes"]
    coll_dev = float(coll["total_wire_bytes_bf16eq"])
    if kind == "train":
        # int8-vs-bf16 gradient-transport comparison: the gradient reduction
        # is the all-reduce/reduce-scatter wire component; the int8_ef
        # transport moves INT8_EF_WIRE_RATIO of its bf16 bytes (validated on
        # a real 8-device mesh in tests/test_multidevice.py), everything
        # else (weight all-gathers, MoE all-to-alls) is unchanged.
        grad_wire = float(coll["all-reduce"]["wire_bytes_bf16eq"]
                          + coll["reduce-scatter"]["wire_bytes_bf16eq"])
        coll_bf16_dev = coll_dev               # SPMD compile wires bf16
        coll_int8_dev = coll_dev - grad_wire * (1 - INT8_EF_WIRE_RATIO)
        coll_own_dev = coll_int8_dev if grad_transport == "int8_ef" \
            else coll_bf16_dev
    else:
        # serve cells: the act_transport comparison is *measured*, not
        # modeled — compile the counterpart transport too and parse its
        # collectives (the activation all-gathers carry s8 + scales under
        # int8; everything else is shared between the two programs).
        cell = (arch, shape_name, multi_pod, preset, cfg.remat_block,
                cfg.capacity_factor)
        _SERVE_COLL_MEMO[cell + (act_transport,)] = coll
        other = "int8" if act_transport == "bf16" else "bf16"
        coll2 = _SERVE_COLL_MEMO.get(cell + (other,))
        if coll2 is None:
            fn2, _ = step_lib.step_for_shape(cfg, shape, act_transport=other)
            t0 = time.time()
            with ctx:
                coll2 = parse_collectives(
                    jit_serve(fn2).lower(*lower_args).compile().as_text())
            rec["compile_other_transport_s"] = round(time.time() - t0, 2)
            _SERVE_COLL_MEMO[cell + (other,)] = coll2
        by_t = {act_transport: coll, other: coll2}
        coll_bf16_dev = float(by_t["bf16"]["total_wire_bytes_bf16eq"])
        coll_int8_dev = float(by_t["int8"]["total_wire_bytes_bf16eq"])
        coll_own_dev = coll_dev
        rec["act_gather_wire_bytes_bf16eq_s8"] = \
            int(by_t["int8"]["total_wire_bytes_bf16eq_s8"])
    mf = model_flops(cfg, shape)
    terms = {
        "compute_s": flops_dev / PEAK_FLOPS,
        "memory_s": bytes_dev / HBM_BW,
        "collective_s": coll_own_dev / ICI_BW,
    }
    dom = max(terms, key=terms.get)
    bound_s = terms[dom]
    rec["roofline"] = {
        **terms,
        "collective_s_bf16": coll_bf16_dev / ICI_BW,
        "collective_s_int8": coll_int8_dev / ICI_BW,
        "dominant": dom,
        "model_flops": mf,
        "model_flops_per_device": mf / n_chips,
        "hlo_flops_per_device": flops_dev,
        "useful_flops_ratio": (mf / n_chips) / flops_dev if flops_dev else None,
        "roofline_fraction": ((mf / n_chips) / PEAK_FLOPS) / bound_s
        if bound_s else None,
    }
    if kind == "decode":
        # disaggregated serving design space: per cache_transfer x
        # kv_storage combination, the prefill->decode cache stream's wire
        # + the serve_decode step's wire + the decode mesh's resident
        # cache bytes (all measured from compiled HLO / resolved layouts),
        # plus the per-slot continuous-streaming wire and its modeled
        # overlap, swept over stream block sizes and hillclimbed
        # (core.autotune.tune_design) for the cheapest combo
        dkey = (arch, shape_name, multi_pod, cfg.remat_block,
                cfg.capacity_factor, cache_transfers, kv_storages,
                stream_blocks)
        rep = _DISAGG_MEMO.get(dkey)
        if rep is None:
            t0 = time.time()
            rep = serve_lib.disagg_decode_report(
                cfg, shape.global_batch, shape.seq_len, mesh, ici_bw=ICI_BW,
                hbm_bw=HBM_BW, transfers=cache_transfers,
                storages=kv_storages, blocks=stream_blocks)
            rep["compile_s"] = round(time.time() - t0, 2)
            _DISAGG_MEMO[dkey] = rep
        rec["disagg"] = rep
        # every scenario leg this family refused, named explicitly (flag +
        # uniform capability reason) instead of silently missing from the
        # roofline keys — the BENCH_roofline artifact carries this list
        rec["skipped_families"] = [
            {"family": cfg.family, "flag": flag, "reason": why}
            for flag, why in sorted(rep.get("skipped", {}).items())]
        for name, cell in rep["cells"].items():
            # flat roofline keys so scripts/bench_diff.py gates each combo
            rec["roofline"]["disagg_collective_s_" + name] = \
                cell["collective_s"]
            # the combo sum is dominated by the one-time transfer, so the
            # per-token and per-batch components are gated separately too
            # (a 10x decode-step regression barely moves the sum)
            t, s = name.split("x")
            rec["roofline"]["disagg_transfer_s_" + t] = cell["transfer_s"]
            rec["roofline"]["disagg_decode_step_s_" + s] = \
                cell["decode_step_s"]
            # overlap efficiency of continuous slot streaming: fraction of
            # a per-slot transfer hidden behind the decode steps that run
            # while it is double-buffered (higher is better; absent for
            # families that refuse slot streaming)
            if "slot_stream_overlap_frac" in cell:
                rec["roofline"]["slot_stream_overlap_frac_" + name] = \
                    cell["slot_stream_overlap_frac"]
        for t, ss in rep["slot_stream"].items():
            rec["roofline"]["slot_stream_transfer_s_" + t] = \
                ss["transfer_s"]
            rec["roofline"]["slot_stream_wire_bytes_" + t] = \
                ss["wire_bytes_bf16eq"]
        if rep["tuned"] is not None:
            rec["roofline"]["disagg_tuned_collective_s"] = \
                rep["tuned"]["collective_s"]
        # fan-in arbitration roofline: drive the real AdmissionArbiter
        # through a deterministic contended trace priced with this cell's
        # measured decode-step and per-slot transfer costs; paged-vs-dense
        # slot HBM rent rides along for families with the paged capability
        cell0 = next(iter(rep["cells"].values()), None)
        ss0 = next(iter(rep["slot_stream"].values()), None)
        frep = serve_lib.fanin_report(
            cfg, shape.global_batch, shape.seq_len,
            workers=workers, page=page_size,
            decode_step_s=cell0["decode_step_s"] if cell0 else 0.0,
            transfer_s=ss0["transfer_s"] if ss0 else 0.0)
        rec["fanin"] = frep
        rec["roofline"]["fanin_admission_wait_s"] = \
            frep["fanin_admission_wait_s"]
        rec["roofline"]["fanin_evictions"] = float(frep["fanin_evictions"])
        if "paged_hbm_bytes_per_slot" in frep:
            rec["roofline"]["paged_hbm_bytes_per_slot"] = \
                frep["paged_hbm_bytes_per_slot"]
        rec["skipped_families"] += [
            {"family": cfg.family, "flag": flag, "reason": why}
            for flag, why in sorted(frep.get("skipped", {}).items())]
    rec["status"] = "ok"
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all",
                    help="comma list of shape names and/or kinds "
                         "(train/prefill/decode) or 'all'")
    ap.add_argument("--mesh", default="both", choices=["pod", "multipod", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--lower-only", action="store_true")
    ap.add_argument("--preset", default="baseline",
                    help="comma-separated preset names or 'all' "
                         f"(known: {','.join(sorted(shd.PRESETS))})")
    ap.add_argument("--grad-transport", default="bf16",
                    choices=["bf16", "int8_ef", "both"],
                    help="gradient transport for train cells; 'both' sweeps "
                         "the two and the records carry the collective_s "
                         "int8-vs-bf16 comparison either way")
    ap.add_argument("--act-transport", default="bf16",
                    choices=["bf16", "int8", "both"],
                    help="activation transport for serve (prefill/decode) "
                         "cells; every compiled serve record carries the "
                         "*measured* collective_s bf16-vs-int8 comparison "
                         "(both transports are compiled either way)")
    ap.add_argument("--cache-transfer", default="bf16,int8",
                    help="comma list of disagg cache-stream wire formats "
                         "for decode cells, or 'all' "
                         f"(known: {','.join(step_lib.CACHE_TRANSFERS)})")
    ap.add_argument("--kv-storage", default="bf16,int8",
                    help="comma list of decode-resident cache storage arms "
                         "for decode cells, or 'all' "
                         f"(known: {','.join(step_lib.KV_STORAGES)}); the "
                         "PR-triggered bench-smoke keeps the quick default "
                         "4-combo sweep, the nightly bench-sweep passes "
                         "'all' to add the f8 arm")
    ap.add_argument("--stream-block", default="256",
                    help="comma list of cache-stream quantization block "
                         "sizes (positions per s8 chunk) to sweep; the "
                         "first is the one the combo cells report")
    ap.add_argument("--workers", type=int, default=2,
                    help="prefill workers for the decode cells' fan-in "
                         "arbitration roofline (serve.fanin_report)")
    ap.add_argument("--page-size", type=int, default=0,
                    help="page size for the decode cells' paged-vs-dense "
                         "slot HBM comparison (0 = the tuned paged_attn "
                         "point, capped to 8 pages per row)")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--remat-block", type=int, default=None)
    ap.add_argument("--capacity-factor", type=float, default=None)
    args = ap.parse_args()

    archs = list(ARCH_IDS) if args.arch == "all" else args.arch.split(",")
    try:
        shapes = shapes_lib.expand_shape_names(args.shape)
    except KeyError as e:
        ap.error(str(e))
    meshes = {"pod": [False], "multipod": [True], "both": [False, True]}[args.mesh]
    presets = sorted(shd.PRESETS) if args.preset == "all" \
        else args.preset.split(",")
    for p in presets:
        if p not in shd.PRESETS:
            ap.error(f"unknown preset {p!r}; known: {sorted(shd.PRESETS)}")
    grad_transports = ["bf16", "int8_ef"] if args.grad_transport == "both" \
        else [args.grad_transport]
    act_transports = ["bf16", "int8"] if args.act_transport == "both" \
        else [args.act_transport]

    def arm(value: str, known, flag: str) -> tuple:
        names = list(known) if value == "all" else value.split(",")
        for n in names:
            if n not in known:
                ap.error(f"unknown {flag} {n!r}; known: {list(known)}")
        return tuple(names)

    args.cache_transfers = arm(args.cache_transfer,
                               step_lib.CACHE_TRANSFERS, "--cache-transfer")
    args.kv_storages = arm(args.kv_storage, step_lib.KV_STORAGES,
                           "--kv-storage")
    try:
        args.stream_blocks = tuple(
            int(b) for b in args.stream_block.split(","))
    except ValueError:
        ap.error(f"--stream-block expects comma-separated ints, got "
                 f"{args.stream_block!r}")
    if any(b < 1 for b in args.stream_blocks):
        ap.error("--stream-block sizes must be positive")
    os.makedirs(args.out, exist_ok=True)

    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                for preset in presets:
                    is_train = shapes_lib.SHAPES[shape].kind == "train"
                    sweep = grad_transports if is_train else act_transports
                    for transport in sweep:
                        failures += run_one(
                            args, arch, shape, mp, preset, transport)
    print(f"done; failures={failures}")
    raise SystemExit(1 if failures else 0)


def run_one(args, arch: str, shape: str, mp: bool, preset: str,
            transport: str) -> int:
    is_train = shapes_lib.SHAPES[shape].kind == "train"
    parts = []
    if preset != "baseline":
        parts.append(preset)
    if transport != "bf16":
        parts.append(transport if is_train else f"act_{transport}")
    if args.microbatches:
        parts.append(f"mb{args.microbatches}")
    if args.remat_block:
        parts.append(f"rb{args.remat_block}")
    if args.capacity_factor:
        parts.append(f"cf{args.capacity_factor}")
    variant = ("__" + "-".join(parts)) if parts else ""
    tag = f"{arch}__{shape}__{'2x16x16' if mp else '16x16'}" + variant
    path = os.path.join(args.out, tag + ".json")
    if os.path.exists(path) and not args.force:
        print(f"[cached] {tag}")
        return 0
    print(f"[dryrun] {tag} ...", flush=True)
    failed = 0
    try:
        rec = lower_cell(arch, shape, mp,
                         skip_compile=args.lower_only,
                         preset=preset,
                         microbatches=args.microbatches,
                         remat_block=args.remat_block,
                         capacity_factor=args.capacity_factor,
                         grad_transport=transport if is_train else "bf16",
                         act_transport="bf16" if is_train else transport,
                         cache_transfers=args.cache_transfers,
                         kv_storages=args.kv_storages,
                         stream_blocks=args.stream_blocks,
                         workers=args.workers,
                         page_size=args.page_size)
    except Exception as e:  # a failure here is a bug in the system
        rec = {"arch": arch, "shape": shape,
               "mesh": "2x16x16" if mp else "16x16",
               "status": "error", "error": repr(e),
               "traceback": traceback.format_exc()[-4000:]}
        failed = 1
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    status = rec.get("status")
    if status == "ok":
        r = rec["roofline"]
        coll_cmp = ""
        if "collective_s_bf16" in r:
            coll_cmp = (f"coll_bf16={r['collective_s_bf16']:.4f}s "
                        f"coll_int8={r['collective_s_int8']:.4f}s ")
        print(f"  ok: compile={rec['compile_s']}s "
              f"dom={r['dominant']} "
              f"compute={r['compute_s']:.4f}s "
              f"mem={r['memory_s']:.4f}s "
              f"coll={r['collective_s']:.4f}s "
              + coll_cmp +
              f"frac={r['roofline_fraction'] and round(r['roofline_fraction'], 3)}",
              flush=True)
    else:
        print(f"  {status}: {rec.get('skip_reason') or rec.get('error', '')[:200]}",
              flush=True)
    return failed


if __name__ == "__main__":
    main()
