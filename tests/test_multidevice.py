"""Real multi-device mesh validation — the suite the CI ``multidevice`` job
runs under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.

Everything here executes on a non-trivial ``(data=4, model=2)`` mesh built
from 8 actual (forced-host) devices: resolved shardings are read back from
committed arrays, collective HLO is parsed from compiled programs, and the
int8_ef gradient transport is shown to move *fewer cross-pod collective
bytes* than the bf16 baseline — not just to simulate its rounding. Skipped
when fewer than 8 devices exist (the plain tier-1 job)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import smoke_config
from repro.dist import sharding as shd
from repro.launch import analysis
from repro.models import transformer
from repro.train import optimizer as opt_lib
from repro.train import step as step_lib

pytestmark = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")

DATA, MODEL = 4, 2


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((DATA, MODEL), ("data", "model"))


@pytest.fixture(scope="module")
def cfg():
    return smoke_config("paper-lm-100m")


def _batch(cfg, batch=8, seq=32, seed=0):
    key = jax.random.PRNGKey(seed)
    toks = jax.random.randint(key, (batch, seq), 0, cfg.vocab, jnp.int32)
    labs = jax.random.randint(key, (batch, seq), 0, cfg.vocab, jnp.int32)
    return {"tokens": toks, "labels": labs}


class TestResolvedShardings:
    def test_param_shardings_on_real_mesh(self, mesh, cfg):
        """FSDP embed dim over data, tensor dims over model — read back from
        the committed arrays, not just the resolver."""
        params = transformer.init_params(cfg, jax.random.PRNGKey(0))
        shards = shd.tree_shardings(transformer.abstract_params(cfg),
                                    transformer.param_axes(cfg), mesh)
        placed = jax.device_put(params, shards)
        # tied embedding (vocab, d): vocab -> model, embed -> data
        emb = placed["embed"]
        assert emb.sharding.spec == P("model", "data")
        local = emb.addressable_shards[0].data
        assert local.shape == (cfg.vocab // MODEL, cfg.d_model // DATA)
        # mlp gate (d, d_ff): embed -> data, mlp -> model
        gate = placed["layers"]["mlp"]["gate"]
        assert gate.sharding.spec[-2:] == ("data", "model")

    def test_constrain_places_activations(self, mesh):
        x = jnp.ones((8, 64))
        with shd.axis_rules(mesh):
            y = jax.jit(lambda t: shd.constrain(t, "batch", "mlp"))(x)
        assert y.sharding.spec == P("data", "model")


def _spmd_train_artifacts(cfg, mesh, grad_transport, rules=None):
    """jit the SPMD train step with explicit shardings and compile it."""
    rules = shd.PRESETS["baseline"] if rules is None else rules
    ef = grad_transport == "int8_ef"
    p_abs = transformer.abstract_params(cfg)
    p_axes = transformer.param_axes(cfg)
    p_shard = shd.tree_shardings(p_abs, p_axes, mesh, rules)
    o_abs = opt_lib.abstract_state(p_abs, error_feedback=ef)
    o_axes = opt_lib.state_axes(p_axes, error_feedback=ef)
    o_shard = shd.tree_shardings(o_abs, o_axes, mesh, rules)
    batch = _batch(cfg)
    b_shard = {k: NamedSharding(mesh, P("data")) for k in batch}
    fn = step_lib.make_train_step(cfg, opt_lib.AdamWConfig(),
                                  grad_transport=grad_transport)
    jfn = jax.jit(fn, in_shardings=(p_shard, o_shard, b_shard),
                  out_shardings=(p_shard, o_shard, None))
    b_abs = {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
             for k, v in batch.items()}
    with shd.axis_rules(mesh, rules):
        compiled = jfn.lower(p_abs, o_abs, b_abs).compile()
    return compiled


class TestSpmdCollectiveHlo:
    def test_train_step_emits_grad_psum_and_weight_gather(self, mesh, cfg):
        """On the real (4,2) mesh the compiled SPMD step must reduce
        gradients (all-reduce/reduce-scatter) and gather FSDP weight shards
        (all-gather) — the 1x1 local mesh never exercises either."""
        compiled = _spmd_train_artifacts(cfg, mesh, "bf16")
        coll = analysis.hlo_collective_bytes(compiled.as_text())
        psum = coll["all-reduce"]["count"] + coll["reduce-scatter"]["count"]
        assert psum > 0
        assert coll["all-gather"]["count"] > 0
        assert coll["total_wire_bytes"] > 0

    def test_int8_ef_spmd_step_compiles_with_ef_state(self, mesh, cfg):
        compiled = _spmd_train_artifacts(cfg, mesh, "int8_ef")
        coll = analysis.hlo_collective_bytes(compiled.as_text())
        assert (coll["all-reduce"]["count"]
                + coll["reduce-scatter"]["count"]) > 0


def _dp_step_artifacts(cfg, mesh, grad_transport):
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    opt = opt_lib.init_state(params,
                             error_feedback=grad_transport == "int8_ef",
                             ef_devices=DATA)
    adamw = opt_lib.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=10)
    step = jax.jit(step_lib.make_train_step(
        cfg, adamw, grad_transport=grad_transport, mesh=mesh))
    batch = _batch(cfg)
    compiled = step.lower(params, opt, batch).compile()
    return step, params, opt, batch, compiled


class TestInt8TransportOnTheWire:
    """The acceptance gate: the compiled int8_ef step moves fewer cross-pod
    collective bytes than the bf16 baseline on the (data=4, model=2) mesh
    (the data axis plays the cross-pod/DCI role)."""

    @pytest.fixture(scope="class")
    def artifacts(self, mesh, cfg):
        return {t: _dp_step_artifacts(cfg, mesh, t)
                for t in ("bf16", "int8_ef")}

    def test_bf16_baseline_reduces_per_leaf(self, artifacts):
        """One gradient all-reduce per parameter leaf (the CPU backend
        promotes the bf16 payload to f32 on the wire — that is exactly the
        promotion the *_bf16eq accounting compensates for)."""
        hlo = artifacts["bf16"][-1].as_text()
        ar_lines = [l for l in hlo.splitlines()
                    if "all-reduce(" in l and " = " in l]
        assert any("bf16[" in l or "f32[" in l for l in ar_lines)
        n_param_leaves = len(jax.tree.leaves(artifacts["bf16"][1]))
        assert len(ar_lines) >= n_param_leaves

    def test_int8_step_moves_int8_payloads(self, artifacts):
        hlo = artifacts["int8_ef"][-1].as_text()
        exch = [l for l in hlo.splitlines()
                if ("all-to-all(" in l or "all-gather(" in l) and " = " in l]
        assert any("s8[" in l for l in exch), \
            "int8 exchange must put s8 payloads on the wire"

    def test_int8_moves_fewer_bytes_than_bf16(self, artifacts):
        coll = {t: analysis.hlo_collective_bytes(a[-1].as_text())
                for t, a in artifacts.items()}
        for key in ("total_wire_bytes", "total_bytes",
                    "total_wire_bytes_bf16eq"):
            int8, bf16 = coll["int8_ef"][key], coll["bf16"][key]
            assert int8 < bf16, (key, int8, bf16)
        # by a margin in the right ballpark even after normalizing away the
        # CPU backend's bf16->f32 promotion: >= 1.5x on the wire
        assert coll["int8_ef"]["total_wire_bytes_bf16eq"] \
            <= coll["bf16"]["total_wire_bytes_bf16eq"] / 1.5

    def test_both_transports_train_to_similar_loss(self, artifacts):
        finals = {}
        for t, (step, params, opt, batch, _) in artifacts.items():
            p, o = params, opt
            for _ in range(6):
                p, o, m = step(p, o, batch)
            finals[t] = float(m["loss"])
            assert np.isfinite(finals[t])
        assert abs(finals["int8_ef"] - finals["bf16"]) \
            <= 0.05 * abs(finals["bf16"]), finals

    def test_ef_residual_is_per_device(self, artifacts, cfg):
        step, params, opt, batch, _ = artifacts["int8_ef"]
        _, o, _ = step(params, opt, batch)
        leaf = jax.tree.leaves(o["ef"])[0]
        assert leaf.shape[0] == DATA          # one residual per data shard
        per_dev = np.asarray(leaf).reshape(DATA, -1)
        norms = np.abs(per_dev).sum(axis=1)
        assert (norms > 0).all()              # every device carries error
