"""Fig. 6 — compaction strategy impact on file count over time.

Strategies: no compaction, table-10, hybrid-50, hybrid-500; hourly periodic
trigger; MOOP weights 0.7/0.3 (the paper's OpenHouse deployment settings).
"""

from __future__ import annotations

from typing import Dict, List

from benchmarks.workload_sim import run_sim

STRATEGIES = ("none", "table-10", "hybrid-50", "hybrid-500")


def run(hours: int = 5, seed: int = 0) -> Dict[str, List[int]]:
    out = {}
    for strat in STRATEGIES:
        res = run_sim(strategy=strat, hours=hours, seed=seed)
        out[strat] = [r["file_count"] for r in res["hourly"]]
    return out


def main(hours: int = 5) -> List[str]:
    rows = []
    series = run(hours=hours)
    for strat, counts in series.items():
        rows.append(f"fig6_file_count[{strat}],{counts[-1]},"
                    f"trajectory={'|'.join(map(str, counts))}")
    none_final = series["none"][-1]
    for strat in STRATEGIES[1:]:
        red = 1 - series[strat][-1] / none_final
        rows.append(f"fig6_reduction_vs_none[{strat}],{red:.3f},"
                    f"final={series[strat][-1]}")
    return rows


if __name__ == "__main__":
    for r in main():
        print(r)
