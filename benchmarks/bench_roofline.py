"""§Roofline table emitter: reads the dry-run JSON records (experiments/
dryrun/) and prints one row per (arch x shape x mesh) cell with the three
terms, the dominant bottleneck, and MODEL_FLOPS/HLO_FLOPS."""

from __future__ import annotations

import glob
import json
import os
from typing import List


def load(outdir: str = "experiments/dryrun"):
    recs = []
    for p in sorted(glob.glob(os.path.join(outdir, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def main() -> List[str]:
    rows = []
    ok = skip = 0
    for r in load():
        tag = f"{r['arch']};{r['shape']};{r['mesh']}"
        if r.get("status") == "skip":
            skip += 1
            rows.append(f"roofline[{tag}],skip,{r['skip_reason']}")
            continue
        if r.get("status") != "ok":
            rows.append(f"roofline[{tag}],ERROR,{r.get('error','')[:80]}")
            continue
        ok += 1
        rf = r["roofline"]
        rows.append(
            f"roofline[{tag}],{rf['roofline_fraction']:.4f},"
            f"dom={rf['dominant'].replace('_s','')};"
            f"compute={rf['compute_s']:.4f};mem={rf['memory_s']:.4f};"
            f"coll={rf['collective_s']:.4f};"
            f"useful_ratio={rf['useful_flops_ratio']:.3f}")
    rows.append(f"roofline_cells,{ok},skips={skip}")
    return rows


if __name__ == "__main__":
    for r in main():
        print(r)
