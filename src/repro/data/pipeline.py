"""Host data pipeline: scan-plan the shard table, read + pack token shards,
prefetch batches on a background thread.

Step-time here is the framework-level analogue of the paper's query latency
(Figs. 3/8): planning cost scales with file count (metadata + open() RPCs),
so AutoComp compaction of the shard table directly improves data-loading
latency. The benchmarks measure exactly this.
"""

from __future__ import annotations

import queue
import threading
import time
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

from repro.data import shards as sh
from repro.data.packing import pack_tokens
from repro.lst.table import LogStructuredTable


class DataPipeline:
    def __init__(self, table: LogStructuredTable, batch: int, seq_len: int,
                 prefetch: int = 2, seed: int = 0) -> None:
        self.table = table
        self.batch = batch
        self.seq_len = seq_len
        self.prefetch = prefetch
        self.seed = seed
        self.plan_time_s = 0.0
        self.read_time_s = 0.0
        self.files_scanned = 0

    # ---------------------------------------------------------------- plan
    def plan(self) -> List:
        t0 = time.perf_counter()
        files = [f for f in self.table.scan() if f.path.endswith(".toks")]
        files.sort(key=lambda f: f.path)
        self.plan_time_s = time.perf_counter() - t0
        self.files_scanned = len(files)
        return files

    # ---------------------------------------------------------------- read
    def _read_stream(self) -> np.ndarray:
        files = self.plan()
        t0 = time.perf_counter()
        parts = [sh.decode_shard(self.table.store.get(f.path)) for f in files]
        self.read_time_s = time.perf_counter() - t0
        if not parts:
            return np.zeros(0, np.int32)
        return np.concatenate(parts)

    def batches(self) -> Iterator[Dict[str, np.ndarray]]:
        stream = self._read_stream()
        slabs = pack_tokens(stream, self.batch, self.seq_len)
        rng = np.random.RandomState(self.seed)
        order = rng.permutation(len(slabs))
        for i in order:
            slab = slabs[i]
            yield {"tokens": slab[:, :-1].astype(np.int32),
                   "labels": slab[:, 1:].astype(np.int32)}

    def prefetching_batches(self) -> Iterator[Dict[str, np.ndarray]]:
        """Background-thread prefetch (overlaps host IO with device step)."""
        q: "queue.Queue" = queue.Queue(maxsize=self.prefetch)
        stop = object()

        def worker():
            try:
                for b in self.batches():
                    q.put(b)
            finally:
                q.put(stop)

        t = threading.Thread(target=worker, daemon=True)
        t.start()
        while True:
            item = q.get()
            if item is stop:
                break
            yield item

    # ------------------------------------------------------------- metrics
    def stats(self) -> Dict[str, float]:
        return {"plan_time_s": self.plan_time_s,
                "read_time_s": self.read_time_s,
                "files_scanned": float(self.files_scanned)}
