"""Batched serving example: prefill + KV-cache decode across architectures
(dense GQA, MoE, MLA, hybrid SWA+SSM, xLSTM) with continuous batching
semantics (per-request lengths masked in the decode step — the contract the
decode_attn Pallas kernel implements on TPU).

Run:  PYTHONPATH=src python examples/serve_batch.py
"""

import sys
import time

_ROOT = __import__("os").path.dirname(__import__("os").path.dirname(__import__("os").path.abspath(__file__)))
sys.path.insert(0, _ROOT)
sys.path.insert(0, __import__("os").path.join(_ROOT, "src"))

import jax
import numpy as np

from repro.configs import smoke_config
from repro.launch.serve import generate
from repro.models import transformer


def main():
    rng = np.random.RandomState(0)
    for arch in ["granite-3-8b", "qwen3-moe-30b-a3b", "minicpm3-4b",
                 "hymba-1.5b", "xlstm-125m"]:
        cfg = smoke_config(arch)
        params = transformer.init_params(cfg, jax.random.PRNGKey(1))
        prompts = rng.randint(0, cfg.vocab, size=(4, 16)).astype(np.int32)
        t0 = time.time()
        out = generate(cfg, params, prompts, max_new=8)
        dt = time.time() - t0
        print(f"{arch:22s} generated {out.size:3d} tokens in {dt:5.2f}s "
              f"| sample {out[0][:6].tolist()}")


if __name__ == "__main__":
    main()
