"""Flash-decode: single-token GQA attention against a long KV cache.

Grid: (B, num_kv_blocks), kv dimension sequential with online-softmax state
in VMEM scratch. Per-sequence valid lengths ride in scalar-prefetch SMEM —
ragged cache fill is masked inside the kernel, so one batched call serves
requests at different positions (continuous batching).

Per step VMEM: q (H, D) + k,v (bk, Hkv, D) + acc (H, D) f32; with bk = 512,
Hkv <= 16, D <= 192 this stays ~1-2 MB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import CompilerParams as _CompilerParams

NEG_INF = -1e30
DEFAULT_BLOCK_K = 512


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                   m_ref, l_ref, acc_ref, *,
                   scale: float, block_k: int, num_kv_blocks: int,
                   group: int):
    b = pl.program_id(0)
    ik = pl.program_id(1)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                        # (H, D)
    k = k_ref[0].astype(jnp.float32)                        # (bk, Hkv, D)
    v = v_ref[0].astype(jnp.float32)
    h, d = q.shape
    hkv = k.shape[1]
    # expand kv heads to query heads via index arithmetic (no materialized
    # repeat: dot per kv-head group)
    qg = q.reshape(hkv, group, d)
    s = jax.lax.dot_general(qg, k.transpose(1, 2, 0),
                            (((2,), (1,)), ((0,), (0,))),
                            preferred_element_type=jnp.float32) * scale
    s = s.reshape(h, k.shape[0])                            # (H, bk)

    kv_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    valid = kv_pos < len_ref[b]
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[:, :1]
    l_prev = l_ref[:, :1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                                  # (H, bk)
    l_new = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
    pv = jax.lax.dot_general(
        p.reshape(hkv, group, -1), v.transpose(1, 0, 2),
        (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32).reshape(h, d)
    acc_ref[...] = acc_ref[...] * alpha + pv
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ik == num_kv_blocks - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[:, :1], 1e-30)
                    ).astype(o_ref.dtype)


def decode_attention_kernel(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                            lengths: jnp.ndarray, *,
                            block_k: int = DEFAULT_BLOCK_K,
                            interpret: bool = False) -> jnp.ndarray:
    """q: (B, H, D); k, v: (B, S, Hkv, D); lengths: (B,) -> (B, H, D)."""
    b, h, d = q.shape
    s, hkv = k.shape[1], k.shape[2]
    group = h // hkv
    bk = min(block_k, s)
    assert s % bk == 0
    nk = s // bk
    scale = 1.0 / (d ** 0.5)

    kernel = functools.partial(_decode_kernel, scale=scale, block_k=bk,
                               num_kv_blocks=nk, group=group)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, nk),
        in_specs=[
            pl.BlockSpec((1, h, d), lambda ib, ik, len_ref: (ib, 0, 0)),
            pl.BlockSpec((1, bk, hkv, d),
                         lambda ib, ik, len_ref: (ib, ik, 0, 0)),
            pl.BlockSpec((1, bk, hkv, d),
                         lambda ib, ik, len_ref: (ib, ik, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, d), lambda ib, ik, len_ref: (ib, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((h, 128), jnp.float32),
            pltpu.VMEM((h, 128), jnp.float32),
            pltpu.VMEM((h, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, d), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(lengths, q, k, v)
