"""Token shards: the data files of the training corpus LST.

A shard is an int32 token array padded to CHUNK_TOKENS (1024) alignment —
the alignment contract that turns compaction into the chunk-permutation DMA
kernel (repro.kernels.compact_pack). The header records the true
(pre-padding) length.

Writers model the paper's §2 causes of small files:
  * TrickleWriter — CDC/streaming ingestion: many small appends;
  * BulkWriter   — well-tuned batch ingestion: near-target files.
"""

from __future__ import annotations

import dataclasses
import io
import struct
from typing import Iterable, List, Optional, Sequence

import numpy as np

from repro.kernels.compact_pack.compact_pack import CHUNK_TOKENS
from repro.lst.files import DataFile
from repro.lst.table import LogStructuredTable

_MAGIC = b"TOKS"


def zipf_tokens(rng: np.random.RandomState, vocab: int, n: int) -> np.ndarray:
    """Zipf-distributed synthetic tokens (learnable unigram structure; a
    uniform stream would already sit at the entropy floor ln(V))."""
    vals = rng.zipf(1.5, size=n)
    return ((vals - 1) % vocab).astype(np.int32)


def encode_shard(tokens: np.ndarray) -> bytes:
    tokens = np.asarray(tokens, dtype=np.int32)
    n = tokens.shape[0]
    pad = (-n) % CHUNK_TOKENS
    padded = np.concatenate([tokens, np.zeros(pad, np.int32)]) if pad else tokens
    return _MAGIC + struct.pack("<q", n) + padded.tobytes()


def decode_shard(raw: bytes) -> np.ndarray:
    assert raw[:4] == _MAGIC, "not a token shard"
    (n,) = struct.unpack("<q", raw[4:12])
    arr = np.frombuffer(raw[12:], dtype=np.int32)
    return arr[:n]


def decode_shard_padded(raw: bytes) -> np.ndarray:
    """Full chunk-aligned payload including padding (kernel input)."""
    assert raw[:4] == _MAGIC
    return np.frombuffer(raw[12:], dtype=np.int32)


@dataclasses.dataclass
class TokenShardWriter:
    table: LogStructuredTable
    vocab: int = 32000
    seed: int = 0
    _counter: int = 0

    def _write(self, tokens: np.ndarray, partition: Optional[str]) -> DataFile:
        self._counter += 1
        path = f"{self.table.table_id}/data/shard-{self._counter:08d}.toks"
        raw = encode_shard(tokens)
        self.table.store.put(path, raw)
        return DataFile(path=path, size_bytes=len(raw),
                        num_rows=int(tokens.shape[0]), partition=partition,
                        created_at=self.table.now_fn())

    def trickle_append(self, n_files: int, tokens_per_file: int,
                       partition: Optional[str] = None,
                       rng: Optional[np.random.RandomState] = None
                       ) -> List[DataFile]:
        """CDC-style: many small shards in one commit."""
        rng = rng or np.random.RandomState(self.seed + self._counter)
        files = [self._write(zipf_tokens(rng, self.vocab, tokens_per_file),
                             partition) for _ in range(n_files)]
        self.table.append(files)
        return files

    def bulk_append(self, total_tokens: int, target_file_tokens: int,
                    partition: Optional[str] = None,
                    rng: Optional[np.random.RandomState] = None
                    ) -> List[DataFile]:
        rng = rng or np.random.RandomState(self.seed + self._counter)
        files = []
        left = total_tokens
        while left > 0:
            n = min(target_file_tokens, left)
            files.append(self._write(zipf_tokens(rng, self.vocab, n),
                                     partition))
            left -= n
        self.table.append(files)
        return files
