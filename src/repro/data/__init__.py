from repro.data.shards import (  # noqa
    decode_shard, encode_shard, TokenShardWriter,
)
from repro.data.packing import merge_shards_fn, pack_tokens  # noqa
from repro.data.pipeline import DataPipeline  # noqa
