"""Public flash-attention wrapper, registered on the tunable-op registry.

``block_q``/``block_k`` default to the tuned point for this (shape,
dtype, device-kind) cell when one is cached, else the deterministic
default (512/512 — the pre-registry hard-coded blocks). Explicit values
override; every point is clamped to the sequence extent so a point tuned
on a long shape degrades to a divisor on a shorter one instead of
tripping the grid assert.

``block_q`` is an exact axis: retiling the query rows never regroups the
kv reduction, so outputs are bit-identical across its values. ``block_k``
splits the online softmax differently and only matches within fp
tolerance.
"""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels import api
from repro.kernels.flash_attn.flash_attn import (
    DEFAULT_BLOCK_K, DEFAULT_BLOCK_Q, flash_attention_kernel)
from repro.kernels.flash_attn.ref import flash_attention_ref

BLOCK_CANDIDATES = (128, 256, 512, 1024)


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k",
                                   "interpret"))
def _run_jit(q, k, v, *, causal, window, block_q, block_k, interpret):
    return flash_attention_kernel(q, k, v, causal=causal, window=window,
                                  block_q=block_q, block_k=block_k,
                                  interpret=interpret)


def _run(point, q, k, v, *, causal=True, window=0):
    return _run_jit(q, k, v, causal=causal, window=window,
                    block_q=point["block_q"], block_k=point["block_k"],
                    interpret=api.use_interpret())


def _ref(q, k, v, *, causal=True, window=0):
    return flash_attention_ref(q, k, v, causal=causal, window=window)


def _clamp(point, q, k, v, **kw):
    s = q.shape[2]
    return {"block_q": api.fit_block(point["block_q"], s),
            "block_k": api.fit_block(point["block_k"], s)}


def _shape_key(q, k, v, **kw):
    b, h, s, d = q.shape
    return f"b{b}h{h}kv{k.shape[1]}s{s}d{d}:{q.dtype.name}"


def _example(quick: bool):
    import jax.numpy as jnp
    s = 256 if quick else 1024
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, 4, s, 64), jnp.float32).astype(jnp.bfloat16)
    k = jax.random.normal(key, (1, 2, s, 64), jnp.float32).astype(jnp.bfloat16)
    v = jax.random.normal(key, (1, 2, s, 64), jnp.float32).astype(jnp.bfloat16)
    return (q, k, v), {"causal": True}


api.register(api.TunableOp(
    name="flash_attn",
    axes={"block_q": BLOCK_CANDIDATES, "block_k": BLOCK_CANDIDATES},
    default={"block_q": DEFAULT_BLOCK_Q, "block_k": DEFAULT_BLOCK_K},
    run=_run,
    ref=_ref,
    clamp=_clamp,
    shape_key=_shape_key,
    example=_example,
    exact_axes=frozenset({"block_q"}),
    tol=5e-2,
))


def flash_attention(q, k, v, *, causal=True, window=0,
                    block_q=None, block_k=None, use_ref=False):
    point = None
    if block_q is not None or block_k is not None:
        point = {"block_q": block_q or DEFAULT_BLOCK_Q,
                 "block_k": block_k or DEFAULT_BLOCK_K}
    return api.call("flash_attn", q, k, v, causal=causal, window=window,
                    point=point, use_ref=use_ref)
