"""AutoCompService: the standalone control-plane service (§5, Fig. 5).

Runs the OODA pipeline either
  * periodically ("pull": evaluate the whole catalog every interval), or
  * on write notifications ("push": optimize-after-write hooks mark tables
    dirty; the service recalculates only those candidates within budget).

Also owns the production rollout policy from §7: fixed top-k during rollout,
then dynamic k constrained by the compaction budget (select_budget).

The service drives any *planner* exposing ``run_cycle(catalog, tables=...)``
— a single ``AutoCompPipeline`` (one pool) or a
``core.fleet.FleetScheduler`` (cross-table decide/act over many per-class
pipelines under a shared budget); their reports are shape-compatible.
Candidates the act phase deferred (e.g. a closed off-peak window) are
requeued: their tables re-enter the next cycle's pool even in
``after_write`` mode where only dirty tables are normally re-evaluated.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Set

from repro.core.ooda import AutoCompPipeline, CycleReport
from repro.core.triggers import OptimizeAfterWriteHook, PeriodicTrigger
from repro.lst.catalog import Catalog


@dataclasses.dataclass
class ServiceConfig:
    interval_hours: float = 24.0          # daily, as deployed at LinkedIn
    mode: str = "periodic"                # "periodic" | "after_write" | "both"
    dynamic_k: bool = False               # §7: fixed k -> budget-driven k


class AutoCompService:
    def __init__(self, catalog: Catalog, pipeline,
                 config: ServiceConfig, now_fn: Callable[[], float]) -> None:
        self.catalog = catalog
        # "pipeline" is any cycle planner: AutoCompPipeline or FleetScheduler
        self.pipeline = pipeline
        self.config = config
        self.trigger = PeriodicTrigger(config.interval_hours, now_fn)
        self.hook: Optional[OptimizeAfterWriteHook] = None
        if config.mode in ("after_write", "both"):
            self.hook = OptimizeAfterWriteHook(catalog)
        self.reports: List = []
        # table_ids whose selected candidates were deferred by act last
        # cycle (closed off-peak window): requeued next cycle instead of
        # silently vanishing
        self._requeue: Set[str] = set()

    def tick(self):
        """Call regularly (e.g. once per simulated hour). Runs a cycle when
        due; returns its report (CycleReport / FleetCycleReport)."""
        if not self.trigger.should_fire():
            return None
        self.trigger.mark_fired()
        tables = None
        if self.hook is not None and self.config.mode == "after_write":
            due = self.hook.drain_dirty() | self._requeue
            tables = [t for t in self.catalog.tables()
                      if t.table_id in due]
        rep = self.pipeline.run_cycle(self.catalog, tables=tables)
        self._requeue = {k[0] for k in getattr(rep, "deferred_keys", ())}
        self.reports.append(rep)
        return rep

    # aggregate telemetry for Fig. 10-style reporting
    def totals(self) -> Dict[str, float]:
        return {
            "cycles": len(self.reports),
            "files_removed": sum(r.files_removed for r in self.reports),
            "gbhr": sum(r.gbhr for r in self.reports),
            "conflicts": sum(r.act.conflicts for r in self.reports if r.act),
            "failures": sum(r.act.failures for r in self.reports if r.act),
            "deferred": sum(len(getattr(r, "deferred_keys", ()))
                            for r in self.reports),
        }
