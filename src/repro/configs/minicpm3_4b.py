"""MiniCPM3-4B [hf:openbmb/MiniCPM3-4B; dense with MLA attention].

62L d_model=2560 40H d_ff=6400 vocab=73448 — Multi-head Latent Attention:
q_lora_rank=768, kv_lora_rank=256, qk_rope_head_dim=32, qk_nope_head_dim=64,
v_head_dim=64. (Config sheet lists kv=40; under MLA the KV cache is the
shared latent, so n_kv_heads is recorded but the cache stores the latent.)
"""
from repro.configs import ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    family="mla",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    d_ff=6400,
    vocab=73448,
    head_dim=64,
    q_lora_rank=768,
    kv_lora_rank=256,
    rope_head_dim=32,
    nope_head_dim=64,
    v_head_dim=64,
    rope_theta=1e6,
)
