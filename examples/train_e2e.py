"""End-to-end training driver: LM training on an AutoComp-managed token
shard table, with checkpoint/restart fault tolerance demonstrated via an
injected preemption.

Default (CI-friendly): a ~13M-param dense LM, 80 steps, preemption at step
35, restart from the step-30 checkpoint, AutoComp compaction of the shard
table mid-run. For the full ~100M-parameter run of the deliverable spec:

  PYTHONPATH=src python examples/train_e2e.py --arch paper-lm-100m \
      --steps 300 --batch 16 --seq-len 512

Run (quick):  PYTHONPATH=src python examples/train_e2e.py
"""

import argparse
import sys
import time

_ROOT = __import__("os").path.dirname(__import__("os").path.dirname(__import__("os").path.abspath(__file__)))
sys.path.insert(0, _ROOT)
sys.path.insert(0, __import__("os").path.join(_ROOT, "src"))

import jax
import numpy as np

from repro.configs import ModelConfig, get_config
from repro.launch.train import build_autocomp, build_data
from repro.models import transformer
from repro.train import optimizer as opt_lib
from repro.train import step as step_lib
from repro.train.checkpoints import CheckpointManager
from repro.train.runner import (RunnerConfig, SimulatedPreemption, Trainer)

QUICK = ModelConfig(name="paper-lm-13m", family="dense", n_layers=4,
                    d_model=256, n_heads=8, n_kv_heads=4, d_ff=1024,
                    vocab=8192, head_dim=32, tie_embeddings=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="quick")
    ap.add_argument("--steps", type=int, default=80)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--preempt-at", type=int, default=35)
    args = ap.parse_args()

    cfg = QUICK if args.arch == "quick" else get_config(args.arch)
    print(f"[e2e] {cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
          f"{args.steps} steps, preemption at step {args.preempt_at}")

    catalog, table, pipe, clock, store = build_data(
        cfg, batch=args.batch, seq_len=args.seq_len,
        n_trickle=40, files_per=10, tokens_per_file=args.seq_len * 40)
    print(f"[e2e] shard table: {table.file_count()} files")

    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    opt_state = opt_lib.init_state(params)
    step_fn = jax.jit(step_lib.make_train_step(
        cfg, opt_lib.AdamWConfig(lr=1e-3, warmup_steps=10,
                                 total_steps=args.steps), microbatches=2))

    ckpt = CheckpointManager(store, keep_last=2)
    autocomp = build_autocomp(catalog, clock)
    fired = {"did": False, "compacted": False}

    def fault_hook(step):
        if step == args.preempt_at and not fired["did"]:
            fired["did"] = True
            print(f"[e2e] *** simulated preemption at step {step} ***")
            raise SimulatedPreemption()

    def tick():
        clock.advance(0.01)
        if not fired["compacted"] and trainer.step == 20:
            fired["compacted"] = True
            rep = autocomp.run_cycle(catalog)
            print(f"[e2e] AutoComp: removed {rep.files_removed} shard files "
                  f"-> {table.file_count()} remain ({rep.gbhr:.4f} GBHr)")

    trainer = Trainer(RunnerConfig(total_steps=args.steps, ckpt_every=10),
                      step_fn, params, opt_state, pipe.prefetching_batches,
                      ckpt=ckpt, autocomp_tick=tick, fault_hook=fault_hook)
    t0 = time.time()
    out = trainer.run_with_recovery()
    losses = [h["loss"] for h in out["history"]]
    print(f"[e2e] done: {out['final_step']} steps, {trainer.restarts} restart,"
          f" loss {losses[0]:.3f} -> {losses[-1]:.3f}, "
          f"{time.time()-t0:.1f}s wall")
    assert trainer.restarts == 1, "preemption/recovery did not exercise"
    assert losses[-1] < losses[0], "loss did not improve"
    print(f"[e2e] store objects={store.object_count} "
          f"open_rpc={store.metrics.open_calls}")


if __name__ == "__main__":
    main()
