"""Int8 expert all-to-all wire format, registered on the tunable-op registry.

Expert-parallel decode dispatches each token group's capacity buffers
``(g, e, c, d)`` across the "experts" mesh axis; XLA SPMD inserts the
all-to-all at the resharding boundary. This op quantizes the dispatch
payload int8-blockwise along the embedding dim *before* that boundary and
dequantizes on the expert shard, so the all-to-all moves ~2x fewer bytes
(int8 values + one f32 scale per block). ``block`` is the quantization
group along d — a pure wire-format knob the sweep harness tunes; the
expert compute epilogue is unchanged. The ref path is the bf16 dispatch
(resharding constraint only, no quantization), so ``tol`` bounds the int8
round-trip error, not a kernel-vs-ref numerics gap.
"""

from __future__ import annotations

from functools import partial

import jax

from repro.dist import collectives
from repro.dist.sharding import constrain
from repro.kernels import api

BLOCK_CANDIDATES = (64, 128, 256, 512)
DEFAULT_BLOCK = collectives.ACT_BLOCK

# the expert-parallel dispatch layout: (groups, experts, capacity, d_model)
EP_AXES = ("batch", "experts", None, "act_embed")


@partial(jax.jit, static_argnames=("block",))
def _a2a_int8(xe, *, block):
    q, scales = collectives.quantize_int8_lastdim(xe, block)
    # reshard the int8 payload (+ scales), not the bf16 tensor: under the
    # "ep" preset this boundary is the expert all-to-all
    q = constrain(q, *EP_AXES)
    scales = constrain(scales, *EP_AXES[:-1], None)
    out = collectives.dequantize_int8_lastdim(q, scales)
    return constrain(out.astype(xe.dtype), *EP_AXES)


def _run(point, xe):
    return _a2a_int8(xe, block=point["block"])


def _ref(xe):
    return constrain(xe, *EP_AXES)


def _clamp(point, xe, **kw):
    return {"block": api.fit_block(point["block"], xe.shape[-1])}


def _shape_key(xe, **kw):
    g, e, c, d = xe.shape
    return f"g{g}e{e}c{c}d{d}:{xe.dtype.name}"


def _example(quick: bool):
    import jax.numpy as jnp
    g = 2 if quick else 8
    key = jax.random.PRNGKey(0)
    xe = jax.random.normal(key, (g, 4, 16, 256),
                           jnp.float32).astype(jnp.bfloat16)
    return (xe,), {}


api.register(api.TunableOp(
    name="expert_a2a",
    axes={"block": BLOCK_CANDIDATES},
    default={"block": DEFAULT_BLOCK},
    run=_run,
    ref=_ref,
    clamp=_clamp,
    shape_key=_shape_key,
    example=_example,
    tol=5e-2,
))


def expert_a2a(xe, *, block=None, use_ref=False):
    """Route the MoE dispatch tensor through the int8 wire format (tuned
    block from the persisted cache unless ``block`` is passed)."""
    point = None if block is None else {"block": block}
    return api.call("expert_a2a", xe, point=point, use_ref=use_ref)
