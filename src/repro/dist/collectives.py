"""Blockwise-int8 compressed collectives with error feedback.

Cross-pod gradient all-reduce is the bandwidth floor of multi-pod training
(the DCI link is ~an order of magnitude slower than ICI). Following the
DRAGONN/ATOMO line of gradient compression, payloads are quantized to
symmetric int8 per ``block`` elements (4x smaller than bf16 on the wire,
scales amortized over the block) and the quantization residual is carried
into the next step — error feedback — so the *long-run* contribution of
every element is unbiased even though each step rounds.

All functions are jit-compatible: shapes are static, no host sync.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jnp.ndarray, block: int = 256
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-block int8 quantization.

    Flattens ``x``, zero-pads to a multiple of ``block``, and scales each
    block by its abs-max so values land in [-127, 127]. Per-element error is
    at most ``block_max / 254`` (half a quantization step). Returns
    ``(q, scales)`` with ``q: int8 (n_blocks, block)`` and
    ``scales: float32 (n_blocks,)``.
    """
    flat = jnp.ravel(x).astype(jnp.float32)
    n = flat.shape[0]
    pad = (-n) % block
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.float32)])
    blocks = flat.reshape(-1, block)
    scales = jnp.max(jnp.abs(blocks), axis=1) / 127.0
    safe = jnp.where(scales > 0, scales, 1.0)   # all-zero block -> q = 0
    q = jnp.clip(jnp.round(blocks / safe[:, None]), -127, 127).astype(jnp.int8)
    return q, scales


def dequantize_int8(q: jnp.ndarray, scales: jnp.ndarray, n: int
                    ) -> jnp.ndarray:
    """Inverse of :func:`quantize_int8`; returns the first ``n`` elements."""
    out = q.astype(jnp.float32) * scales[:, None]
    return out.reshape(-1)[:n]


def compressed_psum(x: jnp.ndarray, axis_name: Optional[str] = None,
                    err: Optional[jnp.ndarray] = None, *, block: int = 256
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """psum of an int8-compressed payload with error-feedback accumulation.

    The carried residual ``err`` (same shape as ``x``, float32; pass zeros or
    ``None`` on the first step) is added *before* quantization and the new
    residual ``(x + err) - dequantized`` is returned for the next step, so
    the accumulated sum over steps converges to the uncompressed sum.

    ``axis_name=None`` degenerates to the single-device identity (no psum) —
    the form the local-mesh tests and the CPU container exercise.

    Returns ``(summed, new_err)``.
    """
    xf = x.astype(jnp.float32)
    carry = xf if err is None else xf + err.astype(jnp.float32)
    q, scales = quantize_int8(carry, block)
    deq = dequantize_int8(q, scales, carry.size).reshape(carry.shape)
    new_err = carry - deq
    out = deq if axis_name is None else jax.lax.psum(deq, axis_name)
    return out.astype(x.dtype), new_err
