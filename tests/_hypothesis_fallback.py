"""Deterministic, dependency-free stand-in for the slice of the `hypothesis`
API this repo's tests use (``given`` / ``settings`` / ``strategies``).

Installed into ``sys.modules`` by ``tests/conftest.py`` ONLY when the real
package is not importable (the container bakes jax but not hypothesis; CI
installs ``requirements-dev.txt`` and gets the real engine). Not a general
property-testing engine: no shrinking, no example database. Examples come
from a PRNG seeded off the test's qualified name — stable across runs — and
each strategy emits its bounds with elevated probability so edge cases are
always covered.
"""

from __future__ import annotations

import functools
import inspect
import random
import sys
import types
import zlib

DEFAULT_MAX_EXAMPLES = 20


class _Unsatisfied(Exception):
    pass


class _Strategy:
    def __init__(self, draw):
        self.draw = draw


def integers(min_value=None, max_value=None):
    lo = -(2 ** 16) if min_value is None else int(min_value)
    hi = 2 ** 16 if max_value is None else int(max_value)

    def draw(rnd):
        r = rnd.random()
        if r < 0.1:
            return lo
        if r < 0.2:
            return hi
        return rnd.randint(lo, hi)
    return _Strategy(draw)


def floats(min_value=None, max_value=None, **_kwargs):
    lo = 0.0 if min_value is None else float(min_value)
    hi = 1.0 if max_value is None else float(max_value)

    def draw(rnd):
        r = rnd.random()
        if r < 0.1:
            return lo
        if r < 0.2:
            return hi
        return rnd.uniform(lo, hi)
    return _Strategy(draw)


def lists(elements, min_size=0, max_size=None, **_kwargs):
    hi = min_size + 10 if max_size is None else max_size

    def draw(rnd):
        k = rnd.randint(min_size, hi)
        return [elements.draw(rnd) for _ in range(k)]
    return _Strategy(draw)


def tuples(*strategies):
    return _Strategy(lambda rnd: tuple(s.draw(rnd) for s in strategies))


def sampled_from(options):
    opts = list(options)
    return _Strategy(lambda rnd: opts[rnd.randrange(len(opts))])


def randoms(**_kwargs):
    return _Strategy(lambda rnd: random.Random(rnd.randrange(2 ** 31)))


def booleans():
    return _Strategy(lambda rnd: rnd.random() < 0.5)


def just(value):
    return _Strategy(lambda rnd: value)


def one_of(*strategies):
    return _Strategy(lambda rnd: rnd.choice(strategies).draw(rnd))


def assume(condition):
    if not condition:
        raise _Unsatisfied()
    return True


def settings(max_examples=None, deadline=None, **_kwargs):
    def deco(fn):
        fn._fallback_settings = {"max_examples": max_examples}
        return fn
    return deco


def given(*strategies):
    """Run the test over deterministic examples of each strategy.

    The wrapper's signature drops the rightmost ``len(strategies)``
    parameters (the ones ``given`` fills) so pytest does not try to resolve
    them as fixtures — mirroring real hypothesis.
    """
    def deco(fn):
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())
        kept = params[:len(params) - len(strategies)]

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            conf = getattr(wrapper, "_fallback_settings", None) or {}
            n = conf.get("max_examples") or DEFAULT_MAX_EXAMPLES
            seed0 = zlib.crc32(fn.__qualname__.encode())
            for i in range(n):
                rnd = random.Random(seed0 * 100003 + i)
                vals = [s.draw(rnd) for s in strategies]
                try:
                    fn(*args, *vals, **kwargs)
                except _Unsatisfied:
                    continue
                except BaseException:
                    print(f"[hypothesis-fallback] falsifying example #{i}: "
                          f"{vals!r}")
                    raise

        wrapper.__signature__ = sig.replace(parameters=kept)
        return wrapper
    return deco


def install() -> None:
    """Register the shim as ``hypothesis`` / ``hypothesis.strategies``."""
    mod = types.ModuleType("hypothesis")
    st = types.ModuleType("hypothesis.strategies")
    for f in (integers, floats, lists, tuples, sampled_from, randoms,
              booleans, just, one_of):
        setattr(st, f.__name__, f)
    mod.given = given
    mod.settings = settings
    mod.assume = assume
    mod.strategies = st
    mod.HealthCheck = types.SimpleNamespace(all=staticmethod(lambda: []))
    mod.__version__ = "0.0.fallback"
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st
