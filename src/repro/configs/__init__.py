"""Architecture config registry.

Each assigned architecture lives in its own module exporting ``CONFIG``.
``get_config(arch_id)`` returns the full published config; ``smoke_config``
shrinks any config to a CPU-runnable size for smoke tests (same family, same
code paths, tiny dims).
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple

FAMILIES = ("dense", "moe", "mla", "hybrid", "ssm_xlstm", "encoder_audio", "vlm")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # one of FAMILIES
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int                        # dense FFN hidden (0 => no separate FFN, e.g. xLSTM)
    vocab: int
    head_dim: int = 0                # 0 => d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 1e6
    tie_embeddings: bool = False
    norm_eps: float = 1e-6

    # --- MoE (family == "moe") ---
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.001

    # --- MLA (family == "mla") ---
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    rope_head_dim: int = 0
    nope_head_dim: int = 0
    v_head_dim: int = 0

    # --- SSM / hybrid (families "hybrid", "ssm_xlstm") ---
    ssm_state: int = 0
    ssm_expand: int = 2              # d_inner = ssm_expand * d_model (hymba mamba heads)
    ssm_conv: int = 4
    attn_window: int = 0             # sliding-window attention width (hybrid long ctx); 0 => full
    mlstm_every: int = 2             # xLSTM: every k-th block is mLSTM (others sLSTM)
    proj_factor_mlstm: float = 2.0   # xLSTM block expansion
    proj_factor_slstm: float = 1.3334

    # --- modality stubs ---
    frontend: str = "none"           # "none" | "audio_frames" | "vit_patches"
    n_vision_tokens: int = 0         # vlm: patch tokens prepended inside seq_len

    # --- structural flags ---
    causal: bool = True              # False => encoder-only (no decode shapes)
    remat_block: int = 1             # layers per remat unit (coarser blocks
                                     # halve saved activations per unit)

    def __post_init__(self):
        if self.family not in FAMILIES:
            raise ValueError(f"unknown family {self.family!r}")
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    # ---- derived quantities -------------------------------------------------
    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def param_count(self) -> int:
        """Total parameter count N (used for MODEL_FLOPS = 6*N*D)."""
        return _param_count(self, active_only=False)

    def active_param_count(self) -> int:
        """Activated parameters per token (MoE: only top_k experts)."""
        return _param_count(self, active_only=True)

    @property
    def sub_quadratic(self) -> bool:
        """True if long-context decode is feasible (bounded state per token)."""
        return self.family in ("hybrid", "ssm_xlstm")

    @property
    def supports_decode(self) -> bool:
        return self.causal


def _param_count(c: ModelConfig, active_only: bool) -> int:
    d = c.d_model
    emb = c.vocab * d * (1 if c.tie_embeddings else 2)
    per_layer = 0
    if c.family == "mla":
        qk_head = c.nope_head_dim + c.rope_head_dim
        per_layer += d * c.q_lora_rank + c.q_lora_rank * c.n_heads * qk_head
        per_layer += d * (c.kv_lora_rank + c.rope_head_dim)
        per_layer += c.kv_lora_rank * c.n_heads * (c.nope_head_dim + c.v_head_dim)
        per_layer += c.n_heads * c.v_head_dim * d
    elif c.family == "ssm_xlstm":
        # mLSTM / sLSTM blocks: projections + gates (approximate but counted
        # exactly from the layer definitions in models/xlstm.py).
        d_in_m = int(c.proj_factor_mlstm * d)
        d_in_s = d  # sLSTM operates at model width
        n_m = sum(1 for i in range(c.n_layers) if i % c.mlstm_every == 0)
        n_s = c.n_layers - n_m
        m_block = 2 * d * d_in_m + 3 * d_in_m * d_in_m // c.n_heads + d_in_m * d
        ff_s = int(c.proj_factor_slstm * d)
        s_block = 4 * d_in_s * d_in_s + 4 * d_in_s * (d_in_s // c.n_heads) + 3 * d * ff_s
        return emb + n_m * m_block + n_s * s_block
    else:
        per_layer += d * c.q_dim + d * c.kv_dim * 2 + c.q_dim * d  # q, k, v, o
        if c.qkv_bias:
            per_layer += c.q_dim + 2 * c.kv_dim
    if c.family == "hybrid":
        d_inner = c.ssm_expand * d
        per_layer += d * d_inner * 2          # in_proj (x, z)
        per_layer += d_inner * (c.ssm_state * 2 + 1)  # B, C, dt projections (fused, low rank)
        per_layer += d_inner * c.ssm_conv + d_inner   # conv + A/D
        per_layer += d_inner * d              # out proj (shared with attn out add)
    if c.family == "moe":
        e = c.n_experts if not active_only else c.top_k
        per_layer += d * c.n_experts          # router
        per_layer += e * 3 * d * c.d_ff_expert
    elif c.d_ff > 0:
        per_layer += 3 * d * c.d_ff           # swiglu gate/up/down
    per_layer += 2 * d                        # norms
    return emb + c.n_layers * per_layer


_REGISTRY = {
    "qwen3-moe-235b-a22b": "repro.configs.qwen3_moe_235b_a22b",
    "qwen3-moe-30b-a3b": "repro.configs.qwen3_moe_30b_a3b",
    "qwen1.5-110b": "repro.configs.qwen1_5_110b",
    "yi-34b": "repro.configs.yi_34b",
    "minicpm3-4b": "repro.configs.minicpm3_4b",
    "granite-3-8b": "repro.configs.granite_3_8b",
    "hubert-xlarge": "repro.configs.hubert_xlarge",
    "hymba-1.5b": "repro.configs.hymba_1_5b",
    "internvl2-2b": "repro.configs.internvl2_2b",
    "xlstm-125m": "repro.configs.xlstm_125m",
    "paper-lm-100m": "repro.configs.paper_lm_100m",
}

ARCH_IDS: Tuple[str, ...] = tuple(k for k in _REGISTRY if k != "paper-lm-100m")


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_REGISTRY)}")
    return importlib.import_module(_REGISTRY[arch_id]).CONFIG


def smoke_config(arch_id: str, *, n_layers: int = 2, vocab: int = 256) -> ModelConfig:
    """Shrink a config to CPU-smoke size, preserving family & code paths."""
    c = get_config(arch_id)
    kw = dict(
        name=c.name + "-smoke", family=c.family, n_layers=n_layers,
        d_model=64, n_heads=4, n_kv_heads=min(c.n_kv_heads, 2) or 2,
        d_ff=128 if c.d_ff else 0, vocab=vocab, head_dim=16,
        qkv_bias=c.qkv_bias, tie_embeddings=c.tie_embeddings, causal=c.causal,
        frontend=c.frontend,
    )
    if c.family == "moe":
        kw.update(n_experts=4, top_k=2, d_ff_expert=32, d_ff=0)
    if c.family == "mla":
        kw.update(q_lora_rank=32, kv_lora_rank=16, rope_head_dim=8,
                  nope_head_dim=8, v_head_dim=16, head_dim=16)
    if c.family == "hybrid":
        kw.update(ssm_state=8, ssm_expand=2, ssm_conv=4, attn_window=32)
    if c.family == "ssm_xlstm":
        kw.update(mlstm_every=c.mlstm_every, d_ff=0)
    if c.family == "vlm":
        kw.update(n_vision_tokens=4)
    return ModelConfig(**kw)
