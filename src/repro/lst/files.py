"""Log-structured-table metadata model (Iceberg-semantics).

A table version (Snapshot) references a *manifest list*, which references
*manifest files*, which reference immutable *data files*. Every metadata
object is itself persisted through the ObjectStore, so metadata churn
contributes to small-file proliferation exactly as §2 of the paper describes
("Iceberg introduces additional metadata ... This added metadata contributes
to small file proliferation").
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class DataFile:
    path: str
    size_bytes: int
    num_rows: int
    partition: Optional[str] = None      # partition key value ("" = unpartitioned)
    created_at: float = 0.0              # logical time
    min_key: Optional[int] = None
    max_key: Optional[int] = None

    def to_json(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @staticmethod
    def from_json(d: Dict[str, Any]) -> "DataFile":
        return DataFile(**d)


@dataclasses.dataclass(frozen=True)
class ManifestFile:
    path: str
    added: Tuple[DataFile, ...] = ()
    removed: Tuple[str, ...] = ()        # removed data-file paths

    def serialize(self) -> bytes:
        return json.dumps({
            "added": [f.to_json() for f in self.added],
            "removed": list(self.removed),
        }).encode()

    @staticmethod
    def deserialize(path: str, raw: bytes) -> "ManifestFile":
        d = json.loads(raw.decode())
        return ManifestFile(path,
                            tuple(DataFile.from_json(f) for f in d["added"]),
                            tuple(d["removed"]))


@dataclasses.dataclass(frozen=True)
class Snapshot:
    snapshot_id: int
    parent_id: Optional[int]
    sequence_number: int
    timestamp: float
    operation: str                       # append | delete | overwrite | replace
    manifest_list_path: str
    summary: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def to_json(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @staticmethod
    def from_json(d: Dict[str, Any]) -> "Snapshot":
        return Snapshot(**d)


@dataclasses.dataclass
class TableMetadata:
    table_id: str
    partition_spec: Optional[str]        # name of the partition column (or None)
    properties: Dict[str, Any]
    snapshots: List[Snapshot]
    current_snapshot_id: Optional[int]
    version: int = 0
    created_at: float = 0.0
    last_write_at: float = 0.0

    def current(self) -> Optional[Snapshot]:
        for s in self.snapshots:
            if s.snapshot_id == self.current_snapshot_id:
                return s
        return None

    def serialize(self) -> bytes:
        d = dataclasses.asdict(self)
        return json.dumps(d).encode()
