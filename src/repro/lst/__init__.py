from repro.lst.files import DataFile, ManifestFile, Snapshot, TableMetadata  # noqa
from repro.lst.storage import InMemoryStore, LocalFSStore, ObjectStore  # noqa
from repro.lst.table import CommitConflict, LogStructuredTable, Transaction  # noqa
from repro.lst.catalog import Catalog, Namespace  # noqa
from repro.lst.retention import (DeleteRoute, PredicateDelete,  # noqa
                                 RetentionPolicy, execute_file_drops,
                                 plan_rewrite_delete, route_delete)
