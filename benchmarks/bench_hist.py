"""Figs. 1/2 — file-size distribution: raw-ingestion vs user-derived tables,
and the distribution shift from compaction (fraction of files < 128MB,
the paper's headline 83% -> 62% -> 44%-style reduction metric)."""

from __future__ import annotations

from typing import List

from benchmarks.workload_sim import make_pipeline
from repro.lst import Catalog, InMemoryStore
from repro.lst.files import DataFile
from repro.lst.workload import SimClock, WorkloadGenerator, WorkloadSpec

MB = 1 << 20


def _small_frac(catalog, cutoff=128 * MB) -> float:
    files = [f for t in catalog.tables() for f in t.current_files()]
    if not files:
        return 0.0
    return sum(1 for f in files if f.size_bytes < cutoff) / len(files)


def main() -> List[str]:
    clock = SimClock()
    store = InMemoryStore()
    catalog = Catalog(store, now_fn=clock.now)
    gen = WorkloadGenerator(catalog, WorkloadSpec(
        n_databases=3, tables_per_db=4, seed=9), clock)
    gen.setup()

    # raw-ingestion table: central pipeline writes ~512MB files (Fig. 1 left)
    raw = catalog.create_table("ingest", "events_raw", "hour")
    raw.now_fn = clock.now
    raw.append([DataFile(f"{raw.table_id}/data/f{i}.parquet",
                         int(512 * MB * 0.95), 10_000, "h0", clock.now())
                for i in range(40)])

    for _ in range(2):
        gen.run_hour()
    rows = [f"fig1_small_frac[raw_ingestion],"
            f"{sum(1 for f in raw.current_files() if f.size_bytes < 128*MB)/raw.file_count():.3f},files={raw.file_count()}",
            f"fig1_small_frac[user_derived],{_small_frac(catalog):.3f},"
            f"files={sum(t.file_count() for t in catalog.tables())}"]

    before = _small_frac(catalog)
    manual = make_pipeline("table", k=3)       # manual: few hand-picked
    manual.run_cycle(catalog)
    after_manual = _small_frac(catalog)
    auto = make_pipeline("hybrid", k=50)
    auto.run_cycle(catalog)
    after_auto = _small_frac(catalog)
    rows.append(f"fig2_small_frac[before],{before:.3f},cutoff=128MB")
    rows.append(f"fig2_small_frac[manual],{after_manual:.3f},k=3")
    rows.append(f"fig2_small_frac[autocomp],{after_auto:.3f},hybrid-50")
    return rows


if __name__ == "__main__":
    for r in main():
        print(r)
