"""Production mesh factory.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state. The dry-run entry point sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import so 512 placeholder host devices exist; everything else (tests,
benches, examples) sees the real single CPU device.
"""

from __future__ import annotations

import jax


def _auto_kw(n):
    # jax.sharding.AxisType landed after 0.4.x; older jax has neither the
    # enum nor the make_mesh kwarg, and Auto is its default behavior anyway.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_auto_kw(len(axes)))


def make_local_mesh(model_parallel: int = 1):
    """Mesh over whatever devices actually exist (tests / examples)."""
    n = jax.device_count()
    assert n % model_parallel == 0
    return jax.make_mesh((n // model_parallel, model_parallel),
                         ("data", "model"), **_auto_kw(2))
