"""The docs lint that tier-1 CI runs (scripts/check_docs.py): package
README presence, relative-link resolution, and the real repo passing."""

import importlib.util
import os

_SPEC = importlib.util.spec_from_file_location(
    "check_docs",
    os.path.join(os.path.dirname(__file__), "..", "scripts",
                 "check_docs.py"))
check_docs = importlib.util.module_from_spec(_SPEC)
_SPEC.loader.exec_module(check_docs)


def _mk_repo(tmp_path, readme_for=("good",), links=""):
    src = tmp_path / "src" / "repro"
    for name in ("good", "bare"):
        pkg = src / name
        pkg.mkdir(parents=True)
        (pkg / "__init__.py").write_text("")
        if name in readme_for:
            body = links if name == "good" else ""
            (pkg / "README.md").write_text(f"# {name}\n{body}")
    # a plain directory (no __init__.py) is NOT a package: no README owed
    (src / "scriptsdir").mkdir()
    return tmp_path


class TestCheckDocs:
    def test_missing_package_readme_reported(self, tmp_path):
        root = _mk_repo(tmp_path, readme_for=("good",))
        missing = check_docs.missing_readmes(root)
        assert len(missing) == 1 and "bare" in missing[0]

    def test_non_package_dir_owes_nothing(self, tmp_path):
        root = _mk_repo(tmp_path, readme_for=("good", "bare"))
        assert check_docs.missing_readmes(root) == []

    def test_broken_relative_link_reported(self, tmp_path):
        root = _mk_repo(tmp_path, readme_for=("good", "bare"),
                        links="see [other](../nowhere/README.md)")
        broken = check_docs.broken_links(root)
        assert len(broken) == 1 and "nowhere" in broken[0]

    def test_resolving_links_and_anchors_pass(self, tmp_path):
        root = _mk_repo(
            tmp_path, readme_for=("good", "bare"),
            links="[peer](../bare/README.md#section) "
                  "[web](https://example.com) [anchor](#local)")
        assert check_docs.broken_links(root) == []

    def test_this_repo_is_clean(self):
        root = check_docs.repo_root()
        assert check_docs.missing_readmes(root) == []
        assert check_docs.broken_links(root) == []
        # the spine the ISSUE demands actually exists
        assert (root / "README.md").exists()
        assert (root / "src" / "repro" / "lst" / "README.md").exists()
