"""HuBERT-XLarge [arXiv:2106.07447; audio encoder-only].

48L d_model=1280 16H d_ff=5120 vocab=504 (masked-unit prediction targets).
Encoder-only (bidirectional, no decode shapes). The conv waveform frontend is
a STUB: input_specs() supplies precomputed frame embeddings (B, S, d_model).
"""
from repro.configs import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="encoder_audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab=504,
    head_dim=80,
    causal=False,
    frontend="audio_frames",
)
