"""Token-run compaction kernel — the AutoComp rewrite inner loop on TPU.

Hardware adaptation (DESIGN.md §2): the Spark executor's file-rewrite loop
(read many small fragments, emit few target-size files) becomes a
scalar-prefetched DMA gather. Token shards are written 128x8-aligned
(CHUNK = 1024 tokens = an (8, 128) int32 VMEM tile), so compacting F
fragments into dense output blocks is a *permutation of aligned chunks*:
no compute, pure data movement — exactly what the TPU DMA engine does well.

The chunk index map rides in scalar-prefetch SMEM (PrefetchScalarGridSpec);
the BlockSpec index_map dereferences it, so the Pallas pipeline issues the
HBM->VMEM->HBM copies with double buffering. The kernel body is a single
VMEM tile copy.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

CHUNK_ROWS = 8
CHUNK_COLS = 128
CHUNK_TOKENS = CHUNK_ROWS * CHUNK_COLS  # 1024


def _copy_kernel(idx_ref, src_ref, out_ref):
    del idx_ref  # consumed by the BlockSpec index maps
    out_ref[...] = src_ref[...]


def compact_chunks_kernel(src: jnp.ndarray, chunk_map: jnp.ndarray,
                          interpret: bool = False) -> jnp.ndarray:
    """Gather chunks of ``src`` according to ``chunk_map``.

    src: (n_src_chunks, CHUNK_ROWS, CHUNK_COLS) any dtype
    chunk_map: (n_out_chunks,) int32 -- source chunk id per output chunk
    returns (n_out_chunks, CHUNK_ROWS, CHUNK_COLS)
    """
    n_out = chunk_map.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_out,),
        in_specs=[
            pl.BlockSpec((1, CHUNK_ROWS, CHUNK_COLS),
                         lambda i, idx_ref: (idx_ref[i], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, CHUNK_ROWS, CHUNK_COLS),
                               lambda i, idx_ref: (i, 0, 0)),
    )
    return pl.pallas_call(
        _copy_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(
            (n_out, CHUNK_ROWS, CHUNK_COLS), src.dtype),
        interpret=interpret,
    )(chunk_map, src)
