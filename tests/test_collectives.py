"""Unit coverage for repro.dist.collectives beyond the hypothesis bounds in
test_dist.py: zero blocks, ragged tails, and the compressed_psum carry API."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.collectives import (compressed_psum, dequantize_int8,
                                    quantize_int8)


class TestQuantize:
    def test_zero_vector_roundtrips_exactly(self):
        x = jnp.zeros((300,), jnp.float32)
        q, s = quantize_int8(x, block=128)
        assert q.dtype == jnp.int8
        out = dequantize_int8(q, s, 300)
        np.testing.assert_array_equal(np.asarray(out), 0.0)

    def test_ragged_tail_padding(self):
        rng = np.random.RandomState(0)
        x = jnp.asarray(rng.randn(1000), jnp.float32)   # 1000 % 256 != 0
        q, s = quantize_int8(x, block=256)
        assert q.shape == (4, 256) and s.shape == (4,)
        out = dequantize_int8(q, s, 1000)
        assert out.shape == (1000,)
        bound = float(jnp.max(jnp.abs(x))) / 127.0 + 1e-6
        assert float(jnp.max(jnp.abs(out - x))) <= bound

    def test_jit_compatible(self):
        x = jnp.linspace(-3.0, 3.0, 512)

        @jax.jit
        def roundtrip(v):
            q, s = quantize_int8(v, block=64)
            return dequantize_int8(q, s, v.shape[0])

        out = roundtrip(x)
        assert float(jnp.max(jnp.abs(out - x))) <= 3.0 / 127.0 + 1e-6


class TestCompressedPsum:
    def test_single_device_identity_with_error_feedback(self):
        """axis_name=None degenerates to quantize->dequantize; carrying the
        residual keeps the accumulated sum unbiased (DRAGONN-style EF)."""
        rng = np.random.RandomState(3)
        x = jnp.asarray(rng.randn(512), jnp.float32)
        err = jnp.zeros_like(x)
        acc = jnp.zeros_like(x)
        steps = 16
        for _ in range(steps):
            out, err = compressed_psum(x, None, err, block=64)
            acc = acc + out
        rel = float(jnp.linalg.norm(acc - steps * x)
                    / jnp.linalg.norm(steps * x))
        assert rel < 0.02

    def test_first_step_accepts_none_err(self):
        x = jnp.ones((64,), jnp.float32)
        out, err = compressed_psum(x, None, None, block=32)
        assert out.shape == x.shape and err.shape == x.shape

    def test_preserves_dtype_and_shape(self):
        x = jnp.ones((4, 32), jnp.bfloat16)
        out, err = compressed_psum(x, None, None, block=16)
        assert out.dtype == jnp.bfloat16 and out.shape == (4, 32)
        assert err.dtype == jnp.float32
