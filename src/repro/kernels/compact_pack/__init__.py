from repro.kernels.compact_pack.ops import compact_chunks, plan_compaction  # noqa
