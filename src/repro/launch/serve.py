"""Serving launcher: mesh-placed batched prefill + decode with a sharded
KV cache and a quantized activation-collective transport.

``python -m repro.launch.serve --arch paper-lm-100m --smoke`` runs a
batched generation loop with the reduced config on a local mesh built over
whatever devices exist (1 CPU device degrades to a (1, 1) mesh; the CI
multidevice job forces 8 host devices and gets a real (data, model) mesh).
Params, KV cache, and batch are explicitly placed: the ``serve_sp`` preset
shards the cache over data (batch dim) x model (sequence dim) and the
residual stream over sequence, and ``--act-transport int8`` runs the
sequence-parallel activation all-gathers as blockwise-int8 chunks + scales
(``repro.dist.collectives.act_gather``). Full configs lower on the
production mesh via the dry-run (``repro.launch.dryrun --shape decode``).

Continuous batching: requests at different positions share one decode step
(``prompt_lens`` gives per-row lengths; positions/masks are per-row, so
padded prompt slots are never attended — same semantics the decode_attn
Pallas kernel implements on TPU).
"""

from __future__ import annotations

import argparse
import contextlib
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.dist import sharding as shd
from repro.launch.mesh import make_local_mesh
from repro.models import transformer
from repro.train import step as step_lib


def grow_cache(cache, target):
    """Grow every cache leaf to the decode-horizon shape (end-padding).

    ``target`` is the abstract decode cache, so windowed/SSM/xLSTM states
    are handled uniformly: leaves already at the target shape only cast,
    anything smaller pads with zeros at the end of each dimension (new
    slots read as empty and are masked by slot-position validity until
    written).
    """
    def grow(c, tgt):
        if c.shape == tgt.shape:
            return c.astype(tgt.dtype)
        pad = [(0, t - s) for s, t in zip(c.shape, tgt.shape)]
        return jnp.pad(c, pad).astype(tgt.dtype)

    return jax.tree.map(grow, cache, target)


def generate(cfg, params, prompts: np.ndarray, max_new: int = 16,
             temperature: float = 0.0, seed: int = 0,
             prompt_lens: Optional[np.ndarray] = None,
             mesh=None, rules=None, act_transport: str = "bf16"):
    """prompts: (B, S0) int32, right-padded when ragged. Greedy (or
    sampled) decode of ``max_new`` tokens per row.

    ``prompt_lens`` (B,) enables ragged continuous batching: row i's real
    prompt is ``prompts[i, :prompt_lens[i]]``; every row decodes from its
    own position and pad slots are masked (each row's output matches a
    solo run of its unpadded prompt). ``mesh`` places params/cache/batch
    explicitly (``rules`` defaults to the ``serve_sp`` preset);
    ``act_transport`` picks the activation all-gather wire format.
    """
    b, s0 = prompts.shape
    total = s0 + max_new
    ragged = prompt_lens is not None
    lens = np.asarray(prompt_lens, np.int32) if ragged else None
    if ragged:
        assert lens.shape == (b,) and (lens >= 1).all() and (lens <= s0).all()
        # Ragged masking is only sound for full (slot == position) caches:
        # ring buffers alias a padded position's junk slot to an in-window
        # position before the row overwrites it, and SSM/xLSTM recurrent
        # states scan pad tokens in during prefill — per-row masks cannot
        # undo either. Refuse loudly rather than drift from solo runs.
        if cfg.attn_window or cfg.family in ("hybrid", "ssm_xlstm"):
            raise NotImplementedError(
                f"ragged prompt_lens is unsupported for {cfg.name}: "
                "windowed (ring-buffer) and recurrent-state families need "
                "per-row prefill masking; pad to a uniform length instead")

    if mesh is not None and rules is None:
        rules = shd.PRESETS["serve_sp"]
    ctx = shd.axis_rules(mesh, rules) if mesh is not None \
        else contextlib.nullcontext()

    prefill_fn = step_lib.make_prefill_step(cfg, act_transport)
    decode_fn = step_lib.make_decode_step(cfg, total, act_transport)

    with ctx:
        c_shard = None
        if mesh is not None:
            p_shard = shd.tree_shardings(transformer.abstract_params(cfg),
                                         transformer.param_axes(cfg),
                                         mesh, rules)
            params = jax.device_put(params, p_shard)
            c_abs = transformer.abstract_cache(cfg, b, total)
            c_axes = transformer.cache_axes(cfg, b, total)
            c_shard = shd.tree_shardings(c_abs, c_axes, mesh, rules)
            prefill = jax.jit(prefill_fn)
            decode = jax.jit(decode_fn, out_shardings=(None, c_shard))
        else:
            prefill = jax.jit(prefill_fn)
            decode = jax.jit(decode_fn)

        pre_batch = {"tokens": jnp.asarray(prompts)}
        if ragged:
            pre_batch["last_pos"] = jnp.asarray(lens - 1)
        logits, cache = prefill(params, pre_batch)
        cache = grow_cache(cache, transformer.abstract_cache(cfg, b, total))
        if c_shard is not None:
            # commit the grown cache to its serve_sp placement; decode's
            # out_shardings keep it resident there across the loop
            cache = jax.device_put(cache, c_shard)

        key = jax.random.PRNGKey(seed)
        out_tokens = []
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        for i in range(max_new):
            out_tokens.append(np.asarray(tok))
            pos = jnp.asarray(lens + i) if ragged \
                else jnp.asarray(s0 + i, jnp.int32)
            logits, cache = decode(params, cache, {"tokens": tok, "pos": pos})
            if temperature > 0:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(sub, logits / temperature
                                             ).astype(jnp.int32)[:, None]
            else:
                tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    return np.concatenate(out_tokens, axis=1)


def _pick_tp(n_devices: int, cfg) -> int:
    """Largest model-parallel degree (<= 2) the device count and head
    counts admit — the smoke default; override with --tp."""
    for tp in (2, 1):
        if n_devices % tp == 0 and cfg.n_heads % tp == 0:
            return tp
    return 1


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--tp", type=int, default=0,
                    help="model-parallel degree (0 = auto)")
    ap.add_argument("--preset", default="serve_sp",
                    choices=sorted(shd.PRESETS))
    ap.add_argument("--act-transport", default="bf16",
                    choices=list(step_lib.ACT_TRANSPORTS))
    ap.add_argument("--ragged", action="store_true",
                    help="serve a mixed-length batch (continuous batching)")
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if not cfg.supports_decode:
        raise SystemExit(f"{cfg.name} is encoder-only; no decode serving")
    tp = args.tp or _pick_tp(jax.device_count(), cfg)
    mesh = make_local_mesh(model_parallel=tp)
    rules = shd.PRESETS[args.preset]

    key = jax.random.PRNGKey(0)
    params = transformer.init_params(cfg, key)
    rng = np.random.RandomState(0)
    prompts = rng.randint(0, cfg.vocab,
                          size=(args.batch, args.prompt_len)).astype(np.int32)
    lens = None
    if args.ragged:
        lens = rng.randint(max(1, args.prompt_len // 2), args.prompt_len + 1,
                           size=(args.batch,)).astype(np.int32)

    t0 = time.time()
    out = generate(cfg, params, prompts, max_new=args.max_new,
                   temperature=args.temperature, prompt_lens=lens,
                   mesh=mesh, rules=rules, act_transport=args.act_transport)
    dt = time.time() - t0
    n_tok = out.size
    print(f"[serve] arch={cfg.name} batch={args.batch} "
          f"prompt={args.prompt_len} new={args.max_new} "
          f"mesh={dict(zip(mesh.axis_names, mesh.devices.shape))} "
          f"preset={args.preset} act_transport={args.act_transport}"
          + (f" lens={lens.tolist()}" if lens is not None else ""))
    print(f"[serve] generated {n_tok} tokens in {dt:.2f}s "
          f"({n_tok/dt:.1f} tok/s incl. compile)")
    print("[serve] sample:", out[0][:10])


if __name__ == "__main__":
    main()
