"""Block/grid sweep harness: ``core.autotune.tune_design`` over the
kernel registry.

``tune_op`` tunes one registered op on representative operands: the
candidate axes are clamped to the operand extents (``api.clamped_axes``),
each point is timed (compile excluded, median of ``iters`` reps), and the
winner is persisted to the tuned-point cache (``repro.kernels.tuned``)
keyed by (op, shape_key, device_kind). A second run for the same cell is
served from the cache with ZERO re-evaluations — serving and fleet
compaction pick up tuned blocks at op-call time without ever recompiling
a sweep.

Kernel spaces are small (a few block-size candidates per axis), so the
sweep runs ``tune_design`` exhaustively when the clamped grid is tiny and
falls back to the coordinate-descent hillclimb above ``EXHAUSTIVE_MAX``
points — same memoized, deterministic walk the serve design space uses.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Optional, Tuple

import jax

from repro.core.autotune import tune_design
from repro.kernels import api, tuned

EXHAUSTIVE_MAX = 64                     # full grid at or below this size


@dataclasses.dataclass
class TuneOutcome:
    op: str
    shape_key: str
    point: Dict[str, Any]               # winning (clamped) point
    default: Dict[str, Any]             # clamped default for this cell
    objective_us: float
    evaluations: int                    # 0 on a cache hit
    cache_hit: bool
    history: Tuple = ()


def time_point(op: api.TunableOp, point: Dict[str, Any], args, kwargs,
               iters: int = 3) -> float:
    """Median wall microseconds of the op at one point (first call warms
    the compile cache and is excluded)."""
    jax.block_until_ready(op.run(dict(point), *args, **kwargs))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(op.run(dict(point), *args, **kwargs))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def tune_op(name: str, args: Optional[tuple] = None,
            kwargs: Optional[dict] = None, *, quick: bool = True,
            iters: int = 3, force: bool = False) -> TuneOutcome:
    """Tune one op for one operand cell; cache-first.

    ``args``/``kwargs`` default to the op's registered example shapes
    (``quick`` picks the CI-smoke cell). ``force=True`` re-sweeps even on
    a cache hit (the nightly refresh path).
    """
    op = api.get_op(name)
    if args is None:
        args, kwargs = op.example(quick)
    kwargs = dict(kwargs or {})
    skey = op.shape_key(*args, **kwargs)
    base = op.clamp(api.default_point(op), *args, **kwargs)

    if not force:
        cached = tuned.lookup(name, skey)
        if cached is not None:
            rec = tuned.entry(name, skey) or {}
            point = op.clamp({**api.default_point(op), **cached},
                             *args, **kwargs)
            return TuneOutcome(op=name, shape_key=skey, point=point,
                               default=base,
                               objective_us=float(rec.get("objective_us", 0.0)),
                               evaluations=0, cache_hit=True)

    axes = api.clamped_axes(op, *args, **kwargs)
    grid_size = 1
    for vals in axes.values():
        grid_size *= len(vals)

    def evaluate(point: Dict[str, Any]) -> float:
        return time_point(op, op.clamp(dict(point), *args, **kwargs),
                          args, kwargs, iters=iters)

    res = tune_design(evaluate, axes, start=base,
                      exhaustive=grid_size <= EXHAUSTIVE_MAX)
    tuned.store(name, skey, res.best_point, objective_us=res.best_objective,
                evaluations=res.evaluations)
    return TuneOutcome(op=name, shape_key=skey, point=dict(res.best_point),
                       default=base, objective_us=res.best_objective,
                       evaluations=res.evaluations, cache_hit=False,
                       history=tuple(res.history))


def tune_registry(quick: bool = True, iters: int = 3,
                  force: bool = False) -> Dict[str, TuneOutcome]:
    """Sweep every registered op on its example cell (registration order
    is deterministic: the builtin import order in ``api``)."""
    return {name: tune_op(name, quick=quick, iters=iters, force=force)
            for name in api.ops()}
