"""Paged decode-attention wrapper, registered on the tunable-op registry.

``page`` is the paged slot cache's granularity — the axis
``tune_design`` sweeps through ``repro.kernels.tune`` like any other
registered op. The op pages the dense K/V into a (reversed-order) pool,
reads them back through the page table, and runs the flash-decode
kernel, so the sweep prices exactly the gather the paged serve path
pays per step. Paging is pure data movement (the roundtrip is the
identity on every live position), so ``page`` is an *exact* axis: every
candidate produces bit-identical output, and the serve path
(``launch/serve.py --paged --page-size 0``) reads its page size from the
tuned cache via :func:`tuned_page_size` without ever recompiling a
sweep.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import api
from repro.kernels.decode_attn.ops import decode_attention
from repro.kernels.paged_attn.ref import gather_pages, pack_pages

PAGE_CANDIDATES = (64, 128, 256, 512)
DEFAULT_PAGE = 256


@partial(jax.jit, static_argnames=("page",))
def _repage(x, *, page):
    pool, pt = pack_pages(x, page)
    return gather_pages(pool, pt)


def _run(point, q, k, v, lengths):
    page = point["page"]
    return decode_attention(q, _repage(k, page=page),
                            _repage(v, page=page), lengths)


def _ref(q, k, v, lengths):
    from repro.kernels.decode_attn.ref import decode_attention_ref
    return decode_attention_ref(q, k, v, lengths)


def _clamp(point, q, k, v, lengths, **kw):
    return {"page": api.fit_block(point["page"], k.shape[1])}


def _shape_key(q, k, v, lengths, **kw):
    b, h, d = q.shape
    return f"b{b}h{h}kv{k.shape[2]}s{k.shape[1]}d{d}:{q.dtype.name}"


def _example(quick: bool):
    s = 512 if quick else 2048
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (4, 8, 64), jnp.float32).astype(jnp.bfloat16)
    k = jax.random.normal(key, (4, s, 2, 64), jnp.float32).astype(jnp.bfloat16)
    v = jax.random.normal(key, (4, s, 2, 64), jnp.float32).astype(jnp.bfloat16)
    lens = jnp.asarray([s, s // 2, s // 4, 100], jnp.int32)
    return (q, k, v, lens), {}


api.register(api.TunableOp(
    name="paged_attn",
    axes={"page": PAGE_CANDIDATES},
    default={"page": DEFAULT_PAGE},
    run=_run,
    ref=_ref,
    clamp=_clamp,
    shape_key=_shape_key,
    example=_example,
    exact_axes=frozenset({"page"}),
    tol=5e-2,
))


def paged_attention(q, k, v, lengths, *, page=None, use_ref=False):
    """Decode attention over paged K/V (dense inputs, paged internally at
    ``page``; tuned > default when None)."""
    point = None if page is None else {"page": page}
    return api.call("paged_attn", q, k, v, lengths, point=point,
                    use_ref=use_ref)


def tuned_page_size(total: int, *, batch: int = 1, heads: int = 8,
                    kv_heads: int = 2, head_dim: int = 64,
                    dtype=jnp.bfloat16) -> int:
    """The page size serving should use for a ``total``-position cache:
    the persisted tuned point for the matching sweep cell when one
    exists, the registry default otherwise — clamped to divide ``total``
    (divisor-safe, like every tuned block)."""
    op = api.get_op("paged_attn")
    q = jax.ShapeDtypeStruct((batch, heads, head_dim), dtype)
    kv = jax.ShapeDtypeStruct((batch, total, kv_heads, head_dim), dtype)
    lens = jax.ShapeDtypeStruct((batch,), jnp.int32)
    point = api.resolve_point(op, q, kv, kv, lens)
    return api.fit_block(point["page"], total)
