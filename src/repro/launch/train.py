"""Training launcher: ``python -m repro.launch.train --arch <id> ...``

Wires together: config -> model -> sharded train_step (jit with logical-rule
shardings on the local mesh) -> AutoComp-managed data pipeline -> fault-
tolerant Trainer. On this CPU container it runs reduced configs end-to-end;
on a TPU fleet the same entry point runs the full configs (mesh comes from
``jax.devices()``).
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, smoke_config
from repro.core import (AutoCompPipeline, MoopRanker, StatsCollector,
                        TraitContext)
from repro.core.act import Scheduler
from repro.core.model import Scope
from repro.core.orient import (ComputeCostTrait, FileCountReductionTrait,
                               FileEntropyTrait)
from repro.data import DataPipeline, TokenShardWriter, merge_shards_fn
from repro.dist import sharding as shd
from repro.launch.mesh import make_local_mesh
from repro.lst import Catalog, InMemoryStore
from repro.lst.workload import SimClock
from repro.models import transformer
from repro.train import optimizer as opt_lib
from repro.train import step as step_lib
from repro.train.checkpoints import CheckpointManager
from repro.train.runner import RunnerConfig, Trainer


def build_data(cfg, *, batch, seq_len, n_trickle=30, files_per=15,
               tokens_per_file=4096, seed=0):
    clock = SimClock()
    store = InMemoryStore()
    catalog = Catalog(store, now_fn=clock.now)
    table = catalog.create_table("train", "corpus",
                                 properties={"conflict_granularity": "table"})
    table.now_fn = clock.now
    writer = TokenShardWriter(table, vocab=cfg.vocab, seed=seed)
    for _ in range(n_trickle):
        writer.trickle_append(files_per, tokens_per_file)
        clock.advance(0.02)
    pipe = DataPipeline(table, batch=batch, seq_len=seq_len, seed=seed)
    return catalog, table, pipe, clock, store


def build_autocomp(catalog, clock, target_bytes=1 << 22, top_k=4):
    pipeline = AutoCompPipeline(
        stats=StatsCollector(target_bytes),
        traits=(FileCountReductionTrait(), FileEntropyTrait(),
                ComputeCostTrait()),
        trait_ctx=TraitContext(target_file_bytes=target_bytes),
        ranker=MoopRanker({"file_count_reduction": 0.7, "compute_cost": 0.3}),
        scheduler=Scheduler(target_bytes, merge_fn=merge_shards_fn),
        scope=Scope.TABLE, top_k=top_k)
    return pipeline


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper-lm-100m")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config of the arch family")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--grad-transport", default="bf16",
                    choices=step_lib.GRAD_TRANSPORTS,
                    help="int8_ef = blockwise int8 + error feedback on the "
                         "gradient reduction (residual in optimizer state)")
    ap.add_argument("--compact-every", type=int, default=25)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_local_mesh()
    print(f"[train] arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}")

    catalog, table, pipe, clock, store = build_data(
        cfg, batch=args.batch, seq_len=args.seq_len)
    print(f"[data] shard files: {table.file_count()} "
          f"(plan {pipe.plan()[0].path.split('/')[-1]}...)")

    key = jax.random.PRNGKey(0)
    params = transformer.init_params(cfg, key)
    opt_state = opt_lib.init_state(
        params, error_feedback=args.grad_transport == "int8_ef")
    adamw = opt_lib.AdamWConfig(lr=1e-3, warmup_steps=10,
                                total_steps=args.steps)
    with shd.axis_rules(mesh):
        step_fn = jax.jit(step_lib.make_train_step(
            cfg, adamw, microbatches=args.microbatches,
            grad_transport=args.grad_transport))

    ckpt = CheckpointManager(store, keep_last=2)
    autocomp = build_autocomp(catalog, clock)
    state = {"i": 0}

    def tick():
        state["i"] += 1
        clock.advance(0.01)
        if state["i"] % args.compact_every == 0:
            rep = autocomp.run_cycle(catalog)
            if rep.files_removed:
                print(f"[autocomp] cycle: removed {rep.files_removed} files "
                      f"-> table now {table.file_count()} files "
                      f"(gbhr {rep.gbhr:.4f})")

    trainer = Trainer(
        RunnerConfig(total_steps=args.steps, ckpt_every=20),
        step_fn, params, opt_state, pipe.prefetching_batches,
        ckpt=ckpt, autocomp_tick=tick)
    t0 = time.time()
    out = trainer.run_with_recovery()
    dt = time.time() - t0
    losses = [h["loss"] for h in out["history"]]
    print(f"[train] {out['final_step']} steps in {dt:.1f}s "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    assert losses[-1] < losses[0], "training did not reduce loss"
    print(f"[store] objects={store.object_count} "
          f"rpc={store.metrics.rpc_total}")


if __name__ == "__main__":
    main()
