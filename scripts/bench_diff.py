#!/usr/bin/env python
"""Bench trajectory gate: diff a fresh ``BENCH_roofline.json`` against the
previous run's artifact and fail on performance regressions.

The CI ``bench-smoke`` job downloads the ``BENCH_roofline`` artifact from
the last successful main run and calls::

    python scripts/bench_diff.py --current BENCH_roofline.json \
        --baseline baseline/BENCH_roofline.json

Cells are matched by (arch, shape, mesh, preset, grad_transport,
act_transport). A cell regresses when a lower-is-better metric
(``collective_s``) grows, or a higher-is-better metric
(``roofline_fraction``, ``slot_stream_overlap_frac_*``) shrinks, by more
than ``--threshold`` (default 15%). A missing/unreadable baseline is
tolerated (first run, expired artifact): the gate passes with a note.
Cells present on only one side are reported but never fail the gate —
sweeps legitimately grow. A gated METRIC the baseline cell has but the
current cell lost, however, FAILS: a renamed roofline key must not
silently stop being gated.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Any, Dict, List, Optional, Tuple

# metric -> direction: "lower" means growth is a regression, "higher"
# means shrinkage is
METRICS: Dict[str, str] = {
    "collective_s": "lower",
    "roofline_fraction": "higher",
}

# Disaggregated-decode design space (decode cells only; a metric missing
# from BOTH records => skipped, so pre-disagg baselines stay comparable —
# but a metric the baseline HAS that the current record LOST fails the
# gate: a renamed roofline key must not silently stop being gated).
# The per-batch transfer and per-token decode-step components are gated
# individually: the combo sum is transfer-dominated, so a large
# decode-step regression would hide inside it. slot_stream_* are the
# continuous-streaming keys: per-slot wire bytes / transfer time (lower)
# and the double-buffer overlap efficiency (higher — the fraction of a
# slot transfer hidden behind decode steps). Note the overlap frac is a
# RATIO of two gated quantities (hide_steps * decode_step_s /
# slot_transfer_s), so a deliberate >threshold improvement in decode-step
# wire also shrinks it and trips this gate — by design: less decode time
# genuinely hides less transfer, and a PR that changes that trade-off
# must say so (and refresh the baseline by landing) rather than slip by.
_TRANSFERS = ("bf16", "int8")
_STORAGES = ("bf16", "int8", "f8")
for _t in _TRANSFERS:
    METRICS[f"disagg_transfer_s_{_t}"] = "lower"
    METRICS[f"slot_stream_transfer_s_{_t}"] = "lower"
    METRICS[f"slot_stream_wire_bytes_{_t}"] = "lower"
for _s in _STORAGES:
    METRICS[f"disagg_decode_step_s_{_s}"] = "lower"
for _t in _TRANSFERS:
    for _s in _STORAGES:
        METRICS[f"disagg_collective_s_{_t}x{_s}"] = "lower"
        METRICS[f"slot_stream_overlap_frac_{_t}x{_s}"] = "higher"
METRICS["disagg_tuned_collective_s"] = "lower"

# Fleet-scale compaction cells (arch "fleet-sim", benchmarks/bench_fleet.py).
# All lower-is-better: the simulated storm is seeded, so drift means a
# behavior change in the scheduler, not noise. p99 read latency and final
# file count are the user-facing outcomes; gbhr_total bounds compute burn
# under the shared budget; starvation_max_cycles gates the aging invariant
# (a scheduler change that lets fragmented tables wait longer must fail).
for _m in ("fleet_p99_query_s", "fleet_file_count_final",
           "fleet_gbhr_total", "fleet_starvation_max_cycles"):
    METRICS[_m] = "lower"
# Retention cells (shape suffix "_ret", bench_fleet.py --retention):
# rows_dropped is higher-is-better — a scheduler/pricing change that
# starves delete candidates shows up as fewer rows deleted under the same
# budget and must fail; retention_bytes_rewritten is lower-is-better —
# boundary-aligned deletes must stay tier-1 metadata drops, so a router
# change that demotes them to rewrites burns bytes and trips this gate.
METRICS["fleet_rows_dropped"] = "higher"
METRICS["fleet_retention_bytes_rewritten"] = "lower"

# Tunable-kernel cells (arch "kernel", benchmarks/bench_kernels.py --json).
# kernel_<op>_tuned_s is the trajectory the sweep harness must keep
# monotone: serving always reads the tuned point from the persisted cache,
# so a regression here means either the sweep picked a worse point or the
# kernel itself got slower. The filter cells gate the fused filter+pack
# hot path: its step time AND its analytic HBM traffic (plan-derived, so
# deterministic — a plan change that re-reads dropped rows fails even if
# the stopwatch is noisy).
for _op in ("compact_pack", "flash_attn", "decode_attn", "paged_attn",
            "rmsnorm", "expert_a2a"):
    METRICS[f"kernel_{_op}_tuned_s"] = "lower"
METRICS["kernel_compact_filter_s"] = "lower"
METRICS["kernel_compact_filter_hbm_bytes"] = "lower"

# Fan-in arbitration keys (decode cells, serve.fanin_report — a
# deterministic simulation driving the real AdmissionArbiter, so drift is
# a queue-discipline change, not noise). fanin_admission_wait_s is the
# mean per-admission latency (queue wait + unhidden transfer);
# fanin_evictions counts preemptions the policy performed (each costs a
# re-prefill of the extended prompt, so an arbiter change that thrashes
# the slot table must fail); paged_hbm_bytes_per_slot is the paged slot
# cache's live-page resident rent — the saving over the dense
# pad-to-horizon layout the paged table exists to buy, gated so a paging
# change cannot silently give it back.
for _m in ("fanin_admission_wait_s", "fanin_evictions",
           "paged_hbm_bytes_per_slot"):
    METRICS[_m] = "lower"

DEFAULT_THRESHOLD = 0.15


def cell_key(rec: Dict[str, Any]) -> Tuple:
    # every field that names a distinct dry-run variant must participate,
    # or variant cells silently collide and diff against the wrong baseline
    return (rec.get("arch"), rec.get("shape"), rec.get("mesh"),
            rec.get("preset"), rec.get("grad_transport"),
            rec.get("act_transport"), rec.get("microbatches"),
            rec.get("remat_block"), rec.get("capacity_factor"))


def _ok_cells(records: List[Dict[str, Any]]) -> Dict[Tuple, Dict[str, Any]]:
    return {cell_key(r): r for r in records
            if r.get("status") == "ok" and isinstance(r.get("roofline"), dict)}


def diff_trajectories(current: List[Dict[str, Any]],
                      baseline: List[Dict[str, Any]],
                      threshold: float = DEFAULT_THRESHOLD,
                      metrics: Optional[Dict[str, str]] = None
                      ) -> Dict[str, Any]:
    """Compare two record lists; returns {regressions, missing_metrics,
    compared, only_*}.

    Each regression is ``{key, metric, baseline, current, change}`` with
    ``change`` the signed relative move in the bad direction (e.g. +0.30
    for a 30% collective_s growth). ``missing_metrics`` lists gated
    metrics the baseline cell HAS but the current cell LOST — a renamed
    or dropped roofline key must fail loudly, not silently stop being
    gated (metrics absent from both sides stay skipped, so old baselines
    remain comparable as the key set grows).
    """
    metrics = METRICS if metrics is None else metrics
    cur = _ok_cells(current)
    base = _ok_cells(baseline)
    regressions: List[Dict[str, Any]] = []
    missing: List[Dict[str, Any]] = []
    compared = 0
    for key, crec in cur.items():
        brec = base.get(key)
        if brec is None:
            continue
        compared += 1
        for metric, direction in metrics.items():
            cval = crec["roofline"].get(metric)
            bval = brec["roofline"].get(metric)
            if not isinstance(bval, (int, float)):
                continue
            if not isinstance(cval, (int, float)):
                missing.append({"key": key, "metric": metric,
                                "baseline": bval})
                continue
            if bval == 0:
                continue
            rel = (cval - bval) / abs(bval)
            bad = rel if direction == "lower" else -rel
            if bad > threshold:
                regressions.append({
                    "key": key, "metric": metric,
                    "baseline": bval, "current": cval,
                    "change": round(bad, 4),
                })
    return {
        "regressions": regressions,
        "missing_metrics": missing,
        "compared": compared,
        "only_current": sorted(str(k) for k in cur.keys() - base.keys()),
        "only_baseline": sorted(str(k) for k in base.keys() - cur.keys()),
    }


def load_records(path: str) -> Optional[List[Dict[str, Any]]]:
    """Records list from a BENCH_roofline.json payload; None if unusable."""
    if not os.path.exists(path):
        return None
    try:
        with open(path) as f:
            payload = json.load(f)
        recs = payload.get("records") if isinstance(payload, dict) else None
        return recs if isinstance(recs, list) else None
    except (OSError, ValueError):
        return None


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--current", required=True,
                    help="fresh BENCH_roofline.json")
    ap.add_argument("--baseline", required=True,
                    help="previous run's BENCH_roofline.json "
                         "(missing => tolerated, gate passes)")
    ap.add_argument("--threshold", type=float, default=DEFAULT_THRESHOLD,
                    help="relative regression tolerance (default 0.15)")
    args = ap.parse_args(argv)

    current = load_records(args.current)
    if current is None:
        print(f"[bench-diff] FAIL: current trajectory {args.current!r} "
              "missing or unreadable")
        return 1
    baseline = load_records(args.baseline)
    if baseline is None:
        print(f"[bench-diff] no usable baseline at {args.baseline!r} "
              "(first run or expired artifact) — gate passes")
        return 0

    res = diff_trajectories(current, baseline, threshold=args.threshold)
    print(f"[bench-diff] compared {res['compared']} cells "
          f"(threshold {args.threshold:.0%}); "
          f"{len(res['only_current'])} new, "
          f"{len(res['only_baseline'])} baseline-only")
    for k in res["only_current"]:
        print(f"  new cell (not gated): {k}")
    for k in res["only_baseline"]:
        print(f"  dropped cell (not gated): {k}")
    for m in res["missing_metrics"]:
        print(f"  MISSING {m['key']}: gated metric {m['metric']!r} "
              f"(baseline {m['baseline']:.6g}) disappeared from the fresh "
              "artifact — renamed keys must not silently stop being gated")
    if not res["regressions"] and not res["missing_metrics"]:
        print("[bench-diff] OK: no regression beyond threshold")
        return 0
    for r in res["regressions"]:
        print(f"  REGRESSION {r['key']}: {r['metric']} "
              f"{r['baseline']:.6g} -> {r['current']:.6g} "
              f"({r['change']:+.1%} in the bad direction)")
    print(f"[bench-diff] FAIL: {len(res['regressions'])} regression(s), "
          f"{len(res['missing_metrics'])} disappeared metric(s)")
    return 1


if __name__ == "__main__":
    sys.exit(main())
