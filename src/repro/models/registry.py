"""Public facade over the model zoo, plus the family-agnostic decode-state
surface the serve/train paths program against.

Historically every serving feature (ragged batching, slot streaming,
quantized cache residency) carried its own copy-pasted family check, so the
scenario matrix was transformer-only in practice. This module replaces
those checks with two things:

* :func:`capabilities` — one table of what each family's decode state
  supports, consulted by ``launch/serve.py`` and ``train/step.py`` (the
  former three refusal sites). :func:`require` raises the uniform
  refusal naming the flag, the family, and the missing capability.
* :class:`StateStore` — one protocol over the per-family decode state:
  ``abstract_state / state_axes / init_state / admit_row / free_row``.
  The KV ring buffer (``attention.py``), the SSM/mLSTM/sLSTM O(1)
  recurrent state (``ssm.py``, ``xlstm.py``), and MoE decode state all
  serve through it — a leaf with a ``kv_seq`` axis admits as a cache
  slice, a leaf without one (recurrent state, ring bookkeeping) admits
  as a whole-row overwrite — so slot streaming never special-cases a
  family again.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import FAMILIES, ModelConfig, get_config, smoke_config  # noqa: F401
from repro.dist import collectives
from repro.dist import sharding as _shd
from repro.models import transformer
from repro.models.transformer import (  # noqa: F401
    abstract_cache,
    abstract_params,
    cache_axes,
    cache_struct,
    forward,
    init_cache,
    init_params,
    param_axes,
    param_specs,
)


# ---------------------------------------------------------------------------
# capabilities
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Capabilities:
    """What one family's decode state supports on the serve path.

    ``ragged``: whole-batch ragged ``prompt_lens`` (per-row masking of a
    padded batch). ``slot_stream``: per-request slot admission into a
    running decode batch. ``quantized_storage``: int8/f8-*resident*
    decode state. ``row_state``: the state is correct only if prefill
    never sees pad tokens (ring buffers alias junk slots into the
    window; recurrent scans fold pads into the state) — slot streaming
    then prefills each request at its exact length and admits the whole
    row, instead of masking a padded slice. ``paged``: the
    ``[slots, total]`` state table can serve as a paged pool
    (:class:`PagedStateStore`) — sound only for full (slot == position)
    attention caches, where junk in unallocated pages is masked by
    per-row positions; ring buffers alias page junk into the window and
    recurrent rows are O(1) (nothing to page).
    """
    family: str
    ragged: bool
    slot_stream: bool
    quantized_storage: bool
    row_state: bool
    paged: bool
    why_ragged: str = ""
    why_storage: str = ""
    why_paged: str = ""


_WHY_RAGGED_RECURRENT = (
    "windowed (ring-buffer) and recurrent-state families fold pad tokens "
    "into per-row state during whole-batch prefill and per-row masks "
    "cannot undo that; serve them with --stream slots (exact-length "
    "per-request prefill) or pad to a uniform length")
_WHY_STORAGE_RECURRENT = (
    "recurrent state leaves (ssm/xlstm) accumulate quantization error "
    "across steps; only pure-attention caches are quantized-resident")
_WHY_PAGED = (
    "paging assumes a full (slot == position) cache whose unallocated "
    "pages are masked by per-row positions; ring-buffer windows alias "
    "page junk into the window and recurrent rows are O(1) per slot — "
    "there is nothing to page")

_ATTENTION_CAPS = dict(ragged=True, slot_stream=True,
                       quantized_storage=True, row_state=False, paged=True)
_RECURRENT_CAPS = dict(ragged=False, slot_stream=True,
                       quantized_storage=False, row_state=True, paged=False,
                       why_ragged=_WHY_RAGGED_RECURRENT,
                       why_storage=_WHY_STORAGE_RECURRENT,
                       why_paged=_WHY_PAGED)

_FAMILY_CAPS = {
    "dense": _ATTENTION_CAPS,
    "moe": _ATTENTION_CAPS,
    "mla": _ATTENTION_CAPS,
    "vlm": _ATTENTION_CAPS,
    "encoder_audio": _ATTENTION_CAPS,
    "hybrid": _RECURRENT_CAPS,
    "ssm_xlstm": _RECURRENT_CAPS,
}


def capabilities(cfg_or_family: Union[ModelConfig, str]) -> Capabilities:
    """The capability record for a family (or a concrete config — an
    ``attn_window`` turns any attention family into a ring buffer, which
    drops whole-batch ragged and makes slot prefill exact-length)."""
    if isinstance(cfg_or_family, str):
        family, windowed = cfg_or_family, False
    else:
        family, windowed = cfg_or_family.family, bool(cfg_or_family.attn_window)
    if family not in _FAMILY_CAPS:
        raise ValueError(f"unknown family {family!r}; "
                         f"expected one of {tuple(_FAMILY_CAPS)}")
    base = dict(_FAMILY_CAPS[family])
    if windowed and base["ragged"]:
        base.update(ragged=False, row_state=True, paged=False,
                    why_ragged=_WHY_RAGGED_RECURRENT,
                    why_paged=_WHY_PAGED)
    return Capabilities(family=family, **base)


def require(cfg: ModelConfig, capability: str, flag: str) -> None:
    """Raise the uniform refusal if ``cfg``'s family lacks ``capability``.

    ``flag`` names the user-facing knob (``"ragged prompt_lens"``,
    ``"--stream slots"``, ``"kv_storage='int8'"``); the error names the
    flag, the family, and the missing capability so every refusal site
    reads the same.
    """
    caps = capabilities(cfg)
    if getattr(caps, capability):
        return
    why = {"ragged": caps.why_ragged,
           "quantized_storage": caps.why_storage,
           "paged": caps.why_paged}.get(capability, "")
    raise NotImplementedError(
        f"{flag} is unsupported for {cfg.name} (family={caps.family}): "
        f"missing capability {capability!r}"
        + (f" — {why}" if why else ""))


# ---------------------------------------------------------------------------
# the StateStore protocol
# ---------------------------------------------------------------------------

def _rename_batch(axes_tree, name: str):
    return jax.tree.map(
        lambda la: tuple(name if a == "batch" else a for a in la),
        axes_tree, is_leaf=lambda x: isinstance(x, tuple))


@dataclasses.dataclass(frozen=True)
class StateStore:
    """One family-agnostic handle on a model's decode-state table.

    ``rows`` is the slot-table size (the state's batch dim doubles as the
    slot dim), ``total`` the decode horizon (sizes attention caches;
    O(1) recurrent state ignores it). Attention families store
    ``[rows, total]`` KV slices; ring-buffer and recurrent families
    store O(1)-per-row state — a *better* fit for slot streaming: no
    paging, admission is a whole-row overwrite.

    ``admit_row``/``free_row`` are pure functions over the state pytree
    (jit them with the store layout as ``out_shardings``); ``slot`` may
    be a traced scalar so one compiled program serves every slot.
    """
    cfg: ModelConfig
    rows: int
    total: int
    kv_storage: str = "bf16"

    def __post_init__(self):
        if self.kv_storage != "bf16":
            require(self.cfg, "quantized_storage",
                    f"kv_storage={self.kv_storage!r}")

    @property
    def caps(self) -> Capabilities:
        return capabilities(self.cfg)

    # --- layout -----------------------------------------------------------
    def abstract_state(self):
        """ShapeDtypeStructs of the state table in its resident layout."""
        return transformer.abstract_cache(self.cfg, self.rows, self.total,
                                          kv_storage=self.kv_storage)

    def state_axes(self):
        """Logical axes of the state table, batch dim renamed to "slots"
        (the serve presets map it to the batch's mesh axes)."""
        return _rename_batch(
            transformer.cache_axes(self.cfg, self.rows, self.total,
                                   kv_storage=self.kv_storage), "slots")

    def row_axes(self):
        """Logical axes of one request's ``[1, total]`` bf16 state slice
        (the admission payload's layout)."""
        return transformer.cache_axes(self.cfg, 1, self.total)

    def abstract_row(self):
        return transformer.abstract_cache(self.cfg, 1, self.total)

    def init_state(self):
        """Zero-initialized state table (empty rows read as masked/empty
        until admitted)."""
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                            self.abstract_state())

    # --- row admission ----------------------------------------------------
    def admit_row(self, state, row, slot, *, transfer: str = "bf16",
                  block: int = collectives.ACT_BLOCK):
        """Write one request's ``[1, total]`` bf16 state slice into row
        ``slot`` of the running state table (in its resident layout).

        Per leaf: a ``kv_seq``-carrying leaf is a cache slice
        (``transfer="int8"`` streams it seq-blockwise via
        ``collectives.stream_slot_int8``); a leaf without one is O(1)
        row state and is overwritten whole (``transfer="int8"`` ships it
        feature-blockwise via ``collectives.stream_row_int8``). The
        written rows are constrained to the slot-table layout so XLA
        never regathers around the dynamic update.
        """
        if transfer not in collectives.CACHE_TRANSFERS:
            raise ValueError(f"unknown cache_transfer {transfer!r}; "
                             f"expected one of {collectives.CACHE_TRANSFERS}")
        slot = jnp.asarray(slot, jnp.int32)
        if self.kv_storage != "bf16":
            return self._admit_row_quantized(state, row, slot,
                                             transfer=transfer, block=block)
        row_axes = self.row_axes()
        leaves, treedef = jax.tree.flatten(state)
        row_l = treedef.flatten_up_to(row)
        raxes_l = [tuple(a) for a in treedef.flatten_up_to(row_axes)]
        saxes_l = [tuple(a) for a in treedef.flatten_up_to(self.state_axes())]
        out = []
        for cur, new, la, sa in zip(leaves, row_l, raxes_l, saxes_l):
            ba = la.index("batch")
            if transfer == "int8" and "kv_seq" in la:
                upd = collectives.stream_slot_int8(
                    cur, new, slot, *la, seq_axis=la.index("kv_seq"),
                    batch_axis=ba, block=block)
            elif transfer == "int8":
                upd = collectives.stream_row_int8(
                    cur, new, slot, *la, batch_axis=ba, block=block)
            else:
                start = [jnp.zeros((), jnp.int32)] * cur.ndim
                start[ba] = slot
                upd = jax.lax.dynamic_update_slice(
                    cur, new.astype(cur.dtype), tuple(start))
            out.append(_shd.constrain(upd, *sa))
        return treedef.unflatten(out)

    def _admit_row_quantized(self, state, row, slot, *, transfer: str,
                             block: int):
        """int8/f8-resident admission: wire the bf16 slice, re-encode it
        into the storage layout (s8 + scale leaves / e4m3), write each
        storage leaf's row. Flat attention caches only — capabilities
        refuse quantized storage for recurrent families."""
        row_axes = self.row_axes()
        store_axes = self.state_axes()
        out = dict(state)
        wired = {}
        for name, leaf in row.items():
            la = tuple(row_axes[name])
            if transfer == "int8" and "kv_seq" in la:
                leaf = collectives.stream_int8(
                    leaf, *la, seq_axis=la.index("kv_seq"), block=block)
            wired[name] = leaf
        store = transformer.quantize_cache(wired, self.kv_storage)
        for name, upd in store.items():
            la = tuple(store_axes[name])
            start = [jnp.zeros((), jnp.int32)] * state[name].ndim
            start[la.index("slots")] = slot
            out[name] = _shd.constrain(
                jax.lax.dynamic_update_slice(
                    state[name], upd.astype(state[name].dtype),
                    tuple(start)),
                *la)
        return out

    def free_row(self, state, slot):
        """Zero row ``slot`` of every leaf. Admission overwrites rows
        fully, so this is explicit-eviction hygiene (a freed slot reads
        as empty, not as its previous occupant)."""
        slot = jnp.asarray(slot, jnp.int32)

        def zero(leaf, la):
            la = tuple(la)
            ba = la.index("slots")
            shape = list(leaf.shape)
            shape[ba] = 1
            start = [jnp.zeros((), jnp.int32)] * leaf.ndim
            start[ba] = slot
            return _shd.constrain(
                jax.lax.dynamic_update_slice(
                    leaf, jnp.zeros(shape, leaf.dtype), tuple(start)),
                *la)
        return jax.tree.map(zero, state, self.state_axes())


def state_store(cfg: ModelConfig, rows: int, total: int,
                kv_storage: str = "bf16") -> StateStore:
    """The StateStore for ``cfg``'s family (validates storage capability)."""
    return StateStore(cfg=cfg, rows=rows, total=total, kv_storage=kv_storage)


# ---------------------------------------------------------------------------
# the paged variant
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PagedStateStore(StateStore):
    """Paged slot table: rows are lists of fixed-size pages in a shared
    pool, so mixed-length requests allocate pages on demand instead of
    padding every row to the decode horizon.

    Every ``kv_seq``-carrying leaf of the dense ``[slots, total]`` layout
    (values AND int8 scale leaves — quantization is per position, so
    pages never straddle a scale block) is stored pool-form: the
    ``(slots, total)`` axes become ``(n_pool, page)``, and a host-owned
    page table ``[rows, total // page]`` of int32 pool indices (-1 =
    unallocated) maps each slot's positions onto pool pages.

    ``gather_dense``/``scatter_dense`` bracket the *unchanged* dense
    decode step: gather reconstructs the ``[rows, total]`` view through
    the page table (-1 clamps to page 0 — junk that per-row position
    masks NEG_INF away before the softmax, so reconstruction is
    bit-exact for every live position), the dense step runs, and scatter
    writes the result back dropping unallocated entries. That bracketing
    is why paged greedy tokens bit-match the unpaged path.

    ``admit_pages`` ships only a request's LIVE pages (the paged form of
    slot admission): a grown ``[1, n_live * page]`` bf16 slice is wired
    (optionally int8 seq-blockwise), re-encoded into the resident
    storage layout, and scattered at the slot's freshly allocated pool
    pages — int8/f8 storage arms preserved.

    Page allocation/free is host bookkeeping (the page table lives on
    the host, uploaded per step); a freed slot's pages return to the
    free list and its stale pool contents are never gathered again.
    """
    page: int = 256
    pool_pages: int = 0                # 0 = fully backed

    def __post_init__(self):
        super().__post_init__()
        require(self.cfg, "paged", "--paged")
        if self.page < 1:
            raise ValueError(f"page size must be >= 1, got {self.page}")
        if self.total % self.page != 0:
            raise ValueError(
                f"page size {self.page} must divide the decode horizon "
                f"{self.total} (round the horizon up or pick a divisor)")
        if self.n_pool < self.pages_per_row:
            raise ValueError(
                f"pool of {self.n_pool} pages cannot back even one "
                f"{self.pages_per_row}-page row; raise pool_pages")

    @property
    def pages_per_row(self) -> int:
        return self.total // self.page

    @property
    def n_pool(self) -> int:
        return self.pool_pages or self.rows * self.pages_per_row

    # --- layout -----------------------------------------------------------
    def _pool_axis(self, la) -> int:
        la = tuple(la)
        i = la.index("slots")
        if i + 1 >= len(la) or la[i + 1] != "kv_seq":
            raise NotImplementedError(
                f"paged leaf layout {la} lacks an adjacent "
                "(slots, kv_seq) pair")
        if i != 1:
            raise NotImplementedError(
                f"paged leaf layout {la} expects (layers, slots, kv_seq, "
                "...)")
        return i

    def dense_abstract_state(self):
        """The ``[rows, total]`` storage layout the decode step sees."""
        return super().abstract_state()

    def dense_state_axes(self):
        return super().state_axes()

    def abstract_state(self):
        """Pool-form ShapeDtypeStructs: (slots, total) -> (n_pool, page)."""
        out = {}
        for name, leaf in super().abstract_state().items():
            i = self._pool_axis(super().state_axes()[name])
            shape = leaf.shape[:i] + (self.n_pool, self.page) \
                + leaf.shape[i + 2:]
            out[name] = jax.ShapeDtypeStruct(shape, leaf.dtype)
        return out

    def state_axes(self):
        """Pool-form logical axes: the pool-page axis is "pages" (the
        serve presets map it to the slot table's mesh axes); positions
        inside a page are unsharded."""
        out = {}
        for name, la in super().state_axes().items():
            i = self._pool_axis(la)
            la = tuple(la)
            out[name] = la[:i] + ("pages", None) + la[i + 2:]
        return out

    def abstract_page_table(self):
        return jax.ShapeDtypeStruct((self.rows, self.pages_per_row),
                                    jnp.int32)

    def init_page_table(self) -> np.ndarray:
        """Host-owned page table, all rows unallocated."""
        return np.full((self.rows, self.pages_per_row), -1, np.int32)

    def page_bytes(self) -> int:
        """Resident bytes one pool page costs across every leaf (all
        layers) — the unit of the ``paged_hbm_bytes_per_slot`` metric."""
        tot = 0
        for leaf in self.abstract_state().values():
            per = int(np.prod(leaf.shape)) * leaf.dtype.itemsize
            tot += per // self.n_pool
        return tot

    # --- dense view around the unchanged decode step ----------------------
    def gather_dense(self, state, page_table):
        """Reconstruct the dense ``[rows, total]`` storage-layout cache by
        reading every leaf through the page table. Unallocated entries
        (-1) clamp to pool page 0: junk, but only at positions beyond
        each row's live length, which decode attention masks."""
        pt = jnp.clip(jnp.asarray(page_table, jnp.int32), 0).reshape(-1)
        dense_axes = self.dense_state_axes()
        out = {}
        for name, leaf in state.items():
            i = self._pool_axis(dense_axes[name])
            g = jnp.take(leaf, pt, axis=i)
            shape = leaf.shape[:i] + (self.rows, self.total) \
                + leaf.shape[i + 2:]
            out[name] = _shd.constrain(g.reshape(shape), *dense_axes[name])
        return out

    def scatter_dense(self, state, dense, page_table):
        """Write a dense ``[rows, total]`` cache back into the pool;
        entries whose page-table slot is unallocated are dropped (mapped
        out of bounds, scatter mode "drop")."""
        pt = jnp.asarray(page_table, jnp.int32)
        pt = jnp.where(pt < 0, self.n_pool, pt).reshape(-1)
        pool_axes = self.state_axes()
        out = {}
        for name, leaf in state.items():
            i = self._pool_axis(self.dense_state_axes()[name])
            pages = dense[name].reshape(
                leaf.shape[:i] + (self.rows * self.pages_per_row, self.page)
                + leaf.shape[i + 2:])
            out[name] = _shd.constrain(
                leaf.at[:, pt].set(pages.astype(leaf.dtype), mode="drop"),
                *pool_axes[name])
        return out

    # --- paged admission --------------------------------------------------
    def admit_pages(self, state, slc, page_idx, *, transfer: str = "bf16",
                    block: int = collectives.ACT_BLOCK):
        """Admit one request's live pages: ``slc`` is its grown
        ``[1, n_live * page]`` bf16 state slice (junk beyond the prompt is
        masked by the row's position), ``page_idx`` an ``(n_live,)``
        int32 vector of freshly allocated pool destinations. The slice is
        wired (``transfer="int8"``: seq-blockwise s8 chunks + scales, the
        colocated form), re-encoded into the resident storage layout, and
        scattered page-wise into the pool. ``page_idx`` may be traced, so
        one compiled program serves every admission of the same page
        count."""
        if transfer not in collectives.CACHE_TRANSFERS:
            raise ValueError(f"unknown cache_transfer {transfer!r}; "
                             f"expected one of {collectives.CACHE_TRANSFERS}")
        page_idx = jnp.asarray(page_idx, jnp.int32)
        n_live = page_idx.shape[0]
        live_len = n_live * self.page
        row_axes = transformer.cache_axes(self.cfg, 1, live_len)
        wired = {}
        for name, leaf in slc.items():
            la = tuple(row_axes[name])
            if transfer == "int8" and "kv_seq" in la:
                leaf = collectives.stream_int8(
                    leaf, *la, seq_axis=la.index("kv_seq"), block=block)
            wired[name] = leaf
        store_slc = transformer.quantize_cache(wired, self.kv_storage)
        pool_axes = self.state_axes()
        out = {}
        for name, leaf in state.items():
            pages = store_slc[name].reshape(
                leaf.shape[:1] + (n_live, self.page) + leaf.shape[3:])
            out[name] = _shd.constrain(
                leaf.at[:, page_idx].set(pages.astype(leaf.dtype)),
                *pool_axes[name])
        return out


def paged_state_store(cfg: ModelConfig, rows: int, total: int,
                      kv_storage: str = "bf16", page: int = 256,
                      pool_pages: int = 0) -> PagedStateStore:
    """The paged StateStore (validates the family's ``paged`` capability
    and that ``page`` divides ``total``)."""
    return PagedStateStore(cfg=cfg, rows=rows, total=total,
                           kv_storage=kv_storage, page=page,
                           pool_pages=pool_pages)
