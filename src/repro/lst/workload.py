"""CAB-like synthetic workload generator (§6 "Design of Experimental
Workloads"): query streams modeled after cloud warehouse usage — constant
demand with sinusoidal variation (dashboards), short bursts (interactive),
large bursts (daily maintenance), and predictable hourly jobs — driving
writes into partitioned (LINEITEM-like) and unpartitioned (ORDERS-like)
tables. Deterministic under a seed (NFR2 makes the whole pipeline
reproducible end-to-end).
"""

from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.lst.catalog import Catalog
from repro.lst.files import DataFile
from repro.lst.table import CommitConflict, LogStructuredTable

MB = 1 << 20


class SimClock:
    """Logical time in hours (float)."""

    def __init__(self, start: float = 0.0) -> None:
        self.t = start

    def now(self) -> float:
        return self.t

    def advance(self, hours: float) -> None:
        self.t += hours


@dataclasses.dataclass
class StreamSpec:
    kind: str          # "dashboard" | "interactive" | "maintenance" | "hourly"
    table: str
    namespace: str
    reads_per_hour: float = 4.0
    writes_per_hour: float = 1.0
    files_per_write: Tuple[int, int] = (4, 40)       # min,max small files
    file_size_mb: Tuple[float, float] = (0.5, 32.0)  # lognormal-ish range


@dataclasses.dataclass
class WorkloadSpec:
    n_databases: int = 4
    tables_per_db: int = 4
    partitions_per_table: int = 12        # monthly SHIPDATE granularity
    partitioned_fraction: float = 0.5
    target_file_mb: int = 512
    initial_files_per_table: Tuple[int, int] = (50, 400)
    seed: int = 0


@dataclasses.dataclass
class QueryEvent:
    t: float
    kind: str            # "read" | "write"
    table_id: str
    latency: float = 0.0
    files_scanned: int = 0
    conflict: bool = False
    retries: int = 0


class CostModel:
    """Client-visible latency model: planning scales with file count (RPC
    pressure), execution with bytes and per-file open overhead — the
    mechanism behind Fig. 3/Fig. 8."""

    def __init__(self, open_ms: float = 4.0, plan_ms_per_file: float = 0.8,
                 read_gb_per_s: float = 1.0, base_ms: float = 50.0):
        self.open_ms = open_ms
        self.plan_ms_per_file = plan_ms_per_file
        self.read_gb_per_s = read_gb_per_s
        self.base_ms = base_ms

    def read_latency_s(self, files: Sequence[DataFile]) -> float:
        n = len(files)
        byts = sum(f.size_bytes for f in files)
        return (self.base_ms + n * (self.open_ms + self.plan_ms_per_file)
                ) / 1e3 + byts / (self.read_gb_per_s * 1e9)


class WorkloadGenerator:
    def __init__(self, catalog: Catalog, spec: WorkloadSpec,
                 clock: Optional[SimClock] = None,
                 cost: Optional[CostModel] = None) -> None:
        self.catalog = catalog
        self.spec = spec
        self.clock = clock or SimClock()
        self.cost = cost or CostModel()
        self.rng = np.random.RandomState(spec.seed)
        self.streams: List[StreamSpec] = []
        self.events: List[QueryEvent] = []
        self._file_ids = itertools.count(1)

    # -------------------------------------------------------------- setup
    def setup(self) -> None:
        kinds = ["dashboard", "interactive", "maintenance", "hourly"]
        for d in range(self.spec.n_databases):
            ns = f"db{d:02d}"
            self.catalog.create_namespace(ns, total_quota=200_000)
            for t in range(self.spec.tables_per_db):
                partitioned = self.rng.rand() < self.spec.partitioned_fraction
                name = f"table{t:02d}"
                table = self.catalog.create_table(
                    ns, name, "ship_month" if partitioned else None,
                    properties={"conflict_granularity": "table"})
                table.now_fn = self.clock.now
                n0 = self.rng.randint(*self.spec.initial_files_per_table)
                self._append_small_files(table, n0)
                self.streams.append(StreamSpec(
                    kind=kinds[t % len(kinds)], table=name, namespace=ns,
                    reads_per_hour=float(self.rng.randint(2, 12)),
                    writes_per_hour=float(self.rng.randint(1, 6))))

    def _rand_partition(self, table: LogStructuredTable) -> Optional[str]:
        if not table.meta.partition_spec:
            return None
        return f"m{self.rng.randint(self.spec.partitions_per_table):02d}"

    def _small_file(self, table: LogStructuredTable,
                    partition: Optional[str]) -> DataFile:
        lo, hi = 0.5, 32.0
        size = float(np.exp(self.rng.uniform(np.log(lo), np.log(hi)))) * MB
        fid = next(self._file_ids)
        path = f"{table.table_id}/data/part-{fid:08d}.parquet"
        table.store.put(path, b"x" * min(int(size) // (1 << 14) + 1, 4096))
        return DataFile(path=path, size_bytes=int(size),
                        num_rows=int(size // 200), partition=partition,
                        created_at=self.clock.now())

    def _append_small_files(self, table: LogStructuredTable, n: int) -> int:
        files = [self._small_file(table, self._rand_partition(table))
                 for _ in range(n)]
        before = table.cas_retries
        table.append(files)
        self.catalog.notify_write(table)
        return table.cas_retries - before

    def _prepare_append(self, table: LogStructuredTable, n: int):
        """Open an append transaction (committed later — concurrent writers
        on the same table then collide on the version CAS, the paper's
        client-side conflicts)."""
        files = [self._small_file(table, self._rand_partition(table))
                 for _ in range(n)]
        return table.new_transaction().append_files(files)

    # -------------------------------------------------------------- phases
    def _intensity(self, stream: StreamSpec, hour: float) -> float:
        if stream.kind == "dashboard":     # sinusoidal constant demand
            return 1.0 + 0.5 * math.sin(2 * math.pi * hour / 24.0)
        if stream.kind == "interactive":   # short random bursts
            return 3.0 if self.rng.rand() < 0.2 else 0.3
        if stream.kind == "maintenance":   # large daily burst around hour 4
            return 6.0 if int(hour) % 24 == 4 else 0.1
        return 1.0 if abs(hour - round(hour)) < 0.26 else 0.0   # hourly job

    def run_hour(self, substeps: int = 4) -> List[QueryEvent]:
        """Advance one logical hour of mixed reads/writes. Writes within a
        substep run as CONCURRENT transactions (opened first, committed
        together), so same-table writers collide on the version CAS."""
        out: List[QueryEvent] = []
        for _ in range(substeps):
            self.clock.advance(1.0 / substeps)
            pending = []                      # (table, txn, event)
            for st in self.streams:
                table = self.catalog.get_table(st.namespace, st.table)
                inten = self._intensity(st, self.clock.now())
                n_reads = self.rng.poisson(st.reads_per_hour * inten / substeps)
                n_writes = self.rng.poisson(st.writes_per_hour * inten / substeps)
                for _ in range(n_reads):
                    part = self._rand_partition(table)
                    files = table.scan(partition=part)
                    # execute the read: one open() RPC per data file (the
                    # HDFS pressure that Fig. 11b measures)
                    for f in files:
                        if table.store.exists(f.path):
                            table.store.metrics.open_calls += 1
                    ev = QueryEvent(self.clock.now(), "read", table.table_id,
                                    latency=self.cost.read_latency_s(files),
                                    files_scanned=len(files))
                    out.append(ev)
                for _ in range(n_writes):
                    n_files = self.rng.randint(*st.files_per_write)
                    txn = self._prepare_append(table, n_files)
                    ev = QueryEvent(self.clock.now(), "write", table.table_id)
                    pending.append((table, txn, ev))
                    out.append(ev)
            for table, txn, ev in pending:    # concurrent commit wave
                before = table.cas_retries
                txn.commit()
                self.catalog.notify_write(table)
                ev.retries = table.cas_retries - before
                ev.conflict = ev.retries > 0
        self.events.extend(out)
        return out

    # -------------------------------------------------------------- metrics
    def total_file_count(self) -> int:
        return sum(t.file_count() for t in self.catalog.tables())

    def small_file_fraction(self, target_bytes: int) -> float:
        files = [f for t in self.catalog.tables() for f in t.current_files()]
        if not files:
            return 0.0
        return sum(1 for f in files if f.size_bytes < target_bytes) / len(files)
