"""Pure-jnp oracle: masked softmax attention with GQA."""

from __future__ import annotations

import jax.numpy as jnp
import jax
import numpy as np


def flash_attention_ref(q, k, v, *, causal=True, window=0):
    """q: (B,H,S,D); k,v: (B,Hkv,S,D)."""
    b, h, s, d = q.shape
    hkv = k.shape[1]
    group = h // hkv
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / np.sqrt(d)
    qp = jnp.arange(s)[:, None]
    kp = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= qp >= kp
    if window:
        mask &= (qp - kp) < window
    scores = jnp.where(mask, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
